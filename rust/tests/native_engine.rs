//! Hermetic end-to-end tests: the native-kernel backend through the full
//! serving stack — engine worker, dynamic batcher, metrics and the server
//! protocol — with no artifacts, no PJRT and no external crates. This is
//! the coverage `cargo test -q` provides on a fresh checkout.

use std::sync::Arc;
use std::time::Duration;

use dsa_serve::coordinator::{
    AdaptiveRouter, BatchPolicy, Engine, EngineConfig, NativeModelConfig, SessionPolicy,
};
use dsa_serve::kernels::Variant;
use dsa_serve::server::{Conn, QuotaConfig, ServerState};
use dsa_serve::util::json::Json;
use dsa_serve::workload::{GenSession, Workload, WorkloadConfig};

const SEQ_LEN: usize = 256;

/// Build an engine for a variant *name*, parsing it exactly once at the
/// test boundary — the same place the CLI/protocol would.
fn engine(variant: &str) -> Engine {
    Engine::start_native(
        NativeModelConfig {
            seq_len: SEQ_LEN,
            ..Default::default()
        },
        EngineConfig {
            default_variant: variant.parse::<Variant>().expect("test variant"),
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
                queue_cap: 128,
                default_deadline: None,
            },
            preload: true,
            router: None,
            sessions: SessionPolicy::default(),
        },
    )
    .expect("native engine")
}

/// A protocol connection over a fresh server state (no sockets), with
/// unlimited quotas unless the test configures them.
fn conn(engine: &Arc<Engine>) -> (Conn, Arc<ServerState>) {
    let state = Arc::new(ServerState::new());
    (
        Conn::new(engine.clone(), state.clone(), QuotaConfig::default()),
        state,
    )
}

/// Serve a burst of requests; the hand-constructed classifier must solve
/// the task through both the dense and the dynamic-sparse kernels, and the
/// dynamic batcher must actually batch.
fn serve_and_score(variant: &str, n: usize) -> (usize, f64) {
    let typed = variant.parse::<Variant>().expect("test variant");
    let engine = engine(variant);
    let mut wl = Workload::new(WorkloadConfig {
        seq_len: SEQ_LEN,
        seed: 99,
        ..Default::default()
    });
    let trace = wl.trace(n);
    let mut rxs = Vec::new();
    let mut labels = Vec::new();
    for r in trace {
        labels.push(r.label);
        rxs.push(engine.submit(r.tokens, None, None).expect("submit"));
    }
    let mut correct = 0;
    for (rx, label) in rxs.into_iter().zip(labels) {
        let resp = rx.recv().expect("channel").expect("served");
        assert_eq!(resp.logits.len(), engine.classes());
        assert!(resp.latency > Duration::ZERO);
        assert_eq!(resp.variant, typed);
        if resp.pred as i32 == label {
            correct += 1;
        }
    }
    let occ = engine
        .metrics
        .to_json()
        .get("mean_occupancy")
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0);
    (correct, occ)
}

#[test]
fn dense_engine_solves_task_and_batches() {
    let n = 32;
    let (correct, occ) = serve_and_score("dense", n);
    assert!(correct >= 29, "dense accuracy too low: {correct}/{n}");
    assert!(occ > 1.0, "expected batching, mean occupancy {occ}");
}

#[test]
fn dsa90_engine_solves_task() {
    let n = 32;
    let (correct, _) = serve_and_score("dsa90", n);
    assert!(correct >= 28, "dsa90 accuracy too low: {correct}/{n}");
}

#[test]
fn dsa95_engine_beats_chance() {
    let n = 32;
    let (correct, _) = serve_and_score("dsa95", n);
    // 95% sparsity is near the budget where label-1 masks saturate; it
    // must still clearly beat chance (22/32 ~ 5 sigma).
    assert!(correct >= 22, "dsa95 accuracy too low: {correct}/{n}");
}

#[test]
fn variant_override_routing() {
    let e = engine("dsa90");
    let mut wl = Workload::new(WorkloadConfig {
        seq_len: SEQ_LEN,
        seed: 4,
        ..Default::default()
    });
    let r = wl.next_request();
    let resp_dense = e.infer(r.tokens.clone(), Some(Variant::Dense)).expect("dense");
    let resp_dsa = e.infer(r.tokens, Some(Variant::Dsa { pct: 95 })).expect("dsa95");
    assert_eq!(resp_dense.variant, Variant::Dense);
    assert_eq!(resp_dsa.variant, Variant::Dsa { pct: 95 });
}

/// With the typed `Variant` API an unknown variant can no longer reach
/// the engine at all: it fails at the parse boundary — the server
/// protocol replies with a structured error, and the engine stays healthy
/// for subsequent requests. (Before the redesign the bogus string rode
/// the queue and only failed at batch execution.)
#[test]
fn unknown_variant_fails_at_parse_boundary() {
    assert!("bogus".parse::<Variant>().is_err());
    let engine = Arc::new(engine("dense"));
    let (mut c, _state) = conn(&engine);
    let toks: Vec<String> = vec![1i32; SEQ_LEN].iter().map(|t| t.to_string()).collect();
    let line = format!(
        r#"{{"op":"infer","variant":"bogus","tokens":[{}]}}"#,
        toks.join(",")
    );
    let err = c.handle_line(&line).expect_err("unknown variant");
    assert!(
        format!("{err:#}").contains("bogus"),
        "error must name the rejected variant"
    );
    // A present-but-non-string variant field is rejected too — never
    // silently served under the default variant.
    let line = format!(
        r#"{{"op":"infer","variant":90,"tokens":[{}]}}"#,
        toks.join(",")
    );
    let err = c.handle_line(&line).expect_err("non-string variant");
    assert!(
        format!("{err:#}").contains("must be a string"),
        "error must explain the malformed field"
    );
    // The engine never saw either request and keeps serving.
    assert!(engine.infer(vec![1i32; SEQ_LEN], None).is_ok());
}

/// The execute_batch runtime-failure contract, end to end: an
/// unbuildable (representable-but-invalid) variant override reaches
/// batch execution, the batch fails, and every waiter receives a typed
/// `Failed` reply naming the failure — no hang, no dropped channel — and
/// the engine stays healthy for subsequent requests.
#[test]
fn failing_batch_answers_waiters_and_engine_survives() {
    let e = engine("dense");
    let tokens = vec![1i32; SEQ_LEN];
    // Dsa { pct: 0 } parses nowhere but is constructible; the fail-closed
    // registry builds no kernel for it, so the batch execution errors.
    let err = e
        .infer(tokens.clone(), Some(Variant::Dsa { pct: 0 }))
        .expect_err("unbuildable variant batch must fail, not hang");
    assert_eq!(err.code(), "error", "execution failure must carry the error code");
    assert!(
        format!("{err}").contains("no registered kernel family"),
        "waiter must see the structured failure: {err}"
    );
    // The engine keeps serving.
    assert!(e.infer(tokens, None).is_ok());
}

#[test]
fn wrong_length_rejected_at_submit() {
    let e = engine("dense");
    let err = e
        .submit(vec![1i32; SEQ_LEN - 1], None, None)
        .map(|_| ())
        .expect_err("short request");
    assert_eq!(err.code(), "invalid");
}

/// The worker-thread preload-failure path still reports synchronously at
/// startup: a representable-but-invalid variant (`Dsa { pct: 0 }` — the
/// fail-closed registry builds no kernel for it) makes
/// `Engine::start_native` return an error instead of hanging or serving.
#[test]
fn failing_preload_fails_engine_startup() {
    let r = Engine::start_native(
        NativeModelConfig {
            seq_len: SEQ_LEN,
            ..Default::default()
        },
        EngineConfig {
            default_variant: Variant::Dsa { pct: 0 },
            ..Default::default()
        },
    );
    let err = r.expect_err("preload of an unbuildable variant must fail startup");
    assert!(
        format!("{err:#}").contains("preload"),
        "startup error must point at the preload stage"
    );
}

/// A typo'd router rung fails engine startup: `AdaptiveRouter::from_pairs`
/// validates every rung via `Variant::from_str` at construction, so the
/// ladder is rejected before a worker thread ever exists.
#[test]
fn typoed_router_rung_fails_before_startup() {
    let ladder = AdaptiveRouter::from_pairs(&[("dense", 0), ("dsaXL", 8)], 1);
    assert!(ladder.is_err(), "typo'd rung must fail ladder construction");
    // And a valid ladder built from the same API starts fine.
    let router = AdaptiveRouter::from_pairs(&[("dense", 0), ("dsa90", 8)], 1).unwrap();
    let e = Engine::start_native(
        NativeModelConfig {
            seq_len: SEQ_LEN,
            ..Default::default()
        },
        EngineConfig {
            default_variant: Variant::Dense,
            router: Some(router),
            ..Default::default()
        },
    )
    .expect("valid ladder starts");
    assert!(e.infer(vec![1i32; SEQ_LEN], None).is_ok());
}

/// The engine worker drives `AdaptiveRouter::select` from live queue
/// depth: a burst of default-variant requests escalates later batches to
/// the sparse rung, the final (empty-backlog) batch de-escalates back to
/// dense, and every decision is visible in the metrics JSON alongside
/// the worker-pool counters.
#[test]
fn adaptive_router_routes_under_load_and_reports() {
    let engine = Engine::start_native(
        NativeModelConfig {
            seq_len: SEQ_LEN,
            ..Default::default()
        },
        EngineConfig {
            default_variant: Variant::Dense,
            policy: BatchPolicy {
                max_batch: 4,
                // Generous deadline: the whole burst is enqueued long
                // before the first deadline-driven cut could fire, so
                // later batches deterministically observe a backlog.
                max_wait: Duration::from_millis(50),
                queue_cap: 128,
                default_deadline: None,
            },
            preload: true,
            // Built from config-style pairs: the from_pairs satellite's
            // validated construction, exercised end to end.
            router: Some(
                AdaptiveRouter::from_pairs(&[("dense", 0), ("dsa90", 2)], 0)
                    .expect("valid ladder"),
            ),
            sessions: SessionPolicy::default(),
        },
    )
    .expect("native engine with router");

    let mut wl = Workload::new(WorkloadConfig {
        seq_len: SEQ_LEN,
        seed: 7,
        ..Default::default()
    });
    let trace = wl.trace(33);
    let mut rxs = Vec::new();
    for r in trace {
        rxs.push(engine.submit(r.tokens, None, None).expect("submit"));
    }
    let mut variants: Vec<Variant> = Vec::new();
    for rx in rxs {
        variants.push(rx.recv().expect("channel").expect("served").variant);
    }
    let (dense, dsa90) = (Variant::Dense, Variant::Dsa { pct: 90 });
    assert!(
        variants.iter().all(|&v| v == dense || v == dsa90),
        "router must only serve ladder rungs, got {variants:?}"
    );
    assert!(
        variants.iter().any(|&v| v == dsa90),
        "burst backlog must escalate at least one batch to dsa90"
    );
    // The last batch leaves an empty queue, so the ladder ends de-escalated.
    assert_eq!(variants.last(), Some(&dense));

    let m = engine.metrics.to_json();
    let router = m.get("router").expect("router section in metrics");
    assert_eq!(router.get("rung").and_then(|r| r.as_str()), Some("dense"));
    let routed = router.get("routed_batches").expect("routed_batches");
    let count = |v: &str| routed.get(v).and_then(|x| x.as_f64()).unwrap_or(0.0);
    let batches = m.get("batches").and_then(|b| b.as_f64()).expect("batches");
    assert!(count("dsa90") >= 1.0, "metrics must record the escalation");
    assert_eq!(
        count("dense") + count("dsa90"),
        batches,
        "every batch decision must be recorded"
    );
    let pool = m.get("pool").expect("pool section in metrics");
    assert!(pool.get("workers").and_then(|w| w.as_f64()).unwrap_or(0.0) >= 1.0);
}

#[test]
fn server_protocol_roundtrip() {
    let engine = Arc::new(engine("dsa90"));
    let (mut c, state) = conn(&engine);

    let pong = c.handle_line(r#"{"op":"ping"}"#).unwrap();
    assert_eq!(pong.get("pong"), Some(&Json::Bool(true)));

    let mut wl = Workload::new(WorkloadConfig {
        seq_len: SEQ_LEN,
        seed: 12,
        ..Default::default()
    });
    let r = wl.next_request();
    let toks: Vec<String> = r.tokens.iter().map(|t| t.to_string()).collect();
    let line = format!(r#"{{"op":"infer","tokens":[{}]}}"#, toks.join(","));
    let resp = c.handle_line(&line).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    assert!(resp.get("pred").is_some());
    assert_eq!(
        resp.get("variant").and_then(|v| v.as_str()),
        Some("dsa90")
    );

    let metrics = c.handle_line(r#"{"op":"metrics"}"#).unwrap();
    assert!(
        metrics
            .get("completed")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0)
            >= 1.0
    );
    // Worker-pool counters ride along in the stats response once a batch
    // has executed; no router section without a configured router. The
    // overload section is always present (all zeroes on a healthy run).
    assert!(metrics.get("pool").is_some(), "pool stats in server metrics");
    assert!(metrics.get("router").is_none());
    let overload = metrics.get("overload").expect("overload section in metrics");
    assert_eq!(overload.get("shed").and_then(|v| v.as_f64()), Some(0.0));
    assert_eq!(overload.get("quota_rejected").and_then(|v| v.as_f64()), Some(0.0));

    // malformed input → structured error, no panic
    assert!(c.handle_line("{nope").is_err());

    // unknown op → error, engine still up
    assert!(c.handle_line(r#"{"op":"frobnicate"}"#).is_err());

    let bye = c.handle_line(r#"{"op":"shutdown"}"#).unwrap();
    assert_eq!(bye.get("stopping"), Some(&Json::Bool(true)));
    assert!(state.stopping(), "shutdown op must flip the server stop flag");
    assert!(!engine.accepting(), "shutdown op must stop engine admissions");
    // Requests after shutdown get the structured shutting_down reply.
    let refused = c.handle_line(&line).unwrap();
    assert_eq!(refused.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(
        refused.get("error").and_then(|v| v.as_str()),
        Some("shutting_down")
    );
}

fn join_tokens(v: &[i32]) -> String {
    v.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",")
}

/// Streamed decode over the wire equals one-shot inference: `open` a
/// session at a prompt prefix, stream the tail one `{"op":"decode"}` at a
/// time, and the final step's logits/pred — JSON-serialized both ways —
/// must match the one-shot `{"op":"infer"}` reply for the full sequence
/// exactly (same engine, same kernels, dense = bitwise).
#[test]
fn session_protocol_decode_matches_one_shot() {
    let engine = Arc::new(engine("dense"));
    let (mut c, _state) = conn(&engine);
    let mut wl = Workload::new(WorkloadConfig {
        seq_len: SEQ_LEN,
        seed: 21,
        ..Default::default()
    });
    let s = wl.next_session(192);
    let opened = c
        .handle_line(&format!(
            r#"{{"op":"open","tokens":[{}]}}"#,
            join_tokens(&s.prompt)
        ))
        .expect("open");
    assert_eq!(opened.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(opened.get("resident").and_then(|v| v.as_f64()), Some(192.0));
    assert_eq!(opened.get("variant").and_then(|v| v.as_str()), Some("dense"));
    let sid = opened.get("session").and_then(|v| v.as_f64()).expect("session id") as u64;

    let mut last = None;
    for (i, &t) in s.steps.iter().enumerate() {
        let reply = c
            .handle_line(&format!(
                r#"{{"op":"decode","session":{sid},"token":{t}}}"#
            ))
            .expect("decode");
        assert_eq!(reply.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(
            reply.get("resident").and_then(|v| v.as_f64()),
            Some((192 + i + 1) as f64),
            "each decode step appends exactly one cached token"
        );
        last = Some(reply);
    }
    let last = last.expect("session has decode steps");

    let mut full = s.prompt.clone();
    full.extend_from_slice(&s.steps);
    let one_shot = c
        .handle_line(&format!(
            r#"{{"op":"infer","tokens":[{}]}}"#,
            join_tokens(&full)
        ))
        .expect("infer");
    let logits = |j: &Json| -> Vec<f64> {
        j.get("logits")
            .and_then(|l| l.as_arr())
            .expect("logits array")
            .iter()
            .filter_map(|v| v.as_f64())
            .collect()
    };
    assert_eq!(
        logits(&last),
        logits(&one_shot),
        "streamed decode must equal one-shot inference"
    );
    assert_eq!(
        last.get("pred").and_then(|v| v.as_f64()),
        one_shot.get("pred").and_then(|v| v.as_f64())
    );

    let closed = c
        .handle_line(&format!(r#"{{"op":"close","session":{sid}}}"#))
        .expect("close");
    assert_eq!(closed.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(
        closed.get("released").and_then(|v| v.as_f64()),
        Some(SEQ_LEN as f64)
    );
}

/// The DSA rungs stream through the same session path: the final decode
/// step's logits equal the one-shot logits bitwise (both paths run the
/// same kernels through the same per-shape tile plan), so sparse serving
/// loses nothing to the incremental cache.
#[test]
fn dsa90_session_decode_matches_one_shot() {
    let e = engine("dsa90");
    let mut wl = Workload::new(WorkloadConfig {
        seq_len: SEQ_LEN,
        seed: 22,
        ..Default::default()
    });
    let s = wl.next_session(128);
    let (sid, resident, variant) = e.open_session(s.prompt.clone(), None).expect("open");
    assert_eq!((resident, variant), (128, Variant::Dsa { pct: 90 }));
    let mut last = None;
    for &t in &s.steps {
        last = Some(e.decode(sid, t).expect("decode"));
    }
    let resp = last.expect("session has decode steps");
    let mut full = s.prompt.clone();
    full.extend_from_slice(&s.steps);
    let one_shot = e.infer(full, None).expect("infer");
    assert_eq!(
        resp.logits, one_shot.logits,
        "dsa90 streamed decode must equal one-shot inference bitwise"
    );
    assert_eq!(resp.pred, one_shot.pred);
    assert_eq!(e.close_session(sid).expect("close"), SEQ_LEN);
}

/// The session table is LRU-bounded by [`SessionPolicy`]: opening past
/// `max_sessions` evicts the least-recently-used stream, whose next
/// `decode` gets a structured error (not a hang or a wrong answer), the
/// survivors keep decoding, and the eviction is visible in metrics.
#[test]
fn session_cap_evicts_lru_with_structured_error() {
    let e = Engine::start_native(
        NativeModelConfig {
            seq_len: SEQ_LEN,
            ..Default::default()
        },
        EngineConfig {
            default_variant: Variant::Dense,
            sessions: SessionPolicy { max_sessions: 2 },
            ..Default::default()
        },
    )
    .expect("native engine");
    let mut wl = Workload::new(WorkloadConfig {
        seq_len: SEQ_LEN,
        seed: 31,
        ..Default::default()
    });
    let mut ids = Vec::new();
    for _ in 0..3 {
        let s = wl.next_session(64);
        ids.push(e.open_session(s.prompt, None).expect("open").0);
    }
    // The third open evicted the least-recently-used first session.
    let err = e.decode(ids[0], 7).expect_err("evicted session must error");
    assert!(
        format!("{err:#}").contains("unknown session"),
        "eviction must surface as a structured unknown-session error: {err:#}"
    );
    assert!(e.decode(ids[1], 7).is_ok(), "survivor must keep decoding");
    assert!(e.decode(ids[2], 7).is_ok(), "survivor must keep decoding");
    let m = e.metrics.to_json();
    let sess = m.get("sessions").expect("sessions section in metrics");
    assert_eq!(sess.get("evicted").and_then(|v| v.as_f64()), Some(1.0));
    assert_eq!(sess.get("active").and_then(|v| v.as_f64()), Some(2.0));
}

/// Close returns a session's cache to the backend pool; a reopened
/// same-shape session reuses it without growing — observable end to end
/// through the `sessions.cache_grows` metrics gauge staying flat across
/// churn.
#[test]
fn closed_session_caches_are_recycled_without_regrowth() {
    let e = engine("dsa90");
    let mut wl = Workload::new(WorkloadConfig {
        seq_len: SEQ_LEN,
        seed: 41,
        ..Default::default()
    });
    let run = |s: &GenSession| {
        let (sid, ..) = e.open_session(s.prompt.clone(), None).expect("open");
        for &t in &s.steps {
            e.decode(sid, t).expect("decode");
        }
        e.close_session(sid).expect("close");
    };
    let grows = |e: &Engine| {
        e.metrics
            .to_json()
            .get("sessions")
            .and_then(|s| s.get("cache_grows"))
            .and_then(|v| v.as_f64())
            .expect("cache_grows gauge")
    };
    run(&wl.next_session(192));
    let cold = grows(&e);
    assert!(cold >= 1.0, "first session must grow its cache, got {cold}");
    run(&wl.next_session(192));
    assert_eq!(grows(&e), cold, "recycled cache must not regrow");
}

/// Malformed or stale session requests die at the protocol boundary as
/// structured errors — never dropped connections or panics — and the
/// engine keeps serving afterwards.
#[test]
fn session_protocol_errors_are_structured() {
    let engine = Arc::new(engine("dense"));
    let (mut c, _state) = conn(&engine);
    // Engine-side rejections come back as structured replies with a
    // machine-readable code, not dropped connections or panics.
    let reply = c
        .handle_line(r#"{"op":"decode","session":999,"token":1}"#)
        .expect("never-opened session gets a structured reply");
    assert_eq!(reply.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(reply.get("error").and_then(|v| v.as_str()), Some("error"));
    assert!(
        reply
            .get("message")
            .and_then(|v| v.as_str())
            .is_some_and(|m| m.contains("unknown session")),
        "reply must name the stale session: {reply:?}"
    );
    // Requests malformed at the protocol boundary fail before reaching
    // the engine; the connection loop renders these as `invalid`.
    let err = c
        .handle_line(r#"{"op":"decode","session":1}"#)
        .expect_err("decode without token");
    assert!(format!("{err:#}").contains("missing token"), "{err:#}");
    let err = c
        .handle_line(r#"{"op":"close"}"#)
        .expect_err("close without session id");
    assert!(format!("{err:#}").contains("missing session"), "{err:#}");
    // An over-length prompt dies at the submit boundary, before the
    // worker or the backend ever see it — structured `invalid` reply.
    let toks = join_tokens(&[1i32; SEQ_LEN + 1]);
    let reply = c
        .handle_line(&format!(r#"{{"op":"open","tokens":[{toks}]}}"#))
        .expect("over-length prompt gets a structured reply");
    assert_eq!(reply.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(reply.get("error").and_then(|v| v.as_str()), Some("invalid"));
    assert!(
        reply
            .get("message")
            .and_then(|v| v.as_str())
            .is_some_and(|m| m.contains("out of range")),
        "{reply:?}"
    );
    // The engine never saw a broken session op and keeps serving.
    assert!(engine.infer(vec![1i32; SEQ_LEN], None).is_ok());
}

/// `deadline_ms` is validated at the protocol boundary: non-numeric or
/// non-positive values are rejected with a parse error before the engine
/// sees the request, while a sane numeric budget flows through to a
/// successful reply.
#[test]
fn deadline_ms_validated_at_protocol_boundary() {
    let engine = Arc::new(engine("dense"));
    let (mut c, _state) = conn(&engine);
    let toks = join_tokens(&[1i32; SEQ_LEN]);
    let err = c
        .handle_line(&format!(
            r#"{{"op":"infer","tokens":[{toks}],"deadline_ms":"soon"}}"#
        ))
        .expect_err("non-numeric deadline");
    assert!(format!("{err:#}").contains("deadline_ms"), "{err:#}");
    let err = c
        .handle_line(&format!(
            r#"{{"op":"infer","tokens":[{toks}],"deadline_ms":-5}}"#
        ))
        .expect_err("negative deadline");
    assert!(format!("{err:#}").contains("positive"), "{err:#}");
    // A generous budget is clamped and honored: the request serves fine.
    let reply = c
        .handle_line(&format!(
            r#"{{"op":"infer","tokens":[{toks}],"deadline_ms":60000}}"#
        ))
        .expect("valid deadline");
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)));
    // `null` means "no deadline", same as omitting the field.
    let reply = c
        .handle_line(&format!(
            r#"{{"op":"infer","tokens":[{toks}],"deadline_ms":null}}"#
        ))
        .expect("null deadline");
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)));
}

/// Per-connection quotas reject over-limit work with structured
/// `quota_exceeded` replies — a token bucket for request rate and a hard
/// cap on concurrently open sessions — and every rejection is counted.
#[test]
fn per_connection_quotas_reject_with_structured_replies() {
    let engine = Arc::new(engine("dense"));
    let toks = join_tokens(&[1i32; SEQ_LEN]);

    // Request-rate bucket: burst of 2 with a refill rate slow enough that
    // the bucket cannot recover mid-test, so the third request bounces.
    let state = Arc::new(ServerState::new());
    let mut c = Conn::new(
        engine.clone(),
        state,
        QuotaConfig {
            rps: 0.001,
            burst: 2.0,
            max_sessions: 0,
        },
    );
    let line = format!(r#"{{"op":"infer","tokens":[{toks}]}}"#);
    for _ in 0..2 {
        let reply = c.handle_line(&line).expect("within burst");
        assert_eq!(reply.get("ok"), Some(&Json::Bool(true)));
    }
    let reply = c.handle_line(&line).expect("structured quota rejection");
    assert_eq!(reply.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(
        reply.get("error").and_then(|v| v.as_str()),
        Some("quota_exceeded")
    );
    assert!(reply.get("limit").is_some(), "rejection carries the limit");
    assert_eq!(engine.metrics.quota_rejected(), 1);

    // Open-session cap: a second concurrent open on the same connection
    // is rejected, and closing the first frees the slot.
    let state = Arc::new(ServerState::new());
    let mut c = Conn::new(
        engine.clone(),
        state,
        QuotaConfig {
            rps: 0.0,
            burst: 8.0,
            max_sessions: 1,
        },
    );
    let open = format!(r#"{{"op":"open","tokens":[{toks}]}}"#);
    let first = c.handle_line(&open).expect("first open");
    assert_eq!(first.get("ok"), Some(&Json::Bool(true)));
    let sid = first.get("session").and_then(|v| v.as_f64()).expect("session id") as u64;
    let reply = c.handle_line(&open).expect("structured session-cap rejection");
    assert_eq!(
        reply.get("error").and_then(|v| v.as_str()),
        Some("quota_exceeded")
    );
    let closed = c
        .handle_line(&format!(r#"{{"op":"close","session":{sid}}}"#))
        .expect("close");
    assert_eq!(closed.get("ok"), Some(&Json::Bool(true)));
    let reopened = c.handle_line(&open).expect("reopen after close");
    assert_eq!(
        reopened.get("ok"),
        Some(&Json::Bool(true)),
        "closing a session must free its quota slot: {reopened:?}"
    );
}

/// Abnormal disconnect: a client that vanishes without closing its
/// sessions must leak neither engine-side cache nor quota slots. The
/// server's connection loop runs [`Conn::release_abandoned`] on the way
/// out; here it's driven directly against an engine capped at 2 live
/// sessions — if cleanup leaked, the reconnect's opens would evict the
/// stale pair instead of landing in free slots.
#[test]
fn abnormal_disconnect_releases_sessions_and_quota_slots() {
    let engine = Arc::new(
        Engine::start_native(
            NativeModelConfig { seq_len: SEQ_LEN, ..Default::default() },
            EngineConfig {
                default_variant: Variant::Dense,
                policy: BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(2),
                    queue_cap: 128,
                    default_deadline: None,
                },
                preload: true,
                router: None,
                sessions: SessionPolicy { max_sessions: 2 },
            },
        )
        .expect("native engine"),
    );
    let toks = join_tokens(&[1i32; SEQ_LEN]);
    let open = format!(r#"{{"op":"open","tokens":[{toks}]}}"#);
    let quota = || QuotaConfig { rps: 0.0, burst: 32.0, max_sessions: 2 };

    let mut c = Conn::new(engine.clone(), Arc::new(ServerState::new()), quota());
    for _ in 0..2 {
        let reply = c.handle_line(&open).expect("open within quota");
        assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply:?}");
    }
    let reply = c.handle_line(&open).expect("structured cap rejection");
    assert_eq!(
        reply.get("error").and_then(|v| v.as_str()),
        Some("quota_exceeded")
    );

    // The client vanishes mid-session: disconnect cleanup closes
    // everything the connection still held (idempotently).
    c.release_abandoned();
    c.release_abandoned();
    drop(c);
    let sessions = engine.metrics.to_json();
    let sessions = sessions.get("sessions").expect("sessions section");
    assert_eq!(
        sessions.get("active").and_then(|v| v.as_f64()),
        Some(0.0),
        "abandoned sessions must be closed engine-side"
    );

    // A reconnect gets a fresh quota and truly free engine slots.
    let mut c = Conn::new(engine.clone(), Arc::new(ServerState::new()), quota());
    for _ in 0..2 {
        let reply = c.handle_line(&open).expect("reopen after disconnect");
        assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply:?}");
    }
    let sessions = engine.metrics.to_json();
    let sessions = sessions.get("sessions").expect("sessions section");
    assert_eq!(
        sessions.get("evicted").and_then(|v| v.as_f64()),
        Some(0.0),
        "released slots must be reused without LRU eviction"
    );
    c.release_abandoned();
}

/// The idle-timeout satellite, over a real socket: a connection that
/// completes no request line within the limit gets one final structured
/// `{"ok":false,"error":"timeout"}` reply, then the server closes it and
/// disconnect cleanup releases the sessions it abandoned.
#[test]
fn idle_connections_time_out_with_a_structured_reply() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::{TcpListener, TcpStream};

    use dsa_serve::server::{serve_listener, ServerConfig};
    use dsa_serve::util::json;

    let engine = Arc::new(engine("dense"));
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    let srv = {
        let engine = engine.clone();
        std::thread::spawn(move || {
            serve_listener(
                engine,
                listener,
                ServerConfig {
                    quota: QuotaConfig::default(),
                    idle_timeout: Some(Duration::from_millis(300)),
                },
            )
            .expect("serve_listener")
        })
    };

    let stream = TcpStream::connect(addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);

    // An active request works normally (and resets the idle clock).
    let toks = join_tokens(&[1i32; SEQ_LEN]);
    writeln!(writer, r#"{{"op":"open","tokens":[{toks}]}}"#).expect("send open");
    let mut line = String::new();
    reader.read_line(&mut line).expect("open reply");
    let reply = json::parse(&line).expect("reply json");
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply:?}");

    // Then silence: the next bytes on the wire are the final timeout
    // reply, followed by EOF.
    line.clear();
    reader.read_line(&mut line).expect("timeout reply");
    let reply = json::parse(&line).expect("timeout json");
    assert_eq!(
        reply.get("error").and_then(|v| v.as_str()),
        Some("timeout"),
        "{reply:?}"
    );
    assert_eq!(reply.get("ok"), Some(&Json::Bool(false)));
    line.clear();
    assert_eq!(
        reader.read_line(&mut line).expect("eof"),
        0,
        "server must close the connection after the timeout reply"
    );

    // Disconnect cleanup ran: the abandoned session is closed
    // engine-side (the connection thread finishes asynchronously).
    let t0 = std::time::Instant::now();
    loop {
        let m = engine.metrics.to_json();
        let active = m
            .get("sessions")
            .and_then(|s| s.get("active"))
            .and_then(|v| v.as_f64());
        if active == Some(0.0) {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "abandoned session not released: active={active:?}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    // A second client shuts the server down cleanly.
    let stream = TcpStream::connect(addr).expect("connect 2");
    let mut writer = stream.try_clone().expect("clone 2");
    let mut reader = BufReader::new(stream);
    writeln!(writer, r#"{{"op":"shutdown"}}"#).expect("send shutdown");
    line.clear();
    reader.read_line(&mut line).expect("shutdown reply");
    drop(writer);
    drop(reader);
    srv.join().expect("server thread");
}
