//! Hermetic end-to-end tests: the native-kernel backend through the full
//! serving stack — engine worker, dynamic batcher, metrics and the server
//! protocol — with no artifacts, no PJRT and no external crates. This is
//! the coverage `cargo test -q` provides on a fresh checkout.

use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

use dsa_serve::coordinator::{
    AdaptiveRouter, BatchPolicy, Engine, EngineConfig, NativeModelConfig, Rung,
};
use dsa_serve::server;
use dsa_serve::util::json::Json;
use dsa_serve::workload::{Workload, WorkloadConfig};

const SEQ_LEN: usize = 256;

fn engine(variant: &str) -> Engine {
    Engine::start_native(
        NativeModelConfig {
            seq_len: SEQ_LEN,
            ..Default::default()
        },
        EngineConfig {
            default_variant: variant.to_string(),
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
                queue_cap: 128,
            },
            preload: true,
            router: None,
        },
    )
    .expect("native engine")
}

/// Serve a burst of requests; the hand-constructed classifier must solve
/// the task through both the dense and the dynamic-sparse kernels, and the
/// dynamic batcher must actually batch.
fn serve_and_score(variant: &str, n: usize) -> (usize, f64) {
    let engine = engine(variant);
    let mut wl = Workload::new(WorkloadConfig {
        seq_len: SEQ_LEN,
        seed: 99,
        ..Default::default()
    });
    let trace = wl.trace(n);
    let mut rxs = Vec::new();
    let mut labels = Vec::new();
    for r in trace {
        labels.push(r.label);
        rxs.push(engine.submit(r.tokens, None).expect("submit"));
    }
    let mut correct = 0;
    for (rx, label) in rxs.into_iter().zip(labels) {
        let resp = rx.recv().expect("response");
        assert_eq!(resp.logits.len(), engine.classes());
        assert!(resp.latency > Duration::ZERO);
        assert_eq!(resp.variant, variant);
        if resp.pred as i32 == label {
            correct += 1;
        }
    }
    let occ = engine
        .metrics
        .to_json()
        .get("mean_occupancy")
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0);
    (correct, occ)
}

#[test]
fn dense_engine_solves_task_and_batches() {
    let n = 32;
    let (correct, occ) = serve_and_score("dense", n);
    assert!(correct >= 29, "dense accuracy too low: {correct}/{n}");
    assert!(occ > 1.0, "expected batching, mean occupancy {occ}");
}

#[test]
fn dsa90_engine_solves_task() {
    let n = 32;
    let (correct, _) = serve_and_score("dsa90", n);
    assert!(correct >= 28, "dsa90 accuracy too low: {correct}/{n}");
}

#[test]
fn dsa95_engine_beats_chance() {
    let n = 32;
    let (correct, _) = serve_and_score("dsa95", n);
    // 95% sparsity is near the budget where label-1 masks saturate; it
    // must still clearly beat chance (22/32 ~ 5 sigma).
    assert!(correct >= 22, "dsa95 accuracy too low: {correct}/{n}");
}

#[test]
fn variant_override_routing() {
    let e = engine("dsa90");
    let mut wl = Workload::new(WorkloadConfig {
        seq_len: SEQ_LEN,
        seed: 4,
        ..Default::default()
    });
    let r = wl.next_request();
    let resp_dense = e.infer(r.tokens.clone(), Some("dense".into())).expect("dense");
    let resp_dsa = e.infer(r.tokens, Some("dsa95".into())).expect("dsa95");
    assert_eq!(resp_dense.variant, "dense");
    assert_eq!(resp_dsa.variant, "dsa95");
}

#[test]
fn unknown_variant_fails_closed() {
    let e = engine("dense");
    let tokens = vec![1i32; SEQ_LEN];
    // The batch execution fails; the waiter channel is dropped and infer
    // surfaces an error instead of hanging or panicking.
    assert!(e.infer(tokens.clone(), Some("bogus".into())).is_err());
    // The engine stays healthy for subsequent requests.
    assert!(e.infer(tokens, None).is_ok());
}

#[test]
fn wrong_length_rejected_at_submit() {
    let e = engine("dense");
    assert!(e.submit(vec![1i32; SEQ_LEN - 1], None).is_err());
}

#[test]
fn unknown_default_variant_fails_startup() {
    let r = Engine::start_native(
        NativeModelConfig::default(),
        EngineConfig {
            default_variant: "dsaXL".into(),
            ..Default::default()
        },
    );
    assert!(r.is_err(), "preload of unknown variant must fail startup");
}

/// The engine worker drives `AdaptiveRouter::select` from live queue
/// depth: a burst of default-variant requests escalates later batches to
/// the sparse rung, the final (empty-backlog) batch de-escalates back to
/// dense, and every decision is visible in the metrics JSON alongside
/// the worker-pool counters.
#[test]
fn adaptive_router_routes_under_load_and_reports() {
    let engine = Engine::start_native(
        NativeModelConfig {
            seq_len: SEQ_LEN,
            ..Default::default()
        },
        EngineConfig {
            default_variant: "dense".to_string(),
            policy: BatchPolicy {
                max_batch: 4,
                // Generous deadline: the whole burst is enqueued long
                // before the first deadline-driven cut could fire, so
                // later batches deterministically observe a backlog.
                max_wait: Duration::from_millis(50),
                queue_cap: 128,
            },
            preload: true,
            router: Some(AdaptiveRouter::new(
                vec![
                    Rung { variant: "dense".into(), min_queue: 0 },
                    Rung { variant: "dsa90".into(), min_queue: 2 },
                ],
                0,
            )),
        },
    )
    .expect("native engine with router");

    let mut wl = Workload::new(WorkloadConfig {
        seq_len: SEQ_LEN,
        seed: 7,
        ..Default::default()
    });
    let trace = wl.trace(33);
    let mut rxs = Vec::new();
    for r in trace {
        rxs.push(engine.submit(r.tokens, None).expect("submit"));
    }
    let mut variants: Vec<String> = Vec::new();
    for rx in rxs {
        variants.push(rx.recv().expect("response").variant);
    }
    assert!(
        variants.iter().all(|v| v == "dense" || v == "dsa90"),
        "router must only serve ladder rungs, got {variants:?}"
    );
    assert!(
        variants.iter().any(|v| v == "dsa90"),
        "burst backlog must escalate at least one batch to dsa90"
    );
    // The last batch leaves an empty queue, so the ladder ends de-escalated.
    assert_eq!(variants.last().map(String::as_str), Some("dense"));

    let m = engine.metrics.to_json();
    let router = m.get("router").expect("router section in metrics");
    assert_eq!(router.get("rung").and_then(|r| r.as_str()), Some("dense"));
    let routed = router.get("routed_batches").expect("routed_batches");
    let count = |v: &str| routed.get(v).and_then(|x| x.as_f64()).unwrap_or(0.0);
    let batches = m.get("batches").and_then(|b| b.as_f64()).expect("batches");
    assert!(count("dsa90") >= 1.0, "metrics must record the escalation");
    assert_eq!(
        count("dense") + count("dsa90"),
        batches,
        "every batch decision must be recorded"
    );
    let pool = m.get("pool").expect("pool section in metrics");
    assert!(pool.get("workers").and_then(|w| w.as_f64()).unwrap_or(0.0) >= 1.0);
}

#[test]
fn server_protocol_roundtrip() {
    let engine = Arc::new(engine("dsa90"));
    let stop = AtomicBool::new(false);

    let pong = server::handle_line(r#"{"op":"ping"}"#, &engine, &stop).unwrap();
    assert_eq!(pong.get("pong"), Some(&Json::Bool(true)));

    let mut wl = Workload::new(WorkloadConfig {
        seq_len: SEQ_LEN,
        seed: 12,
        ..Default::default()
    });
    let r = wl.next_request();
    let toks: Vec<String> = r.tokens.iter().map(|t| t.to_string()).collect();
    let line = format!(r#"{{"op":"infer","tokens":[{}]}}"#, toks.join(","));
    let resp = server::handle_line(&line, &engine, &stop).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    assert!(resp.get("pred").is_some());
    assert_eq!(
        resp.get("variant").and_then(|v| v.as_str()),
        Some("dsa90")
    );

    let metrics = server::handle_line(r#"{"op":"metrics"}"#, &engine, &stop).unwrap();
    assert!(
        metrics
            .get("completed")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0)
            >= 1.0
    );
    // Worker-pool counters ride along in the stats response once a batch
    // has executed; no router section without a configured router.
    assert!(metrics.get("pool").is_some(), "pool stats in server metrics");
    assert!(metrics.get("router").is_none());

    // malformed input → structured error, no panic
    assert!(server::handle_line("{nope", &engine, &stop).is_err());

    // unknown op → error, engine still up
    assert!(server::handle_line(r#"{"op":"frobnicate"}"#, &engine, &stop).is_err());

    let bye = server::handle_line(r#"{"op":"shutdown"}"#, &engine, &stop).unwrap();
    assert_eq!(bye.get("stopping"), Some(&Json::Bool(true)));
    assert!(stop.load(std::sync::atomic::Ordering::SeqCst));
}
