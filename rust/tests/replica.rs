//! Replicated-serving tests: crash isolation, supervised respawn,
//! failover, and durable decode sessions through [`ReplicaSet`].
//!
//! The invariant under test extends the chaos suite's accounting
//! identity with the replica-death outcome —
//!
//! ```text
//! submitted == served + overloaded + expired + errored + session_lost
//! ```
//!
//! — under deterministic replica kills (`inject_crash`/`inject_wedge`)
//! and seeded chaos at the `replica.crash`/`replica.wedge` sites. Every
//! client gets exactly one structured reply (a hang fails the test by
//! timeout), accepted one-shots whose replica dies retry on a sibling
//! (`retried` counted exactly once as served), the supervisor respawns
//! killed replicas, and a respawned replica serves bit-identical logits
//! (same backend factory, same kernel registry).
//!
//! Decode sessions are *durable*: each one's journal (prompt + decoded
//! tokens) lives in the replica-independent route table, and a session
//! whose replica dies is rebuilt on a sibling by replaying the journal
//! — bitwise-identical logits afterwards, by the same determinism the
//! respawn tests pin. `session_lost` is reserved for *exhausted*
//! migrations (replay budget, no sibling, memory pressure), exercised
//! here with `replay_budget_tokens: 0`, which restores the old
//! lazy-loss behaviour.

use std::sync::Arc;
use std::time::{Duration, Instant};

use dsa_serve::coordinator::{
    BatchPolicy, EngineConfig, NativeModelConfig, ReplicaConfig, ReplicaSet, ServeError,
    SessionPolicy,
};
use dsa_serve::kernels::Variant;
use dsa_serve::server::{Conn, QuotaConfig, ServerState};
use dsa_serve::util::faults::{FaultConfig, FaultInjector};
use dsa_serve::util::prop::{forall, Config as PropConfig};
use dsa_serve::workload::{Workload, WorkloadConfig};

const SEQ_LEN: usize = 64;

/// One structured outcome per submission, keyed by wire code. `total()`
/// must equal the number of submissions — the extended identity.
#[derive(Debug, Default)]
struct Tally {
    served: usize,
    overloaded: usize,
    expired: usize,
    errored: usize,
    session_lost: usize,
}

impl Tally {
    fn count_err(&mut self, e: &ServeError) {
        match e.code() {
            "overloaded" => self.overloaded += 1,
            "expired" => self.expired += 1,
            "session_lost" => self.session_lost += 1,
            _ => self.errored += 1,
        }
    }

    fn total(&self) -> usize {
        self.served + self.overloaded + self.expired + self.errored + self.session_lost
    }
}

fn engine_cfg() -> EngineConfig {
    EngineConfig {
        default_variant: Variant::Dense,
        policy: BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_cap: 256,
            default_deadline: None,
        },
        preload: true,
        router: None,
        sessions: SessionPolicy { max_sessions: 8 },
    }
}

/// A replica set with a fast watchdog so respawn tests stay quick.
/// Migration is on at the default replay budget — ample for `SEQ_LEN`.
fn set(replicas: usize) -> ReplicaSet {
    set_with(ReplicaConfig {
        replicas,
        watchdog: Duration::from_millis(150),
        ..Default::default()
    })
}

/// A replica set with full control over the replication policy.
fn set_with(cfg: ReplicaConfig) -> ReplicaSet {
    ReplicaSet::start_native(
        NativeModelConfig { seq_len: SEQ_LEN, ..Default::default() },
        engine_cfg(),
        cfg,
    )
    .expect("replica set boots")
}

fn workload(seed: u64) -> Workload {
    Workload::new(WorkloadConfig { seq_len: SEQ_LEN, seed, ..Default::default() })
}

/// Poll `cond` until it holds or `timeout` elapses.
fn wait_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < timeout {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    cond()
}

/// Infer with bounded retries across the respawn window (transient
/// `overloaded` refusals while no replica is healthy are expected).
fn infer_eventually(set: &ReplicaSet, tokens: Vec<i32>) -> Vec<f32> {
    let t0 = Instant::now();
    loop {
        match set.infer(tokens.clone(), None) {
            Ok(resp) => return resp.logits,
            Err(_) if t0.elapsed() < Duration::from_secs(5) => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => panic!("replica set never recovered: {e}"),
        }
    }
}

/// The tentpole: kill a replica under a pipelined one-shot burst. The
/// extended identity holds, at least one accepted request fails over to
/// a sibling (counted `retried`, served exactly once), the supervisor
/// respawns the corpse back to full strength, and the respawned replica
/// serves bit-identical logits.
#[test]
fn replica_kill_mid_traffic_fails_over_and_respawns() {
    let set = set(3);
    let reference = set
        .infer(vec![1i32; SEQ_LEN], None)
        .expect("healthy set serves")
        .logits;

    let mut wl = workload(7);
    let n = 60;
    let mut tally = Tally::default();
    let mut pending = Vec::new();
    for _ in 0..n {
        match set.submit(wl.next_request().tokens, None, None) {
            Ok(p) => pending.push(p),
            Err(e) => tally.count_err(&e),
        }
    }
    // Kill replica 0 with roughly a third of the burst parked on it; its
    // reply channels drop and `wait` retries each on a sibling.
    set.inject_crash(0);
    for p in pending {
        match p.wait() {
            Ok(_) => tally.served += 1,
            Err(e) => tally.count_err(&e),
        }
    }

    assert_eq!(tally.total(), n, "extended accounting identity violated: {tally:?}");
    assert!(tally.served > 0, "siblings must keep serving through the kill: {tally:?}");
    let m = set.metrics();
    assert!(m.retried() >= 1, "at least one accepted request must fail over");
    assert!(
        m.retried() as usize <= tally.served,
        "a retried request is served exactly once (retried {} vs served {})",
        m.retried(),
        tally.served
    );

    // Supervisor: crash detected, corpse torn down, fresh replica up.
    assert!(
        wait_until(Duration::from_secs(5), || set.alive_replicas() == 3),
        "supervisor must respawn back to 3 replicas"
    );
    assert!(
        wait_until(Duration::from_secs(5), || {
            m.replica_crashes() >= 1 && m.replica_respawns() >= 1 && m.replicas_alive() == 3
        }),
        "replica metrics must record the crash, the respawn, and full strength"
    );

    // Same factory, same registry: every slot (the respawn included, via
    // round-robin) serves bit-identical logits for the same tokens.
    for _ in 0..6 {
        let logits = infer_eventually(&set, vec![1i32; SEQ_LEN]);
        assert_eq!(logits, reference, "respawned replica must serve bit-identical logits");
    }
    set.shutdown();
}

/// A wedged replica (alive thread, dead heartbeat) trips the watchdog:
/// torn down, counted as a crash, respawned, and the set keeps serving.
#[test]
fn wedged_replica_trips_the_watchdog_and_respawns() {
    let set = set(2);
    set.inject_wedge(0);
    let m = set.metrics();
    assert!(
        wait_until(Duration::from_secs(5), || m.replica_crashes() >= 1),
        "watchdog must flag the silent replica"
    );
    assert!(
        wait_until(Duration::from_secs(5), || {
            m.replica_respawns() >= 1 && set.alive_replicas() == 2
        }),
        "wedged replica must be torn down and respawned"
    );
    infer_eventually(&set, vec![1i32; SEQ_LEN]);
    set.shutdown();
}

/// With a single replica there is no failover sibling: a kill answers
/// every parked client with a structured error (never a hang, never a
/// `retried` count), and the supervisor still restores service.
#[test]
fn single_replica_death_answers_every_client_without_retries() {
    let set = set(1);
    let mut wl = workload(11);
    let n = 24;
    let mut tally = Tally::default();
    let mut pending = Vec::new();
    for _ in 0..n {
        match set.submit(wl.next_request().tokens, None, None) {
            Ok(p) => pending.push(p),
            Err(e) => tally.count_err(&e),
        }
    }
    set.inject_crash(0);
    for p in pending {
        match p.wait() {
            Ok(_) => tally.served += 1,
            Err(e) => tally.count_err(&e),
        }
    }
    assert_eq!(tally.total(), n, "identity must hold with no sibling: {tally:?}");
    assert_eq!(set.metrics().retried(), 0, "nothing to retry onto — retried must stay 0");
    assert!(
        wait_until(Duration::from_secs(5), || set.alive_replicas() == 1),
        "supervisor must respawn the only replica"
    );
    infer_eventually(&set, vec![1i32; SEQ_LEN]);
    set.shutdown();
}

/// With migration disabled (`replay_budget_tokens: 0` — every journal
/// exceeds the budget), sessions die with their replica as structured
/// `session_lost` replies carrying the session id: the exhausted-budget
/// path, counted under both `session_lost` and `migration_failed`. The
/// global route is freed (a second op on the same id is an ordinary
/// unknown-session error), a close on a dead route still succeeds
/// locally off the journal, and reopening on the respawned replicas
/// works.
#[test]
fn session_death_converts_to_structured_session_lost() {
    let set = set_with(ReplicaConfig {
        replicas: 2,
        watchdog: Duration::from_millis(150),
        replay_budget_tokens: 0,
        ..Default::default()
    });
    let mut wl = workload(13);
    let (sid1, _, _) = set
        .open_session(wl.next_session(SEQ_LEN / 2).prompt, None)
        .expect("open 1");
    let (sid2, _, _) = set
        .open_session(wl.next_session(SEQ_LEN / 2).prompt, None)
        .expect("open 2");
    assert_ne!(sid1, sid2, "global session ids must be distinct across replicas");
    let (closer, _, _) = set
        .open_session(wl.next_session(SEQ_LEN / 2).prompt, None)
        .expect("open 3");

    set.inject_crash(0);
    set.inject_crash(1);
    assert!(
        wait_until(Duration::from_secs(5), || set.alive_replicas() == 2),
        "both replicas must respawn"
    );

    for sid in [sid1, sid2] {
        match set.decode(sid, 3) {
            Err(ServeError::SessionLost { session }) => {
                assert_eq!(session, sid, "session_lost must name the lost session");
            }
            other => panic!("expected session_lost for {sid}, got {other:?}"),
        }
    }
    assert_eq!(set.metrics().session_lost(), 2);
    assert_eq!(
        set.metrics().migration_failed(),
        2,
        "budget-0 losses are exhausted migrations"
    );
    // A close on a dead route is not a loss: the client is relinquishing
    // the id anyway, so it resolves locally off the journal.
    let released = set.close_session(closer).expect("close on a dead route succeeds");
    assert_eq!(released, SEQ_LEN / 2, "released count comes from the journal");
    assert_eq!(set.metrics().session_lost(), 2, "a close never counts as a loss");
    // The route was freed with the first conversion: the id is now
    // simply unknown, not lost again.
    assert_eq!(set.decode(sid1, 3).unwrap_err().code(), "error");

    // Respawned replicas accept fresh sessions and decode.
    let (sid3, _, _) = set
        .open_session(wl.next_session(SEQ_LEN / 2).prompt, None)
        .expect("reopen on respawned replicas");
    assert!(sid3 > sid2, "global ids keep monotonically increasing");
    set.decode(sid3, 5).expect("decode on the reopened session");
    set.shutdown();
}

/// Wire-level: through a server [`Conn`], a session whose migration is
/// exhausted (budget 0 here) renders as a structured
/// `{"ok":false,"error":"session_lost"}` reply AND frees the
/// connection's quota slot — the client reopens without leaking
/// capacity.
#[test]
fn server_reply_carries_session_lost_and_frees_the_quota_slot() {
    let set = Arc::new(set_with(ReplicaConfig {
        replicas: 2,
        watchdog: Duration::from_millis(150),
        replay_budget_tokens: 0,
        ..Default::default()
    }));
    let state = Arc::new(ServerState::new());
    let mut conn = Conn::new(
        set.clone(),
        state,
        QuotaConfig { max_sessions: 1, ..Default::default() },
    );
    let tokens: Vec<String> = (0..SEQ_LEN / 2).map(|i| (i as i32 % 50).to_string()).collect();
    let open = format!(r#"{{"op":"open","tokens":[{}]}}"#, tokens.join(","));

    let reply = conn.handle_line(&open).expect("open parses");
    assert_eq!(reply.get("ok").and_then(|v| v.as_bool()), Some(true), "{reply:?}");
    let sid = reply.get("session").and_then(|v| v.as_f64()).expect("session id") as u64;

    set.inject_crash(0);
    set.inject_crash(1);
    assert!(wait_until(Duration::from_secs(5), || set.alive_replicas() == 2));

    let reply = conn
        .handle_line(&format!(r#"{{"op":"decode","session":{sid},"token":3}}"#))
        .expect("decode parses");
    assert_eq!(
        reply.get("error").and_then(|v| v.as_str()),
        Some("session_lost"),
        "{reply:?}"
    );
    assert_eq!(
        reply.get("session").and_then(|v| v.as_f64()).map(|s| s as u64),
        Some(sid),
        "the reply names the lost session"
    );

    // The quota slot (max_sessions = 1) came back with the loss: a fresh
    // open on the same connection is admitted, not quota_exceeded.
    let reply = conn.handle_line(&open).expect("reopen parses");
    assert_eq!(
        reply.get("ok").and_then(|v| v.as_bool()),
        Some(true),
        "lost session must free its quota slot: {reply:?}"
    );
    set.shutdown();
}

/// The tentpole: a decode session survives its replica's death. The
/// dispatcher replays the journal onto a sibling and the stream
/// continues bitwise-identically to an uninterrupted single-engine run
/// — the client never sees an error, and `session_lost` stays 0 under
/// the default (ample) replay budget.
#[test]
fn decode_survives_replica_death_by_journal_replay() {
    let s = workload(17).next_session(SEQ_LEN / 2);
    // Uninterrupted reference stream: same model config, one replica,
    // never killed.
    let reference: Vec<Vec<f32>> = {
        let set = set(1);
        let (sid, _, _) = set.open_session(s.prompt.clone(), None).expect("reference open");
        let logits = s
            .steps
            .iter()
            .map(|&t| set.decode(sid, t).expect("reference decode").logits)
            .collect();
        set.shutdown();
        logits
    };

    let set = set(2);
    // Two sessions: round-robin puts one on each replica, so slot 0
    // owns one of them wherever the cursor started.
    let (sid_a, _, _) = set.open_session(s.prompt.clone(), None).expect("open a");
    let (sid_b, _, _) = set.open_session(s.prompt.clone(), None).expect("open b");
    let kill_at = s.steps.len() / 2;
    let (mut got_a, mut got_b) = (Vec::new(), Vec::new());
    for (i, &tok) in s.steps.iter().enumerate() {
        if i == kill_at {
            set.inject_crash(0);
        }
        got_a.push(set.decode(sid_a, tok).expect("stream a survives the kill").logits);
        got_b.push(set.decode(sid_b, tok).expect("stream b survives the kill").logits);
    }
    assert_eq!(got_a, reference, "migrated stream a must be bitwise-identical");
    assert_eq!(got_b, reference, "migrated stream b must be bitwise-identical");

    let m = set.metrics();
    assert!(m.sessions_migrated() >= 1, "the kill must migrate at least one session");
    assert!(
        m.replayed_tokens() >= (SEQ_LEN / 2) as u64,
        "replay covers at least the migrated session's prompt"
    );
    assert_eq!(m.session_lost(), 0, "an ample budget loses nothing");
    assert_eq!(m.migration_failed(), 0);
    set.close_session(sid_a).expect("close a");
    set.close_session(sid_b).expect("close b");
    set.shutdown();
}

/// Property: migrated decode streams are bitwise-identical to an
/// uninterrupted run across workload seeds × replica counts {2,4} ×
/// kill points × victim slots, with no client-visible error and no
/// `session_lost` (ample budget, siblings always available).
#[test]
fn migration_replay_is_bitwise_identical_for_random_kill_points() {
    forall(
        &PropConfig { cases: 4, seed: 0xD04_A11 },
        |rng, _size| {
            let replicas = [2usize, 4][rng.below(2) as usize];
            (
                rng.below(1 << 32),                  // workload seed
                replicas,                            // replica count
                1 + rng.below(16) as usize,          // kill after this many steps
                rng.below(replicas as u64) as usize, // victim slot
            )
        },
        |&(seed, replicas, kill_at, victim)| {
            let s = workload(seed).next_session(SEQ_LEN / 2);
            let reference: Vec<Vec<f32>> = {
                let set = set(1);
                let (sid, _, _) =
                    set.open_session(s.prompt.clone(), None).expect("reference open");
                let logits = s
                    .steps
                    .iter()
                    .map(|&t| set.decode(sid, t).expect("reference decode").logits)
                    .collect();
                set.shutdown();
                logits
            };

            let set = set(replicas);
            // One session per replica: the victim owns at least one.
            let sids: Vec<u64> = (0..replicas)
                .map(|_| set.open_session(s.prompt.clone(), None).expect("open").0)
                .collect();
            let mut streams: Vec<Vec<Vec<f32>>> = vec![Vec::new(); replicas];
            let mut clean = true;
            for (i, &tok) in s.steps.iter().enumerate() {
                if i == kill_at {
                    set.inject_crash(victim);
                }
                for (j, &sid) in sids.iter().enumerate() {
                    match set.decode(sid, tok) {
                        Ok(r) => streams[j].push(r.logits),
                        Err(_) => clean = false,
                    }
                }
            }
            let migrated = set.metrics().sessions_migrated() >= 1;
            let no_losses = set.metrics().session_lost() == 0;
            set.shutdown();
            clean && migrated && no_losses && streams.iter().all(|st| *st == reference)
        },
    );
}

/// `max_resident_tokens` is enforced at open admission: a prompt that
/// would push the journal ledger past the budget answers a structured
/// `quota_exceeded` naming the limit, the refusal is counted, and
/// closing a session releases its tokens back to the budget.
#[test]
fn resident_token_budget_refuses_opens_with_a_structured_quota_reply() {
    let set = set_with(ReplicaConfig {
        replicas: 2,
        watchdog: Duration::from_millis(150),
        max_resident_tokens: SEQ_LEN, // room for exactly two half-length prompts
        ..Default::default()
    });
    let mut wl = workload(23);
    let (sid, _, _) = set
        .open_session(wl.next_session(SEQ_LEN / 2).prompt, None)
        .expect("first open fits the budget");
    set.open_session(wl.next_session(SEQ_LEN / 2).prompt, None)
        .expect("second open exactly fills the budget");
    match set.open_session(wl.next_session(SEQ_LEN / 2).prompt, None) {
        Err(ServeError::QuotaExceeded { what, limit }) => {
            assert_eq!(what, "resident tokens");
            assert_eq!(limit, SEQ_LEN as u64);
        }
        other => panic!("expected quota_exceeded past the budget, got {other:?}"),
    }
    assert_eq!(set.metrics().resident_budget_rejected(), 1);
    // Close releases the ledger tokens: the same open now fits.
    set.close_session(sid).expect("close");
    set.open_session(wl.next_session(SEQ_LEN / 2).prompt, None)
        .expect("open fits again after a close released its tokens");
    set.shutdown();
}

/// `{"op":"health"}` reports per-replica liveness: slot, incarnation,
/// breaker state, and resident tokens, plus set-level alive/configured
/// counts and the journal ledger.
#[test]
fn health_op_reports_per_replica_state() {
    let set = Arc::new(set(2));
    let state = Arc::new(ServerState::new());
    let mut conn = Conn::new(set.clone(), state, QuotaConfig::default());
    let (sid, _, _) = set
        .open_session(workload(31).next_session(SEQ_LEN / 2).prompt, None)
        .expect("open");

    let reply = conn.handle_line(r#"{"op":"health"}"#).expect("health parses");
    assert_eq!(reply.get("ok").and_then(|v| v.as_bool()), Some(true), "{reply:?}");
    assert_eq!(reply.get("alive").and_then(|v| v.as_f64()), Some(2.0), "{reply:?}");
    assert_eq!(reply.get("configured").and_then(|v| v.as_f64()), Some(2.0));
    assert_eq!(
        reply.get("resident_tokens").and_then(|v| v.as_f64()),
        Some((SEQ_LEN / 2) as f64),
        "the ledger counts the open session's journal"
    );
    let replicas = reply.get("replicas").and_then(|v| v.as_arr()).expect("replicas array");
    assert_eq!(replicas.len(), 2);
    for (slot, r) in replicas.iter().enumerate() {
        assert_eq!(r.get("slot").and_then(|v| v.as_f64()), Some(slot as f64));
        assert_eq!(r.get("alive").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(r.get("breaker_state").and_then(|v| v.as_str()), Some("closed"));
        assert!(r.get("incarnation").and_then(|v| v.as_f64()).is_some());
        assert!(r.get("resident_tokens").and_then(|v| v.as_f64()).is_some());
    }
    set.close_session(sid).expect("close");
    set.shutdown();
}

/// `{"op":"drain_replica"}`: the slot's sessions move to siblings by
/// journal replay (no losses), the reply reports how many moved, the
/// drained engine is replaced by a fresh one (counted as a respawn, not
/// a crash), and every session keeps decoding afterwards.
#[test]
fn drain_replica_migrates_sessions_and_swaps_in_a_fresh_engine() {
    let set = Arc::new(set(2));
    let mut wl = workload(37);
    // Three sessions across two replicas: slot 0 owns at least one
    // wherever the round-robin cursor started.
    let sessions: Vec<(u64, Vec<i32>)> = (0..3)
        .map(|_| {
            let s = wl.next_session(SEQ_LEN / 2);
            let (sid, _, _) = set.open_session(s.prompt.clone(), None).expect("open");
            (sid, s.steps)
        })
        .collect();

    let state = Arc::new(ServerState::new());
    let mut conn = Conn::new(set.clone(), state, QuotaConfig::default());
    let reply =
        conn.handle_line(r#"{"op":"drain_replica","slot":0}"#).expect("drain parses");
    assert_eq!(reply.get("ok").and_then(|v| v.as_bool()), Some(true), "{reply:?}");
    assert_eq!(reply.get("slot").and_then(|v| v.as_f64()), Some(0.0));
    let moved = reply.get("migrated").and_then(|v| v.as_f64()).expect("migrated count");
    assert!(moved >= 1.0, "slot 0 owned at least one session: {reply:?}");

    // Every session survives the drain and keeps decoding.
    for (sid, steps) in &sessions {
        set.decode(*sid, steps[0]).expect("session survives the drain");
    }
    let m = set.metrics();
    assert!(m.sessions_migrated() >= moved as u64);
    assert_eq!(m.session_lost(), 0, "drain must not lose sessions");
    assert_eq!(m.replica_crashes(), 0, "a drain is not a crash");
    assert!(m.replica_respawns() >= 1, "the drained slot got a fresh engine");
    assert!(
        wait_until(Duration::from_secs(5), || set.alive_replicas() == 2),
        "set returns to full strength after the drain"
    );
    for (sid, _) in &sessions {
        set.close_session(*sid).expect("close");
    }
    set.shutdown();
}

/// Deterministic kill schedule under mixed one-shot + session traffic
/// with migration on: the extended accounting identity holds, resident
/// sessions migrate rather than convert (`migrated > 0` and zero
/// `session_lost` under the ample default budget), and the supervisor
/// still restores full strength.
#[test]
fn kill_schedule_holds_identity_with_migration_and_no_losses() {
    let set = set(3);
    let mut wl = workload(29);
    let mut tally = Tally::default();
    let mut submitted = 0usize;

    // One session per replica: both victims own one.
    let mut sessions = Vec::new();
    for _ in 0..3 {
        let s = wl.next_session(SEQ_LEN / 2);
        submitted += 1;
        match set.open_session(s.prompt.clone(), None) {
            Ok((sid, _, _)) => {
                tally.served += 1;
                sessions.push((sid, s.steps));
            }
            Err(e) => tally.count_err(&e),
        }
    }

    // A one-shot burst with two kills inside it.
    let n = 30;
    let mut pending = Vec::new();
    for i in 0..n {
        if i == 10 {
            set.inject_crash(0);
        }
        if i == 20 {
            set.inject_crash(1);
        }
        submitted += 1;
        match set.submit(wl.next_request().tokens, None, None) {
            Ok(p) => pending.push(p),
            Err(e) => tally.count_err(&e),
        }
    }
    for p in pending {
        match p.wait() {
            Ok(_) => tally.served += 1,
            Err(e) => tally.count_err(&e),
        }
    }

    // The sessions stream on across both kills, then close.
    for (sid, steps) in &sessions {
        for &tok in steps.iter().take(4) {
            submitted += 1;
            match set.decode(*sid, tok) {
                Ok(_) => tally.served += 1,
                Err(e) => tally.count_err(&e),
            }
        }
        submitted += 1;
        match set.close_session(*sid) {
            Ok(_) => tally.served += 1,
            Err(e) => tally.count_err(&e),
        }
    }

    assert_eq!(tally.total(), submitted, "extended identity violated: {tally:?}");
    assert_eq!(tally.session_lost, 0, "ample budget: no client may see a loss: {tally:?}");
    let m = set.metrics();
    assert!(m.sessions_migrated() >= 1, "the kills must migrate resident sessions");
    assert_eq!(m.session_lost(), 0, "session_lost is reserved for exhausted migrations");
    assert!(
        wait_until(Duration::from_secs(5), || set.alive_replicas() == 3),
        "supervisor restores full strength"
    );
    infer_eventually(&set, vec![1i32; SEQ_LEN]);
    set.shutdown();
}

/// Seeded chaos at the replica sites: `replica.crash`/`replica.wedge`
/// fire from the dispatch path itself under mixed traffic (every third
/// one-shot carries a tight deadline, plus a decode session). The
/// extended identity holds, kills actually happened, and the set serves
/// once the injector is disarmed. `DSA_CHAOS_SEED` overrides the seed so
/// CI can run a matrix.
#[test]
fn seeded_replica_chaos_holds_the_extended_identity() {
    let seed = std::env::var("DSA_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(101);
    let faults = Arc::new(FaultInjector::new(FaultConfig {
        // High enough that a kill is overwhelmingly likely within the
        // run's ~260 site rolls, for any seed CI picks.
        error_rate: 0.08,
        ..FaultConfig::quiet(seed)
    }));
    faults.set_armed(false);
    let set = ReplicaSet::start_native(
        NativeModelConfig { seq_len: SEQ_LEN, ..Default::default() },
        engine_cfg(),
        ReplicaConfig {
            replicas: 3,
            watchdog: Duration::from_millis(150),
            faults: Some(faults.clone()),
            ..Default::default()
        },
    )
    .expect("replica set boots with the injector disarmed");
    faults.set_armed(true);

    let mut wl = workload(seed);
    let n = 120;
    let mut tally = Tally::default();
    let mut pending = Vec::new();
    for i in 0..n {
        let deadline =
            if i % 3 == 0 { Some(Duration::from_millis(50)) } else { None };
        match set.submit(wl.next_request().tokens, None, deadline) {
            Ok(p) => pending.push(p),
            Err(e) => tally.count_err(&e),
        }
    }
    for p in pending {
        match p.wait() {
            Ok(_) => tally.served += 1,
            Err(e) => tally.count_err(&e),
        }
    }
    let mut submitted = n;

    // Session traffic through the same chaos: each blocking call is one
    // submission with exactly one structured outcome.
    let s = wl.next_session(SEQ_LEN / 2);
    submitted += 1;
    match set.open_session(s.prompt, None) {
        Err(e) => tally.count_err(&e),
        Ok((sid, _, _)) => {
            tally.served += 1;
            for &tok in s.steps.iter().take(4) {
                submitted += 1;
                match set.decode(sid, tok) {
                    Ok(_) => tally.served += 1,
                    Err(e) => tally.count_err(&e),
                }
            }
            submitted += 1;
            match set.close_session(sid) {
                Ok(_) => tally.served += 1,
                Err(e) => tally.count_err(&e),
            }
        }
    }

    assert_eq!(
        tally.total(),
        submitted,
        "extended identity violated under seeded kills (seed {seed}): {tally:?}"
    );
    assert!(
        faults.injected_total() > 0,
        "chaos run must actually kill replicas (seed {seed})"
    );
    // Disarm and prove the supervisor restored service.
    faults.set_armed(false);
    infer_eventually(&set, vec![1i32; SEQ_LEN]);
    set.shutdown();
}

/// Property: the extended identity, zero client hangs (exactly one
/// outcome per submission — a hang times the test out), supervised
/// recovery to full strength, and bit-identical logits after respawn
/// hold across random workload seeds × replica counts {1,2,4} × kill
/// schedules.
#[test]
fn identity_and_determinism_hold_for_random_kill_schedules() {
    forall(
        &PropConfig { cases: 5, seed: 0x5E7_CA11 },
        |rng, _size| {
            let replicas = [1usize, 2, 4][rng.below(3) as usize];
            (
                rng.below(1 << 32),                  // workload seed
                replicas,                            // replica count
                1 + rng.below(20) as usize,          // kill after this many submissions
                rng.below(replicas as u64) as usize, // victim slot
            )
        },
        |&(seed, replicas, kill_after, victim)| {
            let set = set(replicas);
            let reference = set
                .infer(vec![1i32; SEQ_LEN], None)
                .expect("healthy set serves")
                .logits;
            let mut wl = workload(seed);
            let n = 30;
            let mut tally = Tally::default();
            let mut pending = Vec::new();
            for i in 0..n {
                if i == kill_after {
                    set.inject_crash(victim);
                }
                match set.submit(wl.next_request().tokens, None, None) {
                    Ok(p) => pending.push(p),
                    Err(e) => tally.count_err(&e),
                }
            }
            for p in pending {
                match p.wait() {
                    Ok(_) => tally.served += 1,
                    Err(e) => tally.count_err(&e),
                }
            }
            let identity = tally.total() == n;
            let recovered =
                wait_until(Duration::from_secs(5), || set.alive_replicas() == replicas);
            let logits = infer_eventually(&set, vec![1i32; SEQ_LEN]);
            set.shutdown();
            identity && recovered && logits == reference
        },
    );
}
