//! Replicated-serving tests: crash isolation, supervised respawn, and
//! failover through [`ReplicaSet`].
//!
//! The invariant under test extends the chaos suite's accounting
//! identity with the replica-death outcome —
//!
//! ```text
//! submitted == served + overloaded + expired + errored + session_lost
//! ```
//!
//! — under deterministic replica kills (`inject_crash`/`inject_wedge`)
//! and seeded chaos at the `replica.crash`/`replica.wedge` sites. Every
//! client gets exactly one structured reply (a hang fails the test by
//! timeout), accepted one-shots whose replica dies retry on a sibling
//! (`retried` counted exactly once as served), sessions die as
//! structured `session_lost` that frees both the global route and the
//! connection quota slot, the supervisor respawns killed replicas, and
//! a respawned replica serves bit-identical logits (same backend
//! factory, same kernel registry).

use std::sync::Arc;
use std::time::{Duration, Instant};

use dsa_serve::coordinator::{
    BatchPolicy, EngineConfig, NativeModelConfig, ReplicaConfig, ReplicaSet, ServeError,
    SessionPolicy,
};
use dsa_serve::kernels::Variant;
use dsa_serve::server::{Conn, QuotaConfig, ServerState};
use dsa_serve::util::faults::{FaultConfig, FaultInjector};
use dsa_serve::util::prop::{forall, Config as PropConfig};
use dsa_serve::workload::{Workload, WorkloadConfig};

const SEQ_LEN: usize = 64;

/// One structured outcome per submission, keyed by wire code. `total()`
/// must equal the number of submissions — the extended identity.
#[derive(Debug, Default)]
struct Tally {
    served: usize,
    overloaded: usize,
    expired: usize,
    errored: usize,
    session_lost: usize,
}

impl Tally {
    fn count_err(&mut self, e: &ServeError) {
        match e.code() {
            "overloaded" => self.overloaded += 1,
            "expired" => self.expired += 1,
            "session_lost" => self.session_lost += 1,
            _ => self.errored += 1,
        }
    }

    fn total(&self) -> usize {
        self.served + self.overloaded + self.expired + self.errored + self.session_lost
    }
}

fn engine_cfg() -> EngineConfig {
    EngineConfig {
        default_variant: Variant::Dense,
        policy: BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_cap: 256,
            default_deadline: None,
        },
        preload: true,
        router: None,
        sessions: SessionPolicy { max_sessions: 8 },
    }
}

/// A replica set with a fast watchdog so respawn tests stay quick.
fn set(replicas: usize) -> ReplicaSet {
    ReplicaSet::start_native(
        NativeModelConfig { seq_len: SEQ_LEN, ..Default::default() },
        engine_cfg(),
        ReplicaConfig {
            replicas,
            watchdog: Duration::from_millis(150),
            ..Default::default()
        },
    )
    .expect("replica set boots")
}

fn workload(seed: u64) -> Workload {
    Workload::new(WorkloadConfig { seq_len: SEQ_LEN, seed, ..Default::default() })
}

/// Poll `cond` until it holds or `timeout` elapses.
fn wait_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < timeout {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    cond()
}

/// Infer with bounded retries across the respawn window (transient
/// `overloaded` refusals while no replica is healthy are expected).
fn infer_eventually(set: &ReplicaSet, tokens: Vec<i32>) -> Vec<f32> {
    let t0 = Instant::now();
    loop {
        match set.infer(tokens.clone(), None) {
            Ok(resp) => return resp.logits,
            Err(_) if t0.elapsed() < Duration::from_secs(5) => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => panic!("replica set never recovered: {e}"),
        }
    }
}

/// The tentpole: kill a replica under a pipelined one-shot burst. The
/// extended identity holds, at least one accepted request fails over to
/// a sibling (counted `retried`, served exactly once), the supervisor
/// respawns the corpse back to full strength, and the respawned replica
/// serves bit-identical logits.
#[test]
fn replica_kill_mid_traffic_fails_over_and_respawns() {
    let set = set(3);
    let reference = set
        .infer(vec![1i32; SEQ_LEN], None)
        .expect("healthy set serves")
        .logits;

    let mut wl = workload(7);
    let n = 60;
    let mut tally = Tally::default();
    let mut pending = Vec::new();
    for _ in 0..n {
        match set.submit(wl.next_request().tokens, None, None) {
            Ok(p) => pending.push(p),
            Err(e) => tally.count_err(&e),
        }
    }
    // Kill replica 0 with roughly a third of the burst parked on it; its
    // reply channels drop and `wait` retries each on a sibling.
    set.inject_crash(0);
    for p in pending {
        match p.wait() {
            Ok(_) => tally.served += 1,
            Err(e) => tally.count_err(&e),
        }
    }

    assert_eq!(tally.total(), n, "extended accounting identity violated: {tally:?}");
    assert!(tally.served > 0, "siblings must keep serving through the kill: {tally:?}");
    let m = set.metrics();
    assert!(m.retried() >= 1, "at least one accepted request must fail over");
    assert!(
        m.retried() as usize <= tally.served,
        "a retried request is served exactly once (retried {} vs served {})",
        m.retried(),
        tally.served
    );

    // Supervisor: crash detected, corpse torn down, fresh replica up.
    assert!(
        wait_until(Duration::from_secs(5), || set.alive_replicas() == 3),
        "supervisor must respawn back to 3 replicas"
    );
    assert!(
        wait_until(Duration::from_secs(5), || {
            m.replica_crashes() >= 1 && m.replica_respawns() >= 1 && m.replicas_alive() == 3
        }),
        "replica metrics must record the crash, the respawn, and full strength"
    );

    // Same factory, same registry: every slot (the respawn included, via
    // round-robin) serves bit-identical logits for the same tokens.
    for _ in 0..6 {
        let logits = infer_eventually(&set, vec![1i32; SEQ_LEN]);
        assert_eq!(logits, reference, "respawned replica must serve bit-identical logits");
    }
    set.shutdown();
}

/// A wedged replica (alive thread, dead heartbeat) trips the watchdog:
/// torn down, counted as a crash, respawned, and the set keeps serving.
#[test]
fn wedged_replica_trips_the_watchdog_and_respawns() {
    let set = set(2);
    set.inject_wedge(0);
    let m = set.metrics();
    assert!(
        wait_until(Duration::from_secs(5), || m.replica_crashes() >= 1),
        "watchdog must flag the silent replica"
    );
    assert!(
        wait_until(Duration::from_secs(5), || {
            m.replica_respawns() >= 1 && set.alive_replicas() == 2
        }),
        "wedged replica must be torn down and respawned"
    );
    infer_eventually(&set, vec![1i32; SEQ_LEN]);
    set.shutdown();
}

/// With a single replica there is no failover sibling: a kill answers
/// every parked client with a structured error (never a hang, never a
/// `retried` count), and the supervisor still restores service.
#[test]
fn single_replica_death_answers_every_client_without_retries() {
    let set = set(1);
    let mut wl = workload(11);
    let n = 24;
    let mut tally = Tally::default();
    let mut pending = Vec::new();
    for _ in 0..n {
        match set.submit(wl.next_request().tokens, None, None) {
            Ok(p) => pending.push(p),
            Err(e) => tally.count_err(&e),
        }
    }
    set.inject_crash(0);
    for p in pending {
        match p.wait() {
            Ok(_) => tally.served += 1,
            Err(e) => tally.count_err(&e),
        }
    }
    assert_eq!(tally.total(), n, "identity must hold with no sibling: {tally:?}");
    assert_eq!(set.metrics().retried(), 0, "nothing to retry onto — retried must stay 0");
    assert!(
        wait_until(Duration::from_secs(5), || set.alive_replicas() == 1),
        "supervisor must respawn the only replica"
    );
    infer_eventually(&set, vec![1i32; SEQ_LEN]);
    set.shutdown();
}

/// Sticky sessions die with their replica as structured `session_lost`
/// replies carrying the session id; the global route is freed (a second
/// op on the same id is an ordinary unknown-session error) and reopening
/// on the respawned replicas works.
#[test]
fn session_death_converts_to_structured_session_lost() {
    let set = set(2);
    let mut wl = workload(13);
    let (sid1, _, _) = set
        .open_session(wl.next_session(SEQ_LEN / 2).prompt, None)
        .expect("open 1");
    let (sid2, _, _) = set
        .open_session(wl.next_session(SEQ_LEN / 2).prompt, None)
        .expect("open 2");
    assert_ne!(sid1, sid2, "global session ids must be distinct across replicas");

    set.inject_crash(0);
    set.inject_crash(1);
    assert!(
        wait_until(Duration::from_secs(5), || set.alive_replicas() == 2),
        "both replicas must respawn"
    );

    for sid in [sid1, sid2] {
        match set.decode(sid, 3) {
            Err(ServeError::SessionLost { session }) => {
                assert_eq!(session, sid, "session_lost must name the lost session");
            }
            other => panic!("expected session_lost for {sid}, got {other:?}"),
        }
    }
    assert_eq!(set.metrics().session_lost(), 2);
    // The route was freed with the first conversion: the id is now
    // simply unknown, not lost again.
    assert_eq!(set.decode(sid1, 3).unwrap_err().code(), "error");

    // Respawned replicas accept fresh sessions and decode.
    let (sid3, _, _) = set
        .open_session(wl.next_session(SEQ_LEN / 2).prompt, None)
        .expect("reopen on respawned replicas");
    assert!(sid3 > sid2, "global ids keep monotonically increasing");
    set.decode(sid3, 5).expect("decode on the reopened session");
    set.shutdown();
}

/// Wire-level: through a server [`Conn`] the lost session renders as a
/// structured `{"ok":false,"error":"session_lost"}` reply AND frees the
/// connection's quota slot — the client reopens without leaking
/// capacity.
#[test]
fn server_reply_carries_session_lost_and_frees_the_quota_slot() {
    let set = Arc::new(set(2));
    let state = Arc::new(ServerState::new());
    let mut conn = Conn::new(
        set.clone(),
        state,
        QuotaConfig { max_sessions: 1, ..Default::default() },
    );
    let tokens: Vec<String> = (0..SEQ_LEN / 2).map(|i| (i as i32 % 50).to_string()).collect();
    let open = format!(r#"{{"op":"open","tokens":[{}]}}"#, tokens.join(","));

    let reply = conn.handle_line(&open).expect("open parses");
    assert_eq!(reply.get("ok").and_then(|v| v.as_bool()), Some(true), "{reply:?}");
    let sid = reply.get("session").and_then(|v| v.as_f64()).expect("session id") as u64;

    set.inject_crash(0);
    set.inject_crash(1);
    assert!(wait_until(Duration::from_secs(5), || set.alive_replicas() == 2));

    let reply = conn
        .handle_line(&format!(r#"{{"op":"decode","session":{sid},"token":3}}"#))
        .expect("decode parses");
    assert_eq!(
        reply.get("error").and_then(|v| v.as_str()),
        Some("session_lost"),
        "{reply:?}"
    );
    assert_eq!(
        reply.get("session").and_then(|v| v.as_f64()).map(|s| s as u64),
        Some(sid),
        "the reply names the lost session"
    );

    // The quota slot (max_sessions = 1) came back with the loss: a fresh
    // open on the same connection is admitted, not quota_exceeded.
    let reply = conn.handle_line(&open).expect("reopen parses");
    assert_eq!(
        reply.get("ok").and_then(|v| v.as_bool()),
        Some(true),
        "lost session must free its quota slot: {reply:?}"
    );
    set.shutdown();
}

/// Seeded chaos at the replica sites: `replica.crash`/`replica.wedge`
/// fire from the dispatch path itself under mixed traffic (every third
/// one-shot carries a tight deadline, plus a decode session). The
/// extended identity holds, kills actually happened, and the set serves
/// once the injector is disarmed. `DSA_CHAOS_SEED` overrides the seed so
/// CI can run a matrix.
#[test]
fn seeded_replica_chaos_holds_the_extended_identity() {
    let seed = std::env::var("DSA_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(101);
    let faults = Arc::new(FaultInjector::new(FaultConfig {
        // High enough that a kill is overwhelmingly likely within the
        // run's ~260 site rolls, for any seed CI picks.
        error_rate: 0.08,
        ..FaultConfig::quiet(seed)
    }));
    faults.set_armed(false);
    let set = ReplicaSet::start_native(
        NativeModelConfig { seq_len: SEQ_LEN, ..Default::default() },
        engine_cfg(),
        ReplicaConfig {
            replicas: 3,
            watchdog: Duration::from_millis(150),
            faults: Some(faults.clone()),
            ..Default::default()
        },
    )
    .expect("replica set boots with the injector disarmed");
    faults.set_armed(true);

    let mut wl = workload(seed);
    let n = 120;
    let mut tally = Tally::default();
    let mut pending = Vec::new();
    for i in 0..n {
        let deadline =
            if i % 3 == 0 { Some(Duration::from_millis(50)) } else { None };
        match set.submit(wl.next_request().tokens, None, deadline) {
            Ok(p) => pending.push(p),
            Err(e) => tally.count_err(&e),
        }
    }
    for p in pending {
        match p.wait() {
            Ok(_) => tally.served += 1,
            Err(e) => tally.count_err(&e),
        }
    }
    let mut submitted = n;

    // Session traffic through the same chaos: each blocking call is one
    // submission with exactly one structured outcome.
    let s = wl.next_session(SEQ_LEN / 2);
    submitted += 1;
    match set.open_session(s.prompt, None) {
        Err(e) => tally.count_err(&e),
        Ok((sid, _, _)) => {
            tally.served += 1;
            for &tok in s.steps.iter().take(4) {
                submitted += 1;
                match set.decode(sid, tok) {
                    Ok(_) => tally.served += 1,
                    Err(e) => tally.count_err(&e),
                }
            }
            submitted += 1;
            match set.close_session(sid) {
                Ok(_) => tally.served += 1,
                Err(e) => tally.count_err(&e),
            }
        }
    }

    assert_eq!(
        tally.total(),
        submitted,
        "extended identity violated under seeded kills (seed {seed}): {tally:?}"
    );
    assert!(
        faults.injected_total() > 0,
        "chaos run must actually kill replicas (seed {seed})"
    );
    // Disarm and prove the supervisor restored service.
    faults.set_armed(false);
    infer_eventually(&set, vec![1i32; SEQ_LEN]);
    set.shutdown();
}

/// Property: the extended identity, zero client hangs (exactly one
/// outcome per submission — a hang times the test out), supervised
/// recovery to full strength, and bit-identical logits after respawn
/// hold across random workload seeds × replica counts {1,2,4} × kill
/// schedules.
#[test]
fn identity_and_determinism_hold_for_random_kill_schedules() {
    forall(
        &PropConfig { cases: 5, seed: 0x5E7_CA11 },
        |rng, _size| {
            let replicas = [1usize, 2, 4][rng.below(3) as usize];
            (
                rng.below(1 << 32),                  // workload seed
                replicas,                            // replica count
                1 + rng.below(20) as usize,          // kill after this many submissions
                rng.below(replicas as u64) as usize, // victim slot
            )
        },
        |&(seed, replicas, kill_after, victim)| {
            let set = set(replicas);
            let reference = set
                .infer(vec![1i32; SEQ_LEN], None)
                .expect("healthy set serves")
                .logits;
            let mut wl = workload(seed);
            let n = 30;
            let mut tally = Tally::default();
            let mut pending = Vec::new();
            for i in 0..n {
                if i == kill_after {
                    set.inject_crash(victim);
                }
                match set.submit(wl.next_request().tokens, None, None) {
                    Ok(p) => pending.push(p),
                    Err(e) => tally.count_err(&e),
                }
            }
            for p in pending {
                match p.wait() {
                    Ok(_) => tally.served += 1,
                    Err(e) => tally.count_err(&e),
                }
            }
            let identity = tally.total() == n;
            let recovered =
                wait_until(Duration::from_secs(5), || set.alive_replicas() == replicas);
            let logits = infer_eventually(&set, vec![1i32; SEQ_LEN]);
            set.shutdown();
            identity && recovered && logits == reference
        },
    );
}
