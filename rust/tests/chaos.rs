//! Chaos tests: seeded fault injection through the full serving stack.
//!
//! A [`FaultInjector`] wired into the native backend injects panics,
//! errors and delays at the `backend.run` / `backend.open` /
//! `backend.decode` hook sites while multiple threads hammer the engine
//! with one-shot requests (some with tiny deadlines) and decode sessions.
//! The invariant under test is *accounting*: every submitted operation
//! gets exactly one structured reply —
//!
//! ```text
//! submitted == served + overloaded + expired + errored
//! ```
//!
//! — the worker never dies (the engine still serves after the injector is
//! disarmed), and drain-then-shutdown exits cleanly. Failures reproduce
//! from their seed; `DSA_CHAOS_SEED` overrides the default so CI can run
//! a seed matrix.
//!
//! The replicated suite (`tests/replica.rs`) extends this identity with
//! the `session_lost` outcome under replica kills, and pins the
//! durability contract on top of it: resident sessions migrate to
//! siblings by journal replay (`migrated > 0`, bitwise-identical
//! streams), so `session_lost` appears only when a migration is
//! exhausted — replay budget, sibling availability, or memory pressure.

use std::sync::Arc;
use std::time::Duration;

use dsa_serve::coordinator::{
    BatchPolicy, Engine, EngineConfig, NativeModelConfig, ServeError, SessionPolicy,
};
use dsa_serve::kernels::Variant;
use dsa_serve::util::faults::{FaultConfig, FaultInjector};
use dsa_serve::util::prop::{forall, Config as PropConfig};
use dsa_serve::workload::{Workload, WorkloadConfig};

const SEQ_LEN: usize = 64;

/// One structured outcome per submitted operation, keyed by the typed
/// error code. `total()` must equal the number of submissions — a
/// mismatch means a request was silently dropped or double-answered.
#[derive(Debug, Default, Clone, Copy)]
struct Tally {
    served: usize,
    overloaded: usize,
    expired: usize,
    errored: usize,
}

impl Tally {
    fn count_err(&mut self, e: &ServeError) {
        match e.code() {
            "overloaded" => self.overloaded += 1,
            "expired" => self.expired += 1,
            _ => self.errored += 1,
        }
    }

    fn total(&self) -> usize {
        self.served + self.overloaded + self.expired + self.errored
    }

    fn absorb(&mut self, o: Tally) {
        self.served += o.served;
        self.overloaded += o.overloaded;
        self.expired += o.expired;
        self.errored += o.errored;
    }
}

/// Start a native engine with a fault injector at the given rates. The
/// injector is disarmed during startup (preload must succeed — chaos
/// targets serving, not boot) and re-armed before this returns.
fn chaos_engine(
    seed: u64,
    rates: (f64, f64, f64),
    queue_cap: usize,
) -> (Arc<Engine>, Arc<FaultInjector>) {
    let faults = Arc::new(FaultInjector::new(FaultConfig {
        panic_rate: rates.0,
        error_rate: rates.1,
        delay_rate: rates.2,
        delay: Duration::from_millis(1),
        ..FaultConfig::quiet(seed)
    }));
    faults.set_armed(false);
    let engine = Engine::start_native(
        NativeModelConfig {
            seq_len: SEQ_LEN,
            faults: Some(faults.clone()),
            ..Default::default()
        },
        EngineConfig {
            default_variant: Variant::Dense,
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                queue_cap,
                default_deadline: None,
            },
            preload: true,
            router: None,
            sessions: SessionPolicy { max_sessions: 8 },
        },
    )
    .expect("chaos engine boots with the injector disarmed");
    faults.set_armed(true);
    (Arc::new(engine), faults)
}

/// Hammer the engine from `threads` submitter threads, each mixing a
/// burst of one-shot requests (every third with a tiny deadline) with a
/// short decode session. Returns (submitted, tally); panics if any
/// request's reply channel disconnects without an answer — the silent
/// drop this harness exists to catch.
fn hammer(engine: &Arc<Engine>, seed: u64, threads: usize, per_thread: usize) -> (usize, Tally) {
    let mut handles = Vec::new();
    for t in 0..threads as u64 {
        let engine = engine.clone();
        handles.push(std::thread::spawn(move || {
            let mut tally = Tally::default();
            let mut submitted = 0usize;
            let mut wl = Workload::new(WorkloadConfig {
                seq_len: SEQ_LEN,
                seed: seed ^ (t.wrapping_mul(0x9E37_79B9)),
                ..Default::default()
            });

            // One-shot burst: submit everything first so the queue
            // actually backs up, then drain the replies.
            let mut rxs = Vec::new();
            for i in 0..per_thread {
                let deadline = if i % 3 == 0 {
                    // Tight enough to expire in a backed-up queue, long
                    // enough to sometimes serve: exercises both paths.
                    Some(Duration::from_micros(500))
                } else {
                    None
                };
                submitted += 1;
                match engine.submit(wl.next_request().tokens, None, deadline) {
                    Ok(rx) => rxs.push(rx),
                    Err(e) => tally.count_err(&e),
                }
            }
            for rx in rxs {
                match rx.recv() {
                    Ok(Ok(_)) => tally.served += 1,
                    Ok(Err(e)) => tally.count_err(&e),
                    Err(_) => panic!(
                        "request reply channel disconnected without an answer \
                         (silent drop, seed {seed})"
                    ),
                }
            }

            // Session traffic through the same faulted backend: open,
            // a few decodes, close. Each blocking call is one submitted
            // operation with exactly one structured outcome.
            let s = wl.next_session(SEQ_LEN / 2);
            submitted += 1;
            match engine.open_session(s.prompt, None) {
                Err(e) => tally.count_err(&e),
                Ok((sid, _resident, _variant)) => {
                    tally.served += 1;
                    for &tok in s.steps.iter().take(4) {
                        submitted += 1;
                        match engine.decode(sid, tok) {
                            Ok(_) => tally.served += 1,
                            Err(e) => tally.count_err(&e),
                        }
                    }
                    // Close ops never expire and must free the slot even
                    // under chaos.
                    submitted += 1;
                    match engine.close_session(sid) {
                        Ok(_) => tally.served += 1,
                        Err(e) => tally.count_err(&e),
                    }
                }
            }
            (submitted, tally)
        }));
    }
    let mut submitted = 0usize;
    let mut tally = Tally::default();
    for h in handles {
        let (s, t) = h.join().expect("submitter thread must not die");
        submitted += s;
        tally.absorb(t);
    }
    (submitted, tally)
}

fn chaos_seed() -> u64 {
    std::env::var("DSA_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(101)
}

/// The tentpole chaos run: panics, errors and delays at every backend
/// hook site under a tight queue cap, multi-threaded mixed traffic, and
/// the full accounting identity — then disarm, prove liveness, and
/// drain-then-shutdown.
#[test]
fn chaos_every_request_gets_exactly_one_reply() {
    let seed = chaos_seed();
    let (engine, faults) = chaos_engine(seed, (0.05, 0.10, 0.10), 8);

    let (submitted, tally) = hammer(&engine, seed, 4, 32);
    assert_eq!(
        submitted,
        tally.total(),
        "accounting identity violated (seed {seed}): {tally:?}"
    );
    assert!(
        faults.injected_total() > 0,
        "harness must actually inject faults (seed {seed})"
    );
    assert!(
        tally.served > 0,
        "some requests must survive moderate chaos (seed {seed}): {tally:?}"
    );

    // The engine's overload accounting saw the same story the clients did.
    let m = engine.metrics.to_json();
    let overload = m.get("overload").expect("overload section");
    let expired = overload
        .get("expired_total")
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0) as usize;
    assert!(
        expired <= tally.expired,
        "metrics cannot expire more than clients observed \
         (metrics {expired} vs clients {:?}, seed {seed})",
        tally.expired
    );

    // Worker never died: disarm the injector and the same engine serves.
    faults.set_armed(false);
    engine
        .infer(vec![1i32; SEQ_LEN], None)
        .expect("engine must serve cleanly once faults are disarmed");

    // Drain-then-shutdown: admissions stop with a structured refusal,
    // then shutdown joins the worker without losing in-flight work.
    engine.stop_admissions();
    let refused = engine
        .submit(vec![1i32; SEQ_LEN], None, None)
        .map(|_| ())
        .expect_err("post-drain submit must be refused");
    assert_eq!(refused.code(), "shutting_down");
    engine.shutdown();
}

/// Property: the accounting identity holds and the worker survives for
/// *random* chaos seeds, fault-rate mixes and thread counts — not just
/// the hand-picked seed above.
#[test]
fn chaos_accounting_identity_holds_for_random_seeds() {
    forall(
        &PropConfig {
            cases: 6,
            seed: 0xC4A05,
        },
        |rng, _size| {
            (
                rng.below(1 << 32),            // chaos seed
                rng.f64() * 0.08,              // panic rate
                rng.f64() * 0.15,              // error rate
                rng.f64() * 0.15,              // delay rate
                1 + rng.below(3) as usize,     // submitter threads
            )
        },
        |&(seed, panic_rate, error_rate, delay_rate, threads)| {
            let (engine, faults) = chaos_engine(seed, (panic_rate, error_rate, delay_rate), 6);
            let (submitted, tally) = hammer(&engine, seed, threads, 16);
            faults.set_armed(false);
            let alive = engine.infer(vec![1i32; SEQ_LEN], None).is_ok();
            engine.stop_admissions();
            engine.shutdown();
            submitted == tally.total() && alive
        },
    );
}
