//! Self-lint: the repo must be clean under its own static-analysis pass.
//!
//! This is the hermetic twin of the CI `lint` job (`dsa-serve lint
//! --check`): it runs the same scanner over the same default path set
//! (`src/`, `tests/`, `benches/`, anchored to the manifest dir), so a
//! rule violation introduced anywhere in the crate fails `cargo test`
//! locally before CI ever sees it. The failure message carries every
//! finding verbatim — `file:line: rule-id message` — so the fix is one
//! click away.

use dsa_serve::lint;

#[test]
fn repo_is_lint_clean() {
    let paths = lint::default_paths();
    assert!(
        paths.iter().any(|p| p.ends_with("src")),
        "default lint paths must include the crate's src/ tree"
    );
    let findings = lint::lint_paths(&paths).expect("lint scan over the repo must not error");
    assert!(
        findings.is_empty(),
        "repo is not lint-clean — {} finding(s):\n{}",
        findings.len(),
        findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
}
