//! Integration tests over the real AOT artifacts (`make artifacts` first).
//!
//! The whole file is gated on the `xla` feature (the default build has no
//! PJRT); with the feature on, every test additionally no-ops with a
//! message when `artifacts/manifest.json` is missing so `cargo test` stays
//! green without artifacts. The hermetic end-to-end coverage lives in
//! `tests/native_engine.rs`.

#![cfg(feature = "xla")]

use std::sync::Arc;
use std::time::Duration;

use dsa_serve::coordinator::{BatchPolicy, Engine, EngineConfig, SessionPolicy};
use dsa_serve::kernels::Variant;
use dsa_serve::runtime::registry::{Manifest, Registry};
use dsa_serve::runtime::Arg;
use dsa_serve::server::{Conn, QuotaConfig, ServerState};
use dsa_serve::util::json::Json;
use dsa_serve::util::prop::assert_allclose;
use dsa_serve::workload::{Workload, WorkloadConfig};

fn manifest() -> Option<Manifest> {
    match Manifest::open("artifacts") {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("artifacts/ not built — skipping integration test");
            None
        }
    }
}

/// The HLO text round-trip must preserve the folded weight constants:
/// replay the first eval row through each compiled classifier and compare
/// with the logits JAX computed at export time.
#[test]
fn classifier_logits_match_jax() {
    let Some(man) = manifest() else { return };
    let registry = Registry::from_manifest(man.clone()).expect("registry");
    let tokens = man.tensor("eval_tokens").expect("eval_tokens");
    let l = man.task_seq_len;
    let row: Vec<i32> = tokens.as_i32().expect("i32")[..l].to_vec();

    for variant in &man.variants {
        let expect = match man.tensor(&format!("expected_logits_{variant}_b1")) {
            Ok(t) => t.as_f32().expect("f32"),
            Err(_) => continue,
        };
        let info = man.classifier(variant, 1).expect("classifier b1");
        let exe = registry.load(&info.name).expect("compile");
        let out = exe
            .run_f32(&[Arg::i32(row.clone(), &[1, l])])
            .expect("execute");
        // The artifact lowers through the Pallas kernels while the expected
        // logits were computed on the jnp path. For the dense model the two
        // paths agree to float noise. For DSA variants, score differences
        // in the last ulps can flip top-k tie-breaks in the dynamic mask —
        // a legitimate divergence that grows with sparsity (at DSA-99 only
        // 3 entries/row survive). Check: logits close at a variant-scaled
        // tolerance AND the argmax (the served prediction) must agree.
        if variant == "dense" {
            assert_allclose(&out[0], &expect, 1e-3, 1e-4);
        } else {
            // DSA-99 keeps only 3 entries/row: one tie-flip moves a logit
            // by O(0.1); gross-bound the values, gate on the prediction.
            assert_allclose(&out[0], &expect, 0.3, 0.3);
            assert_eq!(
                dsa_serve::coordinator::InferResponse::argmax(&out[0]),
                dsa_serve::coordinator::InferResponse::argmax(&expect),
                "{variant}: served prediction flipped"
            );
        }
        eprintln!("{variant}: logits match ({:?})", &out[0]);
    }
}

/// Batch-bucket invariance: the same request padded into different buckets
/// must produce the same logits for the real rows.
#[test]
fn bucket_padding_is_consistent() {
    let Some(man) = manifest() else { return };
    let registry = Registry::from_manifest(man.clone()).expect("registry");
    let l = man.task_seq_len;
    let tokens = man.tensor("eval_tokens").expect("eval_tokens");
    let row: Vec<i32> = tokens.as_i32().expect("i32")[..l].to_vec();

    let variant = "dense";
    let e1 = registry
        .load(&man.classifier(variant, 1).unwrap().name)
        .unwrap();
    let out1 = e1.run_f32(&[Arg::i32(row.clone(), &[1, l])]).unwrap();
    for &b in man.batch_buckets.iter().filter(|&&b| b > 1) {
        let exe = registry
            .load(&man.classifier(variant, b).unwrap().name)
            .unwrap();
        let mut padded = Vec::with_capacity(b * l);
        for _ in 0..b {
            padded.extend_from_slice(&row);
        }
        let out = exe.run_f32(&[Arg::i32(padded, &[b, l])]).unwrap();
        let classes = man.task_classes;
        assert_allclose(&out[0][..classes], &out1[0][..classes], 1e-4, 1e-5);
    }
}

/// Engine end-to-end: submit concurrent requests, get coherent responses,
/// and the trained model must beat chance on its own task distribution.
#[test]
fn engine_serves_and_model_beats_chance() {
    let Some(man) = manifest() else { return };
    let engine = Engine::start(
        man.clone(),
        EngineConfig {
            default_variant: Variant::Dense,
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
                queue_cap: 128,
                default_deadline: None,
            },
            preload: true,
            router: None,
            sessions: SessionPolicy::default(),
        },
    )
    .expect("engine");

    let n = 32;
    let mut wl = Workload::new(WorkloadConfig {
        seq_len: engine.seq_len(),
        seed: 99,
        ..Default::default()
    });
    let trace = wl.trace(n);
    let mut rxs = Vec::new();
    let mut labels = Vec::new();
    for r in trace {
        labels.push(r.label);
        rxs.push(engine.submit(r.tokens, None, None).expect("submit"));
    }
    let mut correct = 0;
    for (rx, label) in rxs.into_iter().zip(labels) {
        let resp = rx.recv().expect("channel").expect("served");
        assert_eq!(resp.logits.len(), man.task_classes);
        assert!(resp.latency > Duration::ZERO);
        if resp.pred as i32 == label {
            correct += 1;
        }
    }
    // Trained to ~0.95+ on this distribution; 22/32 is ~5 sigma above chance.
    assert!(
        correct >= 22,
        "dense model should beat chance: {correct}/{n} correct"
    );
    // Dynamic batching must actually have batched something.
    let occ = engine
        .metrics
        .to_json()
        .get("mean_occupancy")
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0);
    assert!(occ > 1.0, "expected batching, mean occupancy {occ}");
}

/// Per-request variant override routes to a different executable.
#[test]
fn variant_override_routing() {
    let Some(man) = manifest() else { return };
    if !man.variants.iter().any(|v| v == "dsa90") {
        return;
    }
    let engine = Engine::start(man.clone(), EngineConfig::default()).expect("engine");
    let mut wl = Workload::new(WorkloadConfig {
        seq_len: engine.seq_len(),
        seed: 4,
        ..Default::default()
    });
    let r = wl.next_request();
    let resp_dense = engine
        .infer(r.tokens.clone(), Some(Variant::Dense))
        .expect("dense");
    let resp_dsa = engine
        .infer(r.tokens, Some(Variant::Dsa { pct: 90 }))
        .expect("dsa90");
    assert_eq!(resp_dense.variant, Variant::Dense);
    assert_eq!(resp_dsa.variant, Variant::Dsa { pct: 90 });
}

/// Server protocol: infer / metrics / ping round-trip via a `Conn`.
#[test]
fn server_protocol_roundtrip() {
    let Some(man) = manifest() else { return };
    let engine = Arc::new(Engine::start(man.clone(), EngineConfig::default()).expect("engine"));
    let state = Arc::new(ServerState::new());
    let mut c = Conn::new(engine.clone(), state, QuotaConfig::default());

    let pong = c.handle_line(r#"{"op":"ping"}"#).unwrap();
    assert_eq!(pong.get("pong"), Some(&Json::Bool(true)));

    let mut wl = Workload::new(WorkloadConfig {
        seq_len: engine.seq_len(),
        seed: 12,
        ..Default::default()
    });
    let r = wl.next_request();
    let toks: Vec<String> = r.tokens.iter().map(|t| t.to_string()).collect();
    let line = format!(r#"{{"op":"infer","tokens":[{}]}}"#, toks.join(","));
    let resp = c.handle_line(&line).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    assert!(resp.get("pred").is_some());

    let metrics = c.handle_line(r#"{"op":"metrics"}"#).unwrap();
    assert!(metrics.get("completed").and_then(|v| v.as_f64()).unwrap_or(0.0) >= 1.0);

    // malformed input → structured error, no panic
    let err = c.handle_line("{nope");
    assert!(err.is_err());
}

/// Masks exported from the trained DSA model honor the row-top-k uniform
/// constraint at ~90% sparsity.
#[test]
fn exported_masks_are_row_uniform_and_sparse() {
    let Some(man) = manifest() else { return };
    let Ok(t) = man.tensor("dsa90_masks") else { return };
    assert_eq!(t.dims.len(), 4);
    let l = t.dims[2];
    let keep = ((l as f64) * 0.10).round() as usize;
    let m = dsa_serve::sparse::DenseMask::from_tensor_slice(&t, 0).unwrap();
    let sp = m.sparsity();
    assert!((0.85..0.95).contains(&sp), "sparsity {sp}");
    // top-k with ties kept: rows may slightly exceed keep but never less.
    for r in 0..m.rows {
        assert!(m.row_nnz(r) >= keep, "row {r} has {} < {keep}", m.row_nnz(r));
    }
}
