//! Per-shape tile geometry for the fused attention kernels.
//!
//! The fused tiled online-softmax kernels (`kernels::dense`,
//! `kernels::sparse`) are parameterized by a [`Tile`]: how many keys one
//! K/V tile streams ([`Tile::key_tile`]) and how many query rows share
//! each tile pass ([`Tile::query_block`]). Fused outputs depend on the
//! key-tile size (it sets the accumulation order of the online softmax),
//! so the serving invariant — **bit-identical results across thread
//! counts, dispatch backends and batch shapes** — requires the tile to be
//! fixed *per problem shape before dispatch*, never chosen from runtime
//! conditions like the worker count or queue depth.
//!
//! [`TilePlan`] encodes exactly that contract: an immutable map from
//! `(l, dk)` problem shapes to tiles, resolved once per dispatch
//! ([`TilePlan::lookup`]) with [`Tile::DEFAULT`] (`KEY_TILE = 256`,
//! `QUERY_BLOCK = 8` — today's constants) as the fallback for unlisted
//! shapes. An empty plan therefore reproduces the pre-`TilePlan` fused
//! outputs bit for bit.
//!
//! The **committed tile table** ([`TILE_TABLE`], surfaced as
//! [`TilePlan::committed`]) is the offline-tuned source of truth the
//! default [`KernelSpec`](super::dispatch::KernelSpec) ships with. It is
//! produced by the `bench_kernels` tile sweep (`native/.../st-kt*-qb*`
//! names): run the sweep on the serving hardware, copy the winning
//! `(l, dk) -> (key_tile, query_block)` rows into [`TILE_TABLE`], then
//! regenerate the derived artifact with `dsa-serve tile-plan` (CI checks
//! the committed `results/TILE_PLAN.json` against this table in
//! `--check` mode, so the two can never drift apart).

use super::dense;

/// Widest query block the fused kernels support: their per-row running
/// max / denominator / nan-pending state are fixed-size stack arrays of
/// this length, so a [`Tile`] may not exceed it (enforced by
/// [`Tile::validate`] and clamped defensively in the kernels).
pub const MAX_QUERY_BLOCK: usize = 32;

/// One fused-kernel tile geometry: the unit entry of a [`TilePlan`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Tile {
    /// Keys (and value rows) per K/V tile. Changes the accumulation order
    /// of the fused online softmax, so outputs are only comparable
    /// bit-for-bit at equal `key_tile`.
    pub key_tile: usize,
    /// Query rows sharing each K/V tile pass. Pure locality: each row owns
    /// its running state, so per-row results never depend on this.
    pub query_block: usize,
}

impl Tile {
    /// Today's constants — the fallback geometry every unlisted shape
    /// runs at, reproducing the pre-`TilePlan` fused outputs bit for bit.
    pub const DEFAULT: Tile = Tile {
        key_tile: dense::KEY_TILE,
        query_block: dense::QUERY_BLOCK,
    };

    /// Is this a usable geometry (`key_tile >= 1`,
    /// `1 <= query_block <= MAX_QUERY_BLOCK`)?
    pub fn validate(&self) -> bool {
        self.key_tile >= 1 && (1..=MAX_QUERY_BLOCK).contains(&self.query_block)
    }
}

impl Default for Tile {
    fn default() -> Tile {
        Tile::DEFAULT
    }
}

/// The committed per-shape tile table: `(l, dk, key_tile, query_block)`
/// rows, offline-tuned via the `bench_kernels` tile sweep on the serving
/// hardware and checked into source so every build resolves the same
/// plan.
///
/// PROVENANCE: currently **empty** — every shape runs at
/// [`Tile::DEFAULT`], which is exactly the pre-`TilePlan` behavior. The
/// PR introducing this table was authored in a container without a Rust
/// toolchain, so the tuning sweep could not be run; populate it by
/// running `cargo bench --bench bench_kernels` on a cargo-equipped
/// machine, copying the printed `suggested TILE_TABLE rows` here, and
/// refreshing the derived artifact with `dsa-serve tile-plan`.
pub const TILE_TABLE: &[(usize, usize, usize, usize)] = &[];

/// An immutable `(l, dk) -> Tile` plan, fixed before dispatch. Lookups
/// are deterministic functions of the shape alone — thread count, exec
/// backend and batch size never enter — which is what keeps fused
/// outputs bit-identical across all of them (property-tested in
/// `kernels::dispatch`).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TilePlan {
    /// Sorted by `(l, dk)` for binary-search lookup.
    entries: Vec<((usize, usize), Tile)>,
}

impl TilePlan {
    /// The empty plan: every shape resolves to [`Tile::DEFAULT`].
    pub fn empty() -> TilePlan {
        TilePlan::default()
    }

    /// The plan encoded by the committed [`TILE_TABLE`] — what the
    /// default `KernelSpec` ships with.
    pub fn committed() -> TilePlan {
        let mut plan = TilePlan::empty();
        for &(l, dk, key_tile, query_block) in TILE_TABLE {
            plan = plan.with_entry(l, dk, Tile { key_tile, query_block });
        }
        plan
    }

    /// Add (or replace) the tile for one `(l, dk)` shape. Panics on an
    /// invalid geometry — a bad committed table must fail loudly at
    /// construction, not silently misroute at dispatch.
    pub fn with_entry(mut self, l: usize, dk: usize, tile: Tile) -> TilePlan {
        assert!(
            tile.validate(),
            "invalid tile {tile:?} for (l={l}, dk={dk}): need key_tile >= 1 and \
             1 <= query_block <= {MAX_QUERY_BLOCK}"
        );
        match self.entries.binary_search_by_key(&(l, dk), |e| e.0) {
            Ok(i) => self.entries[i].1 = tile,
            Err(i) => self.entries.insert(i, ((l, dk), tile)),
        }
        self
    }

    /// The tile to run an `(l, dk)` problem at: the planned entry, or
    /// [`Tile::DEFAULT`] for unlisted shapes. Pure function of the shape.
    pub fn lookup(&self, l: usize, dk: usize) -> Tile {
        match self.entries.binary_search_by_key(&(l, dk), |e| e.0) {
            Ok(i) => self.entries[i].1,
            Err(_) => Tile::DEFAULT,
        }
    }

    /// Planned entries, ascending by `(l, dk)`.
    pub fn entries(&self) -> impl Iterator<Item = (usize, usize, Tile)> + '_ {
        self.entries.iter().map(|&((l, dk), t)| (l, dk, t))
    }

    /// Number of planned (non-fallback) shapes.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_tile_matches_the_constants() {
        assert_eq!(Tile::DEFAULT.key_tile, dense::KEY_TILE);
        assert_eq!(Tile::DEFAULT.query_block, dense::QUERY_BLOCK);
        assert!(Tile::DEFAULT.validate());
    }

    #[test]
    fn empty_plan_always_falls_back() {
        let p = TilePlan::empty();
        for (l, dk) in [(0, 0), (1, 1), (256, 64), (2000, 64)] {
            assert_eq!(p.lookup(l, dk), Tile::DEFAULT);
        }
        assert!(p.is_empty());
    }

    #[test]
    fn entries_resolve_and_replace() {
        let t1 = Tile { key_tile: 128, query_block: 4 };
        let t2 = Tile { key_tile: 512, query_block: 16 };
        let p = TilePlan::empty()
            .with_entry(1024, 64, t1)
            .with_entry(256, 64, t2)
            .with_entry(1024, 64, t2); // replaces t1
        assert_eq!(p.lookup(1024, 64), t2);
        assert_eq!(p.lookup(256, 64), t2);
        // near-miss shapes fall back
        assert_eq!(p.lookup(1024, 32), Tile::DEFAULT);
        assert_eq!(p.lookup(1023, 64), Tile::DEFAULT);
        assert_eq!(p.len(), 2);
        let listed: Vec<_> = p.entries().collect();
        assert_eq!(listed, vec![(256, 64, t2), (1024, 64, t2)]);
    }

    /// Lookups are pure functions of the shape: repeated queries agree,
    /// and nothing about the environment (thread counts etc.) can enter
    /// the signature. The dispatch-level property test extends this to
    /// bit-identical kernel outputs across thread counts and backends.
    #[test]
    fn lookup_is_deterministic() {
        let p = TilePlan::committed();
        for (l, dk) in [(64, 8), (256, 64), (1024, 64)] {
            let first = p.lookup(l, dk);
            for _ in 0..3 {
                assert_eq!(p.lookup(l, dk), first);
            }
            assert!(first.validate());
        }
    }

    #[test]
    fn committed_table_is_valid() {
        // A malformed TILE_TABLE row must fail this test (with_entry
        // panics), not surface as silent misrouting in serving.
        let p = TilePlan::committed();
        assert_eq!(p.len(), TILE_TABLE.len());
        for (_, _, t) in p.entries() {
            assert!(t.validate());
        }
    }

    #[test]
    #[should_panic(expected = "invalid tile")]
    fn oversized_query_block_rejected() {
        let _ = TilePlan::empty().with_entry(
            64,
            8,
            Tile { key_tile: 64, query_block: MAX_QUERY_BLOCK + 1 },
        );
    }
}
