//! Hand-constructed needle-counting classifier over the native attention
//! kernels — the model served by the hermetic engine backend.
//!
//! The synthetic serving task ([`crate::workload`], mirroring
//! python/compile/data.py `gen_text`) plants `tokens[0]` as a needle;
//! label 1 ⇔ the needle recurs at least `l/16` times. Attention solves
//! this exactly without training: with random ±1 sign embeddings,
//! `q_i · k_j` is large only where `t_i == t_j`, so query row 0's softmax
//! mass over needle columns is monotone in the needle count. With one-hot
//! value vectors, `out[0][needle]` *is* that mass, and thresholding it
//! classifies the sequence.
//!
//! The threshold is variant-aware: a dynamic-sparse mask keeping `keep`
//! entries per row renormalizes the softmax over a shorter non-match tail,
//! inflating the mass, so the decision boundary is computed from the mask
//! budget the dispatched kernel reports. This keeps the classifier
//! accurate through the same dense and DSA kernels the benches measure
//! (down to ~95% sparsity at l = 256; sparser masks saturate the mass and
//! lose label-0 accuracy — the paper's accuracy/sparsity trade-off,
//! observable natively).

use super::dispatch::{AttnBatch, KernelDispatch};
use crate::util::rng::Rng;

/// Token vocabulary (matches the workload generator's `1..=255` range and
/// doubles as the one-hot value dimension).
pub const VOCAB: usize = 256;
/// Embedding width: same-token raw scores land at `sqrt(DK)` after the
/// kernels' `1/sqrt(dk)` scaling; cross-token scores are ~N(0, 1).
const DK: usize = 64;
/// Target softmax weight of a matching column relative to a typical
/// non-match (sets the query scale β = ln(MATCH_WEIGHT)/sqrt(DK)).
const MATCH_WEIGHT: f64 = 40.0;
/// Logit scale.
const GAIN: f64 = 6.0;

/// Deterministic needle-counting classifier. Cheap to construct; the
/// embedding table is fixed by `seed`.
pub struct NativeClassifier {
    seq_len: usize,
    /// `VOCAB x DK` random sign embeddings (±1).
    emb: Vec<f32>,
}

impl NativeClassifier {
    pub fn new(seq_len: usize, seed: u64) -> NativeClassifier {
        assert!(seq_len >= 16, "seq_len {seq_len} too short for the task");
        let mut emb = Vec::with_capacity(VOCAB * DK);
        for t in 0..VOCAB {
            let mut rng = Rng::new(seed ^ ((t as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)));
            for _ in 0..DK {
                emb.push(if rng.next_u64() & 1 == 1 { 1.0 } else { -1.0 });
            }
        }
        NativeClassifier { seq_len, emb }
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    pub fn classes(&self) -> usize {
        2
    }

    /// Decision boundary on the needle softmax mass for a mask keeping
    /// `keep` entries per row: the mass a pivot count of matches (midway
    /// between the task's label-0 max and label-1 min) would produce.
    fn threshold(&self, keep: usize) -> f64 {
        let l = self.seq_len;
        let hi = (l / 16).max(8) as f64;
        let lo = (hi / 4.0).max(2.0);
        let pivot = (lo + hi) / 2.0;
        pivot * MATCH_WEIGHT / (pivot * MATCH_WEIGHT + (keep as f64 - pivot).max(0.0))
    }

    /// Run one sequence through `kernel` and return `[logit_0, logit_1]`.
    pub fn logits(&self, tokens: &[i32], kernel: &dyn KernelDispatch) -> Vec<f32> {
        self.logits_batch(tokens, 1, kernel)
    }

    /// Run `n` concatenated sequences (`n * seq_len` tokens) through
    /// `kernel` as **one** batched dispatch, returning `n * 2` logits.
    /// Each sequence is an independent single-head attention problem
    /// (`b = n`, `h = 1`), so the result is bit-identical to calling
    /// [`NativeClassifier::logits`] per sequence — the kernels' batched
    /// drivers guarantee it — while the dispatch overhead (thread
    /// spawn/join, scorer setup) is paid once per engine batch.
    pub fn logits_batch(
        &self,
        tokens: &[i32],
        n: usize,
        kernel: &dyn KernelDispatch,
    ) -> Vec<f32> {
        let l = self.seq_len;
        assert_eq!(tokens.len(), n * l, "token length");
        let beta = (MATCH_WEIGHT.ln() / (DK as f64).sqrt()) as f32;
        let mut q = Vec::with_capacity(n * l * DK);
        let mut k = Vec::with_capacity(n * l * DK);
        let mut v = vec![0f32; n * l * VOCAB];
        for (s, seq) in tokens.chunks_exact(l).enumerate() {
            for (i, &t) in seq.iter().enumerate() {
                let t = t.rem_euclid(VOCAB as i32) as usize;
                let e = &self.emb[t * DK..(t + 1) * DK];
                k.extend_from_slice(e);
                q.extend(e.iter().map(|&x| x * beta));
                v[(s * l + i) * VOCAB + t] = 1.0;
            }
        }
        let out = kernel.forward_batch(&AttnBatch {
            q: &q,
            k: &k,
            v: &v,
            b: n,
            h: 1,
            l,
            dk: DK,
            dv: VOCAB,
        });
        let keep = kernel.keep(l).unwrap_or(l);
        let threshold = self.threshold(keep);
        let mut logits = Vec::with_capacity(n * 2);
        for (s, seq) in tokens.chunks_exact(l).enumerate() {
            let needle = seq[0].rem_euclid(VOCAB as i32) as usize;
            // Row 0's context vector of each sequence is a distribution
            // over tokens; the mass on the needle coordinate is the
            // matched attention fraction.
            let mass = out[s * l * VOCAB + needle] as f64;
            let score = (GAIN * (mass - threshold)) as f32;
            logits.push(-score);
            logits.push(score);
        }
        logits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::InferResponse;
    use crate::kernels::dispatch::for_variant;
    use crate::workload::{Workload, WorkloadConfig};

    fn accuracy(variant: &str, n: usize) -> f64 {
        let model = NativeClassifier::new(256, 0xD5A);
        let kernel = for_variant(variant, 0).expect("variant");
        let mut wl = Workload::new(WorkloadConfig {
            seq_len: 256,
            seed: 1234,
            ..Default::default()
        });
        let mut correct = 0usize;
        for _ in 0..n {
            let r = wl.next_request();
            let logits = model.logits(&r.tokens, kernel.as_ref());
            if InferResponse::argmax(&logits) as i32 == r.label {
                correct += 1;
            }
        }
        correct as f64 / n as f64
    }

    #[test]
    fn dense_classifier_solves_the_task() {
        assert!(accuracy("dense", 24) >= 0.95, "dense accuracy too low");
    }

    #[test]
    fn dsa90_classifier_solves_the_task() {
        assert!(accuracy("dsa90", 24) >= 0.9, "dsa90 accuracy too low");
    }

    /// One batched dispatch over `n` sequences produces exactly the
    /// logits of `n` per-sequence dispatches — the engine's batched
    /// execution changes performance, never predictions.
    #[test]
    fn batched_logits_match_per_sequence_bitwise() {
        let model = NativeClassifier::new(256, 0xD5A);
        let mut wl = Workload::new(WorkloadConfig {
            seq_len: 256,
            seed: 777,
            ..Default::default()
        });
        let n = 5;
        let mut tokens = Vec::with_capacity(n * 256);
        for _ in 0..n {
            tokens.extend(wl.next_request().tokens);
        }
        for variant in ["dense", "dsa90"] {
            for threads in [1, 0] {
                let kernel = for_variant(variant, threads).unwrap();
                let batched = model.logits_batch(&tokens, n, kernel.as_ref());
                assert_eq!(batched.len(), n * 2);
                let mut looped = Vec::with_capacity(n * 2);
                for seq in tokens.chunks_exact(256) {
                    looped.extend(model.logits(seq, kernel.as_ref()));
                }
                assert_eq!(batched, looped, "{variant} t{threads}");
            }
        }
    }

    #[test]
    fn logits_are_antisymmetric_and_finite() {
        let model = NativeClassifier::new(256, 0xD5A);
        let kernel = for_variant("dsa95", 1).unwrap();
        let tokens: Vec<i32> = (0..256).map(|i| 1 + (i % 255) as i32).collect();
        let logits = model.logits(&tokens, kernel.as_ref());
        assert_eq!(logits.len(), 2);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert!((logits[0] + logits[1]).abs() < 1e-6);
    }
}
