//! Hand-constructed needle-counting classifier over the native attention
//! kernels — the model served by the hermetic engine backend.
//!
//! The synthetic serving task ([`crate::workload`], mirroring
//! python/compile/data.py `gen_text`) plants `tokens[0]` as a needle;
//! label 1 ⇔ the needle recurs at least `l/16` times. Attention solves
//! this exactly without training: with random ±1 sign embeddings,
//! `q_i · k_j` is large only where `t_i == t_j`, so query row 0's softmax
//! mass over needle columns is monotone in the needle count. With one-hot
//! value vectors, `out[0][needle]` *is* that mass, and thresholding it
//! classifies the sequence.
//!
//! The threshold is variant-aware: a dynamic-sparse mask keeping `keep`
//! entries per row renormalizes the softmax over a shorter non-match tail,
//! inflating the mass, so the decision boundary is computed from the mask
//! budget the dispatched kernel reports. This keeps the classifier
//! accurate through the same dense and DSA kernels the benches measure
//! (down to ~95% sparsity at l = 256; sparser masks saturate the mass and
//! lose label-0 accuracy — the paper's accuracy/sparsity trade-off,
//! observable natively).

use super::dispatch::{AttnBatch, KernelDispatch};
use super::kvcache::KvCache;
use super::scratch::Scratch;
use crate::util::rng::Rng;

/// Reusable batch buffers for [`NativeClassifier::logits_batch_into`]:
/// the embedded Q/K, the one-hot V and the attention context output of a
/// whole engine bucket. Owned by the serving backend and grown
/// monotonically to the largest bucket seen, so the steady-state batch
/// loop performs **zero per-batch output allocations** (the warm-dispatch
/// analogue of the kernels' [`Scratch`](super::scratch::Scratch) —
/// observable through the same kind of grow counter, asserted by the
/// backend tests).
#[derive(Debug, Default)]
pub struct ModelScratch {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Attention context output (`n * l * VOCAB`) the kernels'
    /// `forward_batch_into` writes into.
    ctx: Vec<f32>,
    grows: u64,
}

impl ModelScratch {
    pub fn new() -> ModelScratch {
        ModelScratch::default()
    }

    /// Buffer-grow events observed by this instance (monotone; warm
    /// buffers reused at the same or smaller bucket record none).
    pub fn grow_events(&self) -> u64 {
        self.grows
    }

    /// Ensure capacity for an `n`-sequence bucket (`qk` = `n * l * DK`,
    /// `ctx` = `n * l * VOCAB`). Shrinks nothing.
    fn reserve(&mut self, qk: usize, ctx: usize) {
        let mut grows = 0u64;
        for (buf, need) in [
            (&mut self.q, qk),
            (&mut self.k, qk),
            (&mut self.v, ctx),
            (&mut self.ctx, ctx),
        ] {
            if buf.capacity() < need {
                grows += 1;
                let additional = need - buf.len();
                buf.reserve(additional);
            }
        }
        self.grows += grows;
    }
}

/// Token vocabulary (matches the workload generator's `1..=255` range and
/// doubles as the one-hot value dimension).
pub const VOCAB: usize = 256;
/// Embedding width: same-token raw scores land at `sqrt(DK)` after the
/// kernels' `1/sqrt(dk)` scaling; cross-token scores are ~N(0, 1).
const DK: usize = 64;
/// Target softmax weight of a matching column relative to a typical
/// non-match (sets the query scale β = ln(MATCH_WEIGHT)/sqrt(DK)).
const MATCH_WEIGHT: f64 = 40.0;
/// Logit scale.
const GAIN: f64 = 6.0;

/// Deterministic needle-counting classifier. Cheap to construct; the
/// embedding table is fixed by `seed`.
pub struct NativeClassifier {
    seq_len: usize,
    /// `VOCAB x DK` random sign embeddings (±1).
    emb: Vec<f32>,
}

impl NativeClassifier {
    pub fn new(seq_len: usize, seed: u64) -> NativeClassifier {
        assert!(seq_len >= 16, "seq_len {seq_len} too short for the task");
        let mut emb = Vec::with_capacity(VOCAB * DK);
        for t in 0..VOCAB {
            let mut rng = Rng::new(seed ^ ((t as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)));
            for _ in 0..DK {
                emb.push(if rng.next_u64() & 1 == 1 { 1.0 } else { -1.0 });
            }
        }
        NativeClassifier { seq_len, emb }
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    pub fn classes(&self) -> usize {
        2
    }

    /// Decision boundary on the needle softmax mass for a mask keeping
    /// `keep` entries per row: the mass a pivot count of matches (midway
    /// between the task's label-0 max and label-1 min) would produce.
    fn threshold(&self, keep: usize) -> f64 {
        let l = self.seq_len;
        let hi = (l / 16).max(8) as f64;
        let lo = (hi / 4.0).max(2.0);
        let pivot = (lo + hi) / 2.0;
        pivot * MATCH_WEIGHT / (pivot * MATCH_WEIGHT + (keep as f64 - pivot).max(0.0))
    }

    /// Run one sequence through `kernel` and return `[logit_0, logit_1]`.
    pub fn logits(&self, tokens: &[i32], kernel: &dyn KernelDispatch) -> Vec<f32> {
        self.logits_batch(tokens, 1, kernel)
    }

    /// Run `n` concatenated sequences (`n * seq_len` tokens) through
    /// `kernel` as **one** batched dispatch, returning `n * 2` logits.
    /// Allocating convenience over
    /// [`NativeClassifier::logits_batch_into`] (fresh buffers per call) —
    /// the serving backend uses the `_into` form with warm buffers.
    pub fn logits_batch(
        &self,
        tokens: &[i32],
        n: usize,
        kernel: &dyn KernelDispatch,
    ) -> Vec<f32> {
        let mut scratch = ModelScratch::new();
        let mut logits = Vec::new();
        self.logits_batch_into(tokens, n, kernel, &mut scratch, &mut logits);
        logits
    }

    /// The allocation-free batched primitive: run `n` concatenated
    /// sequences through `kernel` as **one** batched dispatch
    /// ([`KernelDispatch::forward_batch_into`] straight into
    /// `scratch.ctx`), writing `n * 2` logits into `logits` (cleared
    /// first). Each sequence is an independent single-head attention
    /// problem (`b = n`, `h = 1`), so the result is bit-identical to
    /// calling [`NativeClassifier::logits`] per sequence — the kernels'
    /// batched drivers guarantee it — while the dispatch overhead is paid
    /// once per engine batch and, with warm buffers, **no** per-batch
    /// output allocation is paid at all (asserted by the backend's
    /// warm-dispatch test).
    pub fn logits_batch_into(
        &self,
        tokens: &[i32],
        n: usize,
        kernel: &dyn KernelDispatch,
        scratch: &mut ModelScratch,
        logits: &mut Vec<f32>,
    ) {
        let l = self.seq_len;
        assert_eq!(tokens.len(), n * l, "token length");
        let beta = (MATCH_WEIGHT.ln() / (DK as f64).sqrt()) as f32;
        scratch.reserve(n * l * DK, n * l * VOCAB);
        let (q, k, v) = (&mut scratch.q, &mut scratch.k, &mut scratch.v);
        q.clear();
        k.clear();
        v.clear();
        v.resize(n * l * VOCAB, 0.0); // within reserved capacity: no alloc
        for (s, seq) in tokens.chunks_exact(l).enumerate() {
            for (i, &t) in seq.iter().enumerate() {
                let t = t.rem_euclid(VOCAB as i32) as usize;
                let e = &self.emb[t * DK..(t + 1) * DK];
                k.extend_from_slice(e);
                q.extend(e.iter().map(|&x| x * beta));
                v[(s * l + i) * VOCAB + t] = 1.0;
            }
        }
        // Size-only adjustment, NO zeroing: `forward_batch_into` is
        // contractually required (and property-tested) to fully overwrite
        // the output, so re-zeroing a warm same-bucket buffer would just
        // re-add a memset to the hot path this buffer exists to thin out.
        let need = n * l * VOCAB;
        if scratch.ctx.len() != need {
            scratch.ctx.resize(need, 0.0);
        }
        let batch = AttnBatch {
            q: &q[..],
            k: &k[..],
            v: &v[..],
            b: n,
            h: 1,
            l,
            dk: DK,
            dv: VOCAB,
        };
        kernel.forward_batch_into(&batch, &mut scratch.ctx);
        let keep = kernel.keep(l).unwrap_or(l);
        let threshold = self.threshold(keep);
        logits.clear();
        logits.reserve(n * 2);
        for (s, seq) in tokens.chunks_exact(l).enumerate() {
            let needle = seq[0].rem_euclid(VOCAB as i32) as usize;
            // Row 0's context vector of each sequence is a distribution
            // over tokens; the mass on the needle coordinate is the
            // matched attention fraction.
            let mass = scratch.ctx[s * l * VOCAB + needle] as f64;
            let score = (GAIN * (mass - threshold)) as f32;
            logits.push(-score);
            logits.push(score);
        }
    }

    /// K/V cache row shape this model decodes over (`dk`, `dv`) — what a
    /// [`KvCachePool`](super::kvcache::KvCachePool) serving this model
    /// must be constructed with.
    pub fn cache_dims(&self) -> (usize, usize) {
        (DK, VOCAB)
    }

    /// Embed one token and append its K row (sign embedding) and V row
    /// (one-hot) to `cache`. `onehot` is a caller-owned `VOCAB`-length
    /// zero buffer (grown once, then reused allocation-free): the hot
    /// entry is set, copied into the cache, and cleared again.
    fn append_token(&self, cache: &mut KvCache, token: i32, onehot: &mut Vec<f32>) {
        let t = token.rem_euclid(VOCAB as i32) as usize;
        let e = &self.emb[t * DK..(t + 1) * DK];
        if onehot.len() != VOCAB {
            onehot.resize(VOCAB, 0.0);
        }
        onehot[t] = 1.0;
        cache.append(e, &onehot[..]);
        onehot[t] = 0.0;
    }

    /// Open a decode session: pin `prompt[0]` as the needle (its scaled
    /// embedding is the session's one query row, exactly the query row 0
    /// of the one-shot path) and prefill the cache with every prompt
    /// token's K/V. The caller supplies the cache (typically recycled
    /// from a [`KvCachePool`](super::kvcache::KvCachePool)) and gets it
    /// back via [`DecodeSession::into_cache`] on close.
    pub fn open_session(
        &self,
        prompt: &[i32],
        mut cache: KvCache,
        onehot: &mut Vec<f32>,
    ) -> DecodeSession {
        assert!(!prompt.is_empty(), "decode session needs a non-empty prompt");
        assert_eq!((cache.dk(), cache.dv()), (DK, VOCAB), "cache shape");
        assert!(cache.is_empty(), "session cache must start empty");
        let beta = (MATCH_WEIGHT.ln() / (DK as f64).sqrt()) as f32;
        let needle = prompt[0].rem_euclid(VOCAB as i32) as usize;
        let qrow: Vec<f32> = self.emb[needle * DK..(needle + 1) * DK]
            .iter()
            .map(|&x| x * beta)
            .collect();
        for &t in prompt {
            self.append_token(&mut cache, t, onehot);
        }
        DecodeSession { cache, needle, qrow }
    }

    /// Rebuild a session from its token journal: open on `prompt`, then
    /// append every `decoded` token **without** running the decode
    /// kernel. A decode step is `append_token` + a kernel read of the
    /// cache — the kernel never writes session state — so the rebuilt
    /// cache (rows, int8 mirror, scale) is **bitwise-identical** to one
    /// that decoded the same tokens step by step, in O(tokens) instead
    /// of O(tokens x cache_len). This is the replica-migration replay
    /// path; the full replay length is reserved up front as one cache
    /// grow event.
    pub fn reopen_session(
        &self,
        prompt: &[i32],
        decoded: &[i32],
        mut cache: KvCache,
        onehot: &mut Vec<f32>,
    ) -> DecodeSession {
        cache.reserve_rows(prompt.len() + decoded.len());
        let mut sess = self.open_session(prompt, cache, onehot);
        for &t in decoded {
            self.append_token(&mut sess.cache, t, onehot);
        }
        sess
    }

    /// Append `token` to the session's cache and re-run the needle query
    /// against the whole cache through `kernel`'s decode path, returning
    /// `[logit_0, logit_1]`. At `len == seq_len` this is **bitwise equal**
    /// to the one-shot [`NativeClassifier::logits`] on the concatenated
    /// sequence (the decode kernels reproduce row 0 of the fused forward
    /// exactly; see `kernels::decode`). `ctx` is the caller-owned
    /// `VOCAB`-length context row — like `onehot`, grown once and then
    /// reused so warm steps allocate nothing.
    pub fn decode_step(
        &self,
        sess: &mut DecodeSession,
        token: i32,
        kernel: &dyn KernelDispatch,
        scratch: &mut Scratch,
        onehot: &mut Vec<f32>,
        ctx: &mut Vec<f32>,
    ) -> [f32; 2] {
        self.append_token(&mut sess.cache, token, onehot);
        let l = sess.cache.len();
        if ctx.len() != VOCAB {
            ctx.resize(VOCAB, 0.0);
        }
        kernel.decode_into(&sess.qrow, &sess.cache, scratch, &mut ctx[..]);
        let keep = kernel.keep(l).unwrap_or(l);
        let threshold = self.threshold(keep);
        let mass = ctx[sess.needle] as f64;
        let score = (GAIN * (mass - threshold)) as f32;
        [-score, score]
    }
}

/// One live decode session: the pinned needle query row plus the growing
/// K/V cache. Created by [`NativeClassifier::open_session`]; stepped by
/// [`NativeClassifier::decode_step`]; the cache is recovered for pooled
/// reuse with [`DecodeSession::into_cache`].
#[derive(Debug)]
pub struct DecodeSession {
    cache: KvCache,
    needle: usize,
    qrow: Vec<f32>,
}

impl DecodeSession {
    /// Tokens resident in the session's cache (prompt + decoded steps).
    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }

    pub fn cache(&self) -> &KvCache {
        &self.cache
    }

    /// Bucket grow events on the session's cache (the serving metrics
    /// aggregate these with the pool's to expose cache allocation).
    pub fn cache_grow_events(&self) -> u64 {
        self.cache.grow_events()
    }

    /// Surrender the cache (for return to a
    /// [`KvCachePool`](super::kvcache::KvCachePool)).
    pub fn into_cache(self) -> KvCache {
        self.cache
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::InferResponse;
    use crate::kernels::dispatch::for_variant;
    use crate::workload::{Workload, WorkloadConfig};

    fn accuracy(variant: &str, n: usize) -> f64 {
        let model = NativeClassifier::new(256, 0xD5A);
        let kernel = for_variant(variant, 0).expect("variant");
        let mut wl = Workload::new(WorkloadConfig {
            seq_len: 256,
            seed: 1234,
            ..Default::default()
        });
        let mut correct = 0usize;
        for _ in 0..n {
            let r = wl.next_request();
            let logits = model.logits(&r.tokens, kernel.as_ref());
            if InferResponse::argmax(&logits) as i32 == r.label {
                correct += 1;
            }
        }
        correct as f64 / n as f64
    }

    #[test]
    fn dense_classifier_solves_the_task() {
        assert!(accuracy("dense", 24) >= 0.95, "dense accuracy too low");
    }

    #[test]
    fn dsa90_classifier_solves_the_task() {
        assert!(accuracy("dsa90", 24) >= 0.9, "dsa90 accuracy too low");
    }

    /// One batched dispatch over `n` sequences produces exactly the
    /// logits of `n` per-sequence dispatches — the engine's batched
    /// execution changes performance, never predictions.
    #[test]
    fn batched_logits_match_per_sequence_bitwise() {
        let model = NativeClassifier::new(256, 0xD5A);
        let mut wl = Workload::new(WorkloadConfig {
            seq_len: 256,
            seed: 777,
            ..Default::default()
        });
        let n = 5;
        let mut tokens = Vec::with_capacity(n * 256);
        for _ in 0..n {
            tokens.extend(wl.next_request().tokens);
        }
        for variant in ["dense", "dsa90"] {
            for threads in [1, 0] {
                let kernel = for_variant(variant, threads).unwrap();
                let batched = model.logits_batch(&tokens, n, kernel.as_ref());
                assert_eq!(batched.len(), n * 2);
                let mut looped = Vec::with_capacity(n * 2);
                for seq in tokens.chunks_exact(256) {
                    looped.extend(model.logits(seq, kernel.as_ref()));
                }
                assert_eq!(batched, looped, "{variant} t{threads}");
            }
        }
    }

    /// Warm-dispatch allocation freedom at the model layer: once
    /// `ModelScratch` (and the logits buffer) have seen a bucket size,
    /// repeated batches of the same or smaller size record **zero**
    /// buffer grows and reproduce the allocating path bit for bit.
    #[test]
    fn warm_model_scratch_batches_are_allocation_free() {
        let model = NativeClassifier::new(256, 0xD5A);
        let kernel = for_variant("dsa90", 2).unwrap();
        let mut wl = Workload::new(WorkloadConfig {
            seq_len: 256,
            seed: 555,
            ..Default::default()
        });
        let n = 4;
        let mut tokens = Vec::with_capacity(n * 256);
        for _ in 0..n {
            tokens.extend(wl.next_request().tokens);
        }
        let mut scratch = ModelScratch::new();
        let mut logits = Vec::new();
        model.logits_batch_into(&tokens, n, kernel.as_ref(), &mut scratch, &mut logits);
        let first = logits.clone();
        let warm = scratch.grow_events();
        let warm_cap = logits.capacity();
        assert!(warm >= 1, "cold buffers must have grown");
        for shrink in [n, n, 2, 1] {
            model.logits_batch_into(
                &tokens[..shrink * 256],
                shrink,
                kernel.as_ref(),
                &mut scratch,
                &mut logits,
            );
            assert_eq!(&logits[..], &first[..shrink * 2], "warm reuse changed logits");
        }
        assert_eq!(scratch.grow_events(), warm, "warm batch dispatch allocated");
        assert_eq!(logits.capacity(), warm_cap, "logits buffer regrew");
        assert_eq!(first, model.logits_batch(&tokens, n, kernel.as_ref()));
    }

    /// Stepwise decode reproduces the one-shot classifier **bitwise** at
    /// full length, for dense and DSA alike: open on a prompt prefix,
    /// decode the remaining tokens one at a time, and the final step's
    /// logits equal `logits()` on the concatenated sequence to the bit
    /// (the decode kernels compute exactly row 0 of the fused forward;
    /// the incremental int8 key mirror is bitwise-equal to the one-shot
    /// quantization).
    #[test]
    fn decode_matches_one_shot_bitwise() {
        let model = NativeClassifier::new(256, 0xD5A);
        let mut wl = Workload::new(WorkloadConfig {
            seq_len: 256,
            seed: 9090,
            ..Default::default()
        });
        let (dk, dv) = model.cache_dims();
        for variant in ["dense", "dsa90"] {
            let kernel = for_variant(variant, 0).unwrap();
            for _ in 0..3 {
                let tokens = wl.next_request().tokens;
                let oneshot = model.logits(&tokens, kernel.as_ref());
                let split = 192;
                let (mut onehot, mut ctx) = (Vec::new(), Vec::new());
                let mut scratch = Scratch::new();
                let mut sess =
                    model.open_session(&tokens[..split], KvCache::new(dk, dv), &mut onehot);
                assert_eq!(sess.len(), split);
                let mut last = [0.0f32; 2];
                for &t in &tokens[split..] {
                    last = model.decode_step(
                        &mut sess,
                        t,
                        kernel.as_ref(),
                        &mut scratch,
                        &mut onehot,
                        &mut ctx,
                    );
                    assert!(last.iter().all(|x| x.is_finite()), "{variant}");
                    assert!((last[0] + last[1]).abs() < 1e-6, "{variant}");
                }
                assert_eq!(sess.len(), 256);
                assert_eq!(
                    [last[0].to_bits(), last[1].to_bits()],
                    [oneshot[0].to_bits(), oneshot[1].to_bits()],
                    "{variant}: decode diverged from one-shot"
                );
            }
        }
    }

    /// Journal replay reconstructs session state **bitwise**: reopening
    /// from (prompt, decoded-so-far) at any split point yields a cache
    /// whose rows, int8 mirror and scale equal the stepped session's,
    /// and whose subsequent decode steps produce bit-identical logits —
    /// the determinism contract replica migration rides on. Also pins
    /// the single-grow reservation.
    #[test]
    fn reopened_session_matches_stepped_session_bitwise() {
        let model = NativeClassifier::new(256, 0xD5A);
        let mut wl = Workload::new(WorkloadConfig {
            seq_len: 256,
            seed: 31337,
            ..Default::default()
        });
        let (dk, dv) = model.cache_dims();
        for variant in ["dense", "dsa90"] {
            let kernel = for_variant(variant, 0).unwrap();
            let tokens = wl.next_request().tokens;
            let prompt = &tokens[..128];
            for kill_at in [0usize, 1, 7, 64] {
                // Stepped reference: open + decode every token to the end.
                let (mut onehot, mut ctx) = (Vec::new(), Vec::new());
                let mut scratch = Scratch::new();
                let mut stepped =
                    model.open_session(prompt, KvCache::new(dk, dv), &mut onehot);
                let mut want = Vec::new();
                for &t in &tokens[128..] {
                    want.push(model.decode_step(
                        &mut stepped,
                        t,
                        kernel.as_ref(),
                        &mut scratch,
                        &mut onehot,
                        &mut ctx,
                    ));
                }
                // Migrated run: decode `kill_at` steps, reopen from the
                // journal on a fresh cache, decode the rest.
                let decoded = &tokens[128..128 + kill_at];
                let reopened = model.reopen_session(
                    prompt,
                    decoded,
                    KvCache::new(dk, dv),
                    &mut onehot,
                );
                assert_eq!(reopened.len(), 128 + kill_at);
                assert_eq!(
                    reopened.cache().grow_events(),
                    1,
                    "{variant}: replay reservation must be one grow"
                );
                let s = stepped.cache();
                let r = reopened.cache();
                assert_eq!(&s.k()[..r.k().len()], r.k(), "{variant}@{kill_at}: K rows");
                assert_eq!(&s.v()[..r.v().len()], r.v(), "{variant}@{kill_at}: V rows");
                let mut sess = reopened;
                for (i, &t) in tokens[128 + kill_at..].iter().enumerate() {
                    let got = model.decode_step(
                        &mut sess,
                        t,
                        kernel.as_ref(),
                        &mut scratch,
                        &mut onehot,
                        &mut ctx,
                    );
                    let w = want[kill_at + i];
                    assert_eq!(
                        [got[0].to_bits(), got[1].to_bits()],
                        [w[0].to_bits(), w[1].to_bits()],
                        "{variant}@{kill_at}: step {i} diverged after reopen"
                    );
                }
            }
        }
    }

    /// A session run over a recycled cache and warm scratch allocates
    /// nothing: after one full cold session has sized the cache buckets,
    /// the kernel scratch and the one-hot/context rows, replaying the
    /// whole session (open + every decode step) records **zero** further
    /// grow events and reproduces the logits bit for bit.
    #[test]
    fn warm_model_decode_sessions_are_allocation_free() {
        let model = NativeClassifier::new(256, 0xD5A);
        let kernel = for_variant("dsa90", 0).unwrap();
        let mut wl = Workload::new(WorkloadConfig {
            seq_len: 256,
            seed: 4242,
            ..Default::default()
        });
        let tokens = wl.next_request().tokens;
        let (dk, dv) = model.cache_dims();
        let (mut onehot, mut ctx) = (Vec::new(), Vec::new());
        let mut scratch = Scratch::new();
        let run = |cache: KvCache,
                   scratch: &mut Scratch,
                   onehot: &mut Vec<f32>,
                   ctx: &mut Vec<f32>| {
            let mut sess = model.open_session(&tokens[..128], cache, onehot);
            let mut last = [0.0f32; 2];
            for &t in &tokens[128..] {
                last = model.decode_step(&mut sess, t, kernel.as_ref(), scratch, onehot, ctx);
            }
            (sess.into_cache(), last)
        };
        let (mut cache, cold) =
            run(KvCache::new(dk, dv), &mut scratch, &mut onehot, &mut ctx);
        let (warm_cache, warm_scratch) = (cache.grow_events(), scratch.grow_events());
        let (oh_cap, ctx_cap) = (onehot.capacity(), ctx.capacity());
        assert!(warm_cache >= 1 && warm_scratch >= 1, "cold run must grow");
        cache.reset();
        let (cache, warm) = run(cache, &mut scratch, &mut onehot, &mut ctx);
        assert_eq!(cache.grow_events(), warm_cache, "recycled cache re-grew");
        assert_eq!(scratch.grow_events(), warm_scratch, "warm scratch re-grew");
        assert_eq!(onehot.capacity(), oh_cap);
        assert_eq!(ctx.capacity(), ctx_cap);
        assert_eq!(
            [cold[0].to_bits(), cold[1].to_bits()],
            [warm[0].to_bits(), warm[1].to_bits()],
            "recycled session changed the logits"
        );
    }

    #[test]
    fn logits_are_antisymmetric_and_finite() {
        let model = NativeClassifier::new(256, 0xD5A);
        let kernel = for_variant("dsa95", 1).unwrap();
        let tokens: Vec<i32> = (0..256).map(|i| 1 + (i % 255) as i32).collect();
        let logits = model.logits(&tokens, kernel.as_ref());
        assert_eq!(logits.len(), 2);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert!((logits[0] + logits[1]).abs() < 1e-6);
    }
}
