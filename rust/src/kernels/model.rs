//! Hand-constructed needle-counting classifier over the native attention
//! kernels — the model served by the hermetic engine backend.
//!
//! The synthetic serving task ([`crate::workload`], mirroring
//! python/compile/data.py `gen_text`) plants `tokens[0]` as a needle;
//! label 1 ⇔ the needle recurs at least `l/16` times. Attention solves
//! this exactly without training: with random ±1 sign embeddings,
//! `q_i · k_j` is large only where `t_i == t_j`, so query row 0's softmax
//! mass over needle columns is monotone in the needle count. With one-hot
//! value vectors, `out[0][needle]` *is* that mass, and thresholding it
//! classifies the sequence.
//!
//! The threshold is variant-aware: a dynamic-sparse mask keeping `keep`
//! entries per row renormalizes the softmax over a shorter non-match tail,
//! inflating the mass, so the decision boundary is computed from the mask
//! budget the dispatched kernel reports. This keeps the classifier
//! accurate through the same dense and DSA kernels the benches measure
//! (down to ~95% sparsity at l = 256; sparser masks saturate the mass and
//! lose label-0 accuracy — the paper's accuracy/sparsity trade-off,
//! observable natively).

use super::dispatch::{AttnBatch, KernelDispatch};
use crate::util::rng::Rng;

/// Reusable batch buffers for [`NativeClassifier::logits_batch_into`]:
/// the embedded Q/K, the one-hot V and the attention context output of a
/// whole engine bucket. Owned by the serving backend and grown
/// monotonically to the largest bucket seen, so the steady-state batch
/// loop performs **zero per-batch output allocations** (the warm-dispatch
/// analogue of the kernels' [`Scratch`](super::scratch::Scratch) —
/// observable through the same kind of grow counter, asserted by the
/// backend tests).
#[derive(Debug, Default)]
pub struct ModelScratch {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Attention context output (`n * l * VOCAB`) the kernels'
    /// `forward_batch_into` writes into.
    ctx: Vec<f32>,
    grows: u64,
}

impl ModelScratch {
    pub fn new() -> ModelScratch {
        ModelScratch::default()
    }

    /// Buffer-grow events observed by this instance (monotone; warm
    /// buffers reused at the same or smaller bucket record none).
    pub fn grow_events(&self) -> u64 {
        self.grows
    }

    /// Ensure capacity for an `n`-sequence bucket (`qk` = `n * l * DK`,
    /// `ctx` = `n * l * VOCAB`). Shrinks nothing.
    fn reserve(&mut self, qk: usize, ctx: usize) {
        let mut grows = 0u64;
        for (buf, need) in [
            (&mut self.q, qk),
            (&mut self.k, qk),
            (&mut self.v, ctx),
            (&mut self.ctx, ctx),
        ] {
            if buf.capacity() < need {
                grows += 1;
                let additional = need - buf.len();
                buf.reserve(additional);
            }
        }
        self.grows += grows;
    }
}

/// Token vocabulary (matches the workload generator's `1..=255` range and
/// doubles as the one-hot value dimension).
pub const VOCAB: usize = 256;
/// Embedding width: same-token raw scores land at `sqrt(DK)` after the
/// kernels' `1/sqrt(dk)` scaling; cross-token scores are ~N(0, 1).
const DK: usize = 64;
/// Target softmax weight of a matching column relative to a typical
/// non-match (sets the query scale β = ln(MATCH_WEIGHT)/sqrt(DK)).
const MATCH_WEIGHT: f64 = 40.0;
/// Logit scale.
const GAIN: f64 = 6.0;

/// Deterministic needle-counting classifier. Cheap to construct; the
/// embedding table is fixed by `seed`.
pub struct NativeClassifier {
    seq_len: usize,
    /// `VOCAB x DK` random sign embeddings (±1).
    emb: Vec<f32>,
}

impl NativeClassifier {
    pub fn new(seq_len: usize, seed: u64) -> NativeClassifier {
        assert!(seq_len >= 16, "seq_len {seq_len} too short for the task");
        let mut emb = Vec::with_capacity(VOCAB * DK);
        for t in 0..VOCAB {
            let mut rng = Rng::new(seed ^ ((t as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)));
            for _ in 0..DK {
                emb.push(if rng.next_u64() & 1 == 1 { 1.0 } else { -1.0 });
            }
        }
        NativeClassifier { seq_len, emb }
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    pub fn classes(&self) -> usize {
        2
    }

    /// Decision boundary on the needle softmax mass for a mask keeping
    /// `keep` entries per row: the mass a pivot count of matches (midway
    /// between the task's label-0 max and label-1 min) would produce.
    fn threshold(&self, keep: usize) -> f64 {
        let l = self.seq_len;
        let hi = (l / 16).max(8) as f64;
        let lo = (hi / 4.0).max(2.0);
        let pivot = (lo + hi) / 2.0;
        pivot * MATCH_WEIGHT / (pivot * MATCH_WEIGHT + (keep as f64 - pivot).max(0.0))
    }

    /// Run one sequence through `kernel` and return `[logit_0, logit_1]`.
    pub fn logits(&self, tokens: &[i32], kernel: &dyn KernelDispatch) -> Vec<f32> {
        self.logits_batch(tokens, 1, kernel)
    }

    /// Run `n` concatenated sequences (`n * seq_len` tokens) through
    /// `kernel` as **one** batched dispatch, returning `n * 2` logits.
    /// Allocating convenience over
    /// [`NativeClassifier::logits_batch_into`] (fresh buffers per call) —
    /// the serving backend uses the `_into` form with warm buffers.
    pub fn logits_batch(
        &self,
        tokens: &[i32],
        n: usize,
        kernel: &dyn KernelDispatch,
    ) -> Vec<f32> {
        let mut scratch = ModelScratch::new();
        let mut logits = Vec::new();
        self.logits_batch_into(tokens, n, kernel, &mut scratch, &mut logits);
        logits
    }

    /// The allocation-free batched primitive: run `n` concatenated
    /// sequences through `kernel` as **one** batched dispatch
    /// ([`KernelDispatch::forward_batch_into`] straight into
    /// `scratch.ctx`), writing `n * 2` logits into `logits` (cleared
    /// first). Each sequence is an independent single-head attention
    /// problem (`b = n`, `h = 1`), so the result is bit-identical to
    /// calling [`NativeClassifier::logits`] per sequence — the kernels'
    /// batched drivers guarantee it — while the dispatch overhead is paid
    /// once per engine batch and, with warm buffers, **no** per-batch
    /// output allocation is paid at all (asserted by the backend's
    /// warm-dispatch test).
    pub fn logits_batch_into(
        &self,
        tokens: &[i32],
        n: usize,
        kernel: &dyn KernelDispatch,
        scratch: &mut ModelScratch,
        logits: &mut Vec<f32>,
    ) {
        let l = self.seq_len;
        assert_eq!(tokens.len(), n * l, "token length");
        let beta = (MATCH_WEIGHT.ln() / (DK as f64).sqrt()) as f32;
        scratch.reserve(n * l * DK, n * l * VOCAB);
        let (q, k, v) = (&mut scratch.q, &mut scratch.k, &mut scratch.v);
        q.clear();
        k.clear();
        v.clear();
        v.resize(n * l * VOCAB, 0.0); // within reserved capacity: no alloc
        for (s, seq) in tokens.chunks_exact(l).enumerate() {
            for (i, &t) in seq.iter().enumerate() {
                let t = t.rem_euclid(VOCAB as i32) as usize;
                let e = &self.emb[t * DK..(t + 1) * DK];
                k.extend_from_slice(e);
                q.extend(e.iter().map(|&x| x * beta));
                v[(s * l + i) * VOCAB + t] = 1.0;
            }
        }
        // Size-only adjustment, NO zeroing: `forward_batch_into` is
        // contractually required (and property-tested) to fully overwrite
        // the output, so re-zeroing a warm same-bucket buffer would just
        // re-add a memset to the hot path this buffer exists to thin out.
        let need = n * l * VOCAB;
        if scratch.ctx.len() != need {
            scratch.ctx.resize(need, 0.0);
        }
        let batch = AttnBatch {
            q: &q[..],
            k: &k[..],
            v: &v[..],
            b: n,
            h: 1,
            l,
            dk: DK,
            dv: VOCAB,
        };
        kernel.forward_batch_into(&batch, &mut scratch.ctx);
        let keep = kernel.keep(l).unwrap_or(l);
        let threshold = self.threshold(keep);
        logits.clear();
        logits.reserve(n * 2);
        for (s, seq) in tokens.chunks_exact(l).enumerate() {
            let needle = seq[0].rem_euclid(VOCAB as i32) as usize;
            // Row 0's context vector of each sequence is a distribution
            // over tokens; the mass on the needle coordinate is the
            // matched attention fraction.
            let mass = scratch.ctx[s * l * VOCAB + needle] as f64;
            let score = (GAIN * (mass - threshold)) as f32;
            logits.push(-score);
            logits.push(score);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::InferResponse;
    use crate::kernels::dispatch::for_variant;
    use crate::workload::{Workload, WorkloadConfig};

    fn accuracy(variant: &str, n: usize) -> f64 {
        let model = NativeClassifier::new(256, 0xD5A);
        let kernel = for_variant(variant, 0).expect("variant");
        let mut wl = Workload::new(WorkloadConfig {
            seq_len: 256,
            seed: 1234,
            ..Default::default()
        });
        let mut correct = 0usize;
        for _ in 0..n {
            let r = wl.next_request();
            let logits = model.logits(&r.tokens, kernel.as_ref());
            if InferResponse::argmax(&logits) as i32 == r.label {
                correct += 1;
            }
        }
        correct as f64 / n as f64
    }

    #[test]
    fn dense_classifier_solves_the_task() {
        assert!(accuracy("dense", 24) >= 0.95, "dense accuracy too low");
    }

    #[test]
    fn dsa90_classifier_solves_the_task() {
        assert!(accuracy("dsa90", 24) >= 0.9, "dsa90 accuracy too low");
    }

    /// One batched dispatch over `n` sequences produces exactly the
    /// logits of `n` per-sequence dispatches — the engine's batched
    /// execution changes performance, never predictions.
    #[test]
    fn batched_logits_match_per_sequence_bitwise() {
        let model = NativeClassifier::new(256, 0xD5A);
        let mut wl = Workload::new(WorkloadConfig {
            seq_len: 256,
            seed: 777,
            ..Default::default()
        });
        let n = 5;
        let mut tokens = Vec::with_capacity(n * 256);
        for _ in 0..n {
            tokens.extend(wl.next_request().tokens);
        }
        for variant in ["dense", "dsa90"] {
            for threads in [1, 0] {
                let kernel = for_variant(variant, threads).unwrap();
                let batched = model.logits_batch(&tokens, n, kernel.as_ref());
                assert_eq!(batched.len(), n * 2);
                let mut looped = Vec::with_capacity(n * 2);
                for seq in tokens.chunks_exact(256) {
                    looped.extend(model.logits(seq, kernel.as_ref()));
                }
                assert_eq!(batched, looped, "{variant} t{threads}");
            }
        }
    }

    /// Warm-dispatch allocation freedom at the model layer: once
    /// `ModelScratch` (and the logits buffer) have seen a bucket size,
    /// repeated batches of the same or smaller size record **zero**
    /// buffer grows and reproduce the allocating path bit for bit.
    #[test]
    fn warm_model_scratch_batches_are_allocation_free() {
        let model = NativeClassifier::new(256, 0xD5A);
        let kernel = for_variant("dsa90", 2).unwrap();
        let mut wl = Workload::new(WorkloadConfig {
            seq_len: 256,
            seed: 555,
            ..Default::default()
        });
        let n = 4;
        let mut tokens = Vec::with_capacity(n * 256);
        for _ in 0..n {
            tokens.extend(wl.next_request().tokens);
        }
        let mut scratch = ModelScratch::new();
        let mut logits = Vec::new();
        model.logits_batch_into(&tokens, n, kernel.as_ref(), &mut scratch, &mut logits);
        let first = logits.clone();
        let warm = scratch.grow_events();
        let warm_cap = logits.capacity();
        assert!(warm >= 1, "cold buffers must have grown");
        for shrink in [n, n, 2, 1] {
            model.logits_batch_into(
                &tokens[..shrink * 256],
                shrink,
                kernel.as_ref(),
                &mut scratch,
                &mut logits,
            );
            assert_eq!(&logits[..], &first[..shrink * 2], "warm reuse changed logits");
        }
        assert_eq!(scratch.grow_events(), warm, "warm batch dispatch allocated");
        assert_eq!(logits.capacity(), warm_cap, "logits buffer regrew");
        assert_eq!(first, model.logits_batch(&tokens, n, kernel.as_ref()));
    }

    #[test]
    fn logits_are_antisymmetric_and_finite() {
        let model = NativeClassifier::new(256, 0xD5A);
        let kernel = for_variant("dsa95", 1).unwrap();
        let tokens: Vec<i32> = (0..256).map(|i| 1 + (i % 255) as i32).collect();
        let logits = model.logits(&tokens, kernel.as_ref());
        assert_eq!(logits.len(), 2);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert!((logits[0] + logits[1]).abs() < 1e-6);
    }
}
