//! Persistent, channel-fed worker pool for the row-parallel kernel
//! drivers.
//!
//! Every `KernelDispatch` call used to pay a `std::thread::scope`
//! spawn/join; for small problems (`l <= 256`) that per-dispatch overhead
//! swamps the dynamic-sparse win the paper's practical-speedup claim
//! rests on. This pool keeps the execution units hot instead: workers are
//! spawned once, park on a condvar when the queue is empty, and each owns
//! one [`Scratch`] that stays warm across dispatches — so a steady-state
//! dispatch does zero thread creation and zero allocation (asserted by
//! the scratch grow-counter tests). Work items are the row-parallel
//! drivers' query-block-aligned row blocks (see `kernels::parallel`);
//! [`WorkerPool::warm`] pre-grows every buffer the fused tiled kernels
//! touch — their key-tile score buffer is the `[..tile]` prefix of the
//! same scratch row the unfused kernels use, so one `(l, keep)` warm-up
//! covers both shapes.
//!
//! Design:
//!
//! * **Queue** — a `Mutex<VecDeque>` + `Condvar` MPMC queue (std has no
//!   multi-consumer channel). Producers enqueue a whole dispatch at once
//!   and `notify_all`; idle workers park on the condvar.
//! * **Scoped tasks** — tasks may borrow the caller's stack (the drivers
//!   hand workers `&mut` output slices and `&` inputs). Safety comes from
//!   the completion latch: [`WorkerPool::run_scoped`] does not return
//!   until every task of the dispatch has finished, so no borrow outlives
//!   its frame — the same contract `std::thread::scope` enforces, without
//!   the spawn/join.
//! * **Panic-safe join** — each task runs under `catch_unwind`; the
//!   panic payload travels through the dispatch latch and is re-raised
//!   (diagnostics intact) on the calling thread, but never kills the
//!   worker, so the pool stays serviceable.
//! * **Nested dispatch** — a task that itself calls `run_scoped` (or any
//!   pool entry point) executes inline on the worker instead of
//!   re-enqueueing, which would risk deadlock with every worker blocked.
//! * **Graceful shutdown** — dropping the pool sets the shutdown flag,
//!   wakes all workers and joins them. The process-wide
//!   [`WorkerPool::global`] pool is never dropped.
//!
//! Stats ([`WorkerPool::stats`]) — worker count, dispatches, tasks
//! executed, queue high-water mark, per-worker scratch grows — feed
//! `coordinator::Metrics` and the server stats response.

use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

use super::scratch::Scratch;
use crate::util::faults::FaultInjector;
use crate::util::sync::{lock_recover, wait_recover};

/// A unit of work handed to one worker: runs once with that worker's
/// persistent scratch. The `'env` lifetime lets tasks borrow the caller's
/// stack; [`WorkerPool::run_scoped`] guarantees completion before return.
pub type ScopedTask<'env> = Box<dyn FnOnce(&mut Scratch) + Send + 'env>;

/// Fully-owned task as stored in the queue (lifetime erased; see the
/// SAFETY comment in [`WorkerPool::run_scoped`]).
type Task = Box<dyn FnOnce(&mut Scratch) + Send + 'static>;

thread_local! {
    /// True on pool worker threads — used to run nested dispatches inline.
    static IS_POOL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
    /// Per-thread scratch for inline (non-pooled) execution paths, so
    /// `threads <= 1` dispatches also reuse buffers across calls.
    static LOCAL_SCRATCH: std::cell::RefCell<Scratch> =
        std::cell::RefCell::new(Scratch::new());
}

/// Run `f` with this thread's persistent [`Scratch`] (grown monotonically,
/// reused across calls). Must not be re-entered from inside `f`.
pub fn with_local_scratch<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    LOCAL_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Is the current thread a pool worker?
fn on_pool_worker() -> bool {
    IS_POOL_WORKER.with(|w| w.get())
}

/// A caught panic payload, carried back to the dispatching thread.
type Payload = Box<dyn std::any::Any + Send + 'static>;

/// Completion latch of one dispatch: counts outstanding tasks down and
/// carries the first panic payload back to the dispatcher.
struct Latch {
    /// (remaining tasks, first caught panic payload)
    state: Mutex<(usize, Option<Payload>)>,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { state: Mutex::new((n, None)), cv: Condvar::new() }
    }

    fn complete(&self, panicked: Option<Payload>) {
        let mut g = lock_recover(&self.state);
        g.0 -= 1;
        if let Some(p) = panicked {
            g.1.get_or_insert(p);
        }
        if g.0 == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until every task completed; the first panic payload, if any.
    fn wait(&self) -> Option<Payload> {
        let mut g = lock_recover(&self.state);
        while g.0 > 0 {
            g = wait_recover(&self.cv, g);
        }
        g.1.take()
    }
}

/// State shared between the pool handle and its workers.
struct Shared {
    queue: Mutex<VecDeque<(Task, Arc<Latch>)>>,
    available: Condvar,
    shutdown: AtomicBool,
    dispatches: AtomicU64,
    tasks_executed: AtomicU64,
    queue_highwater: AtomicUsize,
    scratch_grows: AtomicU64,
    /// Chaos hook rolled at `pool.task` before each task executes (inside
    /// the worker's panic shield); `None` on every production pool.
    faults: Option<Arc<FaultInjector>>,
}

/// Point-in-time snapshot of pool counters (all monotone except
/// `workers`, which is fixed at construction).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Worker threads owned by the pool.
    pub workers: usize,
    /// `run_scoped` dispatches served through the queue.
    pub dispatches: u64,
    /// Tasks executed by workers (inline fallback tasks not counted).
    pub tasks_executed: u64,
    /// Deepest the task queue has ever been.
    pub queue_highwater: usize,
    /// Scratch-buffer grow events across all workers; flat once warm.
    pub scratch_grows: u64,
}

/// Long-lived worker pool; see the module docs.
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();

impl WorkerPool {
    /// Spawn a pool with `workers` parked worker threads (0 = one per
    /// available core, via the same resolution the drivers use for their
    /// chunk counts).
    pub fn new(workers: usize) -> WorkerPool {
        WorkerPool::with_faults(workers, None)
    }

    /// [`WorkerPool::new`] with a seeded chaos injector: each worker rolls
    /// the `pool.task` site before running a task, **inside** its panic
    /// shield — injected panics travel the same latch path real task
    /// panics do (re-raised at the dispatcher, worker survives), and
    /// injected errors surface as panics too, since pool tasks have no
    /// `Result` channel.
    pub fn with_faults(workers: usize, faults: Option<Arc<FaultInjector>>) -> WorkerPool {
        let workers = super::parallel::effective_threads(workers);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            dispatches: AtomicU64::new(0),
            tasks_executed: AtomicU64::new(0),
            queue_highwater: AtomicUsize::new(0),
            scratch_grows: AtomicU64::new(0),
            faults,
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("dsa-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawning pool worker")
            })
            .collect();
        WorkerPool { shared, workers: handles }
    }

    /// The process-wide pool every `_mt` driver dispatches through by
    /// default (one worker per core, spawned on first use, never dropped).
    pub fn global() -> &'static WorkerPool {
        GLOBAL.get_or_init(|| WorkerPool::new(0))
    }

    /// Stats of the global pool **if it has been started** — observers
    /// (metrics, stats endpoints) must not themselves spawn a pool a
    /// non-native serving path would never use.
    pub fn try_global_stats() -> Option<PoolStats> {
        GLOBAL.get().map(WorkerPool::stats)
    }

    /// Worker threads owned by this pool.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Snapshot the pool counters.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            workers: self.workers.len(),
            dispatches: self.shared.dispatches.load(Ordering::Relaxed),
            tasks_executed: self.shared.tasks_executed.load(Ordering::Relaxed),
            queue_highwater: self.shared.queue_highwater.load(Ordering::Relaxed),
            scratch_grows: self.shared.scratch_grows.load(Ordering::Relaxed),
        }
    }

    /// Execute one dispatch: enqueue `tasks`, wake the workers, and block
    /// until every task has completed. If any task panicked, the first
    /// panic's payload is re-raised here — after all of them finished, so
    /// borrowed data is never touched past this call (the
    /// `std::thread::scope` contract, original diagnostics preserved).
    ///
    /// Called from a pool worker (nested dispatch), the tasks run inline
    /// on that worker instead — every worker blocking on a sub-dispatch
    /// could otherwise deadlock the queue.
    pub fn run_scoped<'env>(&self, tasks: Vec<ScopedTask<'env>>) {
        if tasks.is_empty() {
            return;
        }
        if self.workers.is_empty() || on_pool_worker() {
            // Fresh scratch, not the thread-local one: a nested task may
            // itself enter `with_local_scratch` (e.g. a `threads <= 1`
            // driver), which must not find it already borrowed.
            let mut scratch = Scratch::new();
            for t in tasks {
                t(&mut scratch);
            }
            return;
        }
        let latch = Arc::new(Latch::new(tasks.len()));
        {
            let mut q = lock_recover(&self.shared.queue);
            for t in tasks {
                // SAFETY: erasing `'env` to `'static` is sound because
                // this function does not return until `latch.wait()`
                // observes every task completed (panicked tasks complete
                // via `catch_unwind` + poison), so no borrow in `t` is
                // used after its referent could be dropped. The queue is
                // drained by workers that never outlive the process.
                let t: Task = unsafe { std::mem::transmute::<ScopedTask<'env>, Task>(t) };
                q.push_back((t, latch.clone()));
            }
            self.shared.queue_highwater.fetch_max(q.len(), Ordering::Relaxed);
        }
        self.shared.dispatches.fetch_add(1, Ordering::Relaxed);
        self.shared.available.notify_all();
        if let Some(payload) = latch.wait() {
            panic::resume_unwind(payload);
        }
    }

    /// Deterministically pre-grow **every** worker's scratch (and the
    /// calling thread's inline scratch) for an `(l, keep)` problem, so the
    /// first real dispatch after warm-up is allocation-free. A barrier
    /// holds each warm task on its worker until all workers have one,
    /// guaranteeing full coverage.
    pub fn warm(&self, l: usize, keep: usize) {
        if !self.workers.is_empty() && !on_pool_worker() {
            let barrier = Arc::new(Barrier::new(self.workers.len()));
            let mut tasks: Vec<ScopedTask<'_>> = Vec::with_capacity(self.workers.len());
            for _ in 0..self.workers.len() {
                let barrier = barrier.clone();
                tasks.push(Box::new(move |scratch: &mut Scratch| {
                    scratch.reserve(l, keep);
                    barrier.wait();
                }));
            }
            self.run_scoped(tasks);
        }
        with_local_scratch(|scratch| scratch.reserve(l, keep));
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    IS_POOL_WORKER.with(|w| w.set(true));
    let mut scratch = Scratch::new();
    let mut grows_seen = 0u64;
    loop {
        let job = {
            let mut q = lock_recover(&shared.queue);
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                q = wait_recover(&shared.available, q); // parked
            }
        };
        let Some((task, latch)) = job else { return };
        let panicked = panic::catch_unwind(AssertUnwindSafe(|| {
            if let Some(f) = &shared.faults {
                if let Err(e) = f.fire("pool.task") {
                    panic!("{e:#}");
                }
            }
            task(&mut scratch)
        }))
        .err();
        shared.tasks_executed.fetch_add(1, Ordering::Relaxed);
        let grows = scratch.grow_events();
        shared.scratch_grows.fetch_add(grows - grows_seen, Ordering::Relaxed);
        grows_seen = grows;
        latch.complete(panicked);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64 as Counter;

    /// Box a closure as a pool task (keeps the test call sites readable).
    fn task<'env>(f: impl FnOnce(&mut Scratch) + Send + 'env) -> ScopedTask<'env> {
        Box::new(f)
    }

    #[test]
    fn executes_tasks_and_counts_them() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.workers(), 3);
        let hits = Counter::new(0);
        let mut tasks: Vec<ScopedTask<'_>> = Vec::new();
        for _ in 0..10 {
            tasks.push(task(|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            }));
        }
        pool.run_scoped(tasks);
        assert_eq!(hits.load(Ordering::Relaxed), 10);
        let s = pool.stats();
        assert_eq!(s.dispatches, 1);
        assert_eq!(s.tasks_executed, 10);
        assert!(s.queue_highwater >= 1);
    }

    #[test]
    fn workers_write_disjoint_borrowed_slices() {
        let pool = WorkerPool::new(4);
        let mut out = vec![0u32; 64];
        let mut tasks: Vec<ScopedTask<'_>> = Vec::new();
        for (i, slice) in out.chunks_mut(16).enumerate() {
            tasks.push(task(move |_| {
                for (j, x) in slice.iter_mut().enumerate() {
                    *x = (i * 16 + j) as u32;
                }
            }));
        }
        pool.run_scoped(tasks);
        assert!(out.iter().enumerate().all(|(i, &x)| x == i as u32));
    }

    #[test]
    fn panicking_task_poisons_dispatch_but_not_pool() {
        let pool = WorkerPool::new(2);
        let r = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_scoped(vec![task(|_| {}), task(|_| panic!("boom"))]);
        }));
        let payload = r.expect_err("panic must propagate to the dispatching thread");
        assert_eq!(
            payload.downcast_ref::<&str>(),
            Some(&"boom"),
            "original panic payload must be preserved"
        );
        // The pool stays serviceable: workers survived the panic.
        let ok = Counter::new(0);
        pool.run_scoped(vec![task(|_| {
            ok.fetch_add(1, Ordering::Relaxed);
        })]);
        assert_eq!(ok.load(Ordering::Relaxed), 1);
        assert_eq!(pool.stats().tasks_executed, 3);
    }

    #[test]
    fn nested_dispatch_runs_inline_without_deadlock() {
        let pool = WorkerPool::new(1); // 1 worker: a queued nested dispatch would deadlock
        let hits = Counter::new(0);
        pool.run_scoped(vec![task(|_| {
            pool.run_scoped(vec![
                task(|_| {
                    hits.fetch_add(1, Ordering::Relaxed);
                }),
                task(|_| {
                    hits.fetch_add(1, Ordering::Relaxed);
                }),
            ]);
        })]);
        assert_eq!(hits.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn warm_covers_every_worker() {
        let pool = WorkerPool::new(3);
        pool.warm(128, 16);
        let warm = pool.stats().scratch_grows;
        assert!(warm >= 3, "each worker must have grown at least once");
        // Warming again at the same (or smaller) size grows nothing.
        pool.warm(128, 16);
        pool.warm(64, 4);
        assert_eq!(pool.stats().scratch_grows, warm);
    }

    #[test]
    fn tasks_see_worker_scratch() {
        let pool = WorkerPool::new(1);
        let sum = Counter::new(0);
        pool.run_scoped(vec![task(|s| {
            s.reserve(8, 2);
            sum.fetch_add(s.row.len() as u64, Ordering::Relaxed);
        })]);
        assert!(sum.load(Ordering::Relaxed) >= 8);
    }

    #[test]
    fn global_pool_is_shared_and_sized() {
        let a = WorkerPool::global();
        let b = WorkerPool::global();
        assert!(std::ptr::eq(a, b));
        assert!(a.workers() >= 1);
    }

    /// A fault-armed pool injects at `pool.task` through the same latch
    /// path real panics take: the dispatch re-raises on the caller, the
    /// workers survive, and disarming restores clean service.
    #[test]
    fn fault_injection_panics_dispatch_but_not_workers() {
        use crate::util::faults::{FaultConfig, FaultInjector};
        let faults = Arc::new(FaultInjector::new(FaultConfig {
            panic_rate: 1.0,
            ..FaultConfig::quiet(17)
        }));
        let pool = WorkerPool::with_faults(2, Some(Arc::clone(&faults)));
        let r = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_scoped(vec![task(|_| {})]);
        }));
        assert!(r.is_err(), "injected pool panic must reach the dispatcher");
        assert_eq!(faults.site("pool.task").panics, 1);
        faults.set_armed(false);
        let ok = Counter::new(0);
        pool.run_scoped(vec![task(|_| {
            ok.fetch_add(1, Ordering::Relaxed);
        })]);
        assert_eq!(ok.load(Ordering::Relaxed), 1, "workers must survive injection");
    }

    #[test]
    fn drop_joins_workers_gracefully() {
        let pool = WorkerPool::new(2);
        pool.run_scoped(vec![task(|_| {})]);
        drop(pool); // must not hang or panic
    }
}
