//! [`KernelDispatch`]: one call surface over the native attention paths so
//! the engine backend, tests and benches can switch dense vs dynamic
//! sparse (and single- vs multi-threaded) without caring which kernels
//! run. Serving variant names ("dense", "dsa90", "dsa95", "dsa99", …)
//! resolve through [`for_variant`].

use super::{dense, parallel, sparse};

/// One single-head attention problem, row-major f32.
#[derive(Debug, Clone, Copy)]
pub struct AttnInput<'a> {
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub l: usize,
    pub dk: usize,
    pub dv: usize,
}

impl AttnInput<'_> {
    fn validate(&self) {
        assert_eq!(self.q.len(), self.l * self.dk, "q shape");
        assert_eq!(self.k.len(), self.l * self.dk, "k shape");
        assert_eq!(self.v.len(), self.l * self.dv, "v shape");
    }
}

/// A selectable attention implementation.
pub trait KernelDispatch: Send + Sync {
    /// Human-readable identifier (shows up in bench/metrics output).
    fn name(&self) -> String;

    /// Kept entries per mask row at sequence length `l`; `None` = dense.
    fn keep(&self, l: usize) -> Option<usize>;

    /// Compute the `l x dv` context matrix.
    fn forward(&self, x: &AttnInput) -> Vec<f32>;
}

/// Dense attention baseline (`threads`: 0 = one per core, 1 = reference
/// single-threaded path).
#[derive(Debug, Clone)]
pub struct DenseKernel {
    pub threads: usize,
}

impl KernelDispatch for DenseKernel {
    fn name(&self) -> String {
        format!("dense(t{})", self.threads)
    }

    fn keep(&self, _l: usize) -> Option<usize> {
        None
    }

    fn forward(&self, x: &AttnInput) -> Vec<f32> {
        x.validate();
        if self.threads == 1 {
            dense::attention(x.q, x.k, x.v, x.l, x.dk, x.dv)
        } else {
            parallel::dense_attention_mt(x.q, x.k, x.v, x.l, x.dk, x.dv, self.threads)
        }
    }
}

/// Dynamic-sparse attention at a target sparsity ratio in `(0, 1)`.
#[derive(Debug, Clone)]
pub struct SparseKernel {
    pub sparsity: f64,
    pub threads: usize,
}

impl SparseKernel {
    /// Mask budget: kept entries per row at sequence length `l`.
    pub fn keep_for(&self, l: usize) -> usize {
        (((1.0 - self.sparsity) * l as f64).round() as usize).clamp(1, l.max(1))
    }
}

impl KernelDispatch for SparseKernel {
    fn name(&self) -> String {
        format!("dsa{:.0}(t{})", self.sparsity * 100.0, self.threads)
    }

    fn keep(&self, l: usize) -> Option<usize> {
        Some(self.keep_for(l))
    }

    fn forward(&self, x: &AttnInput) -> Vec<f32> {
        x.validate();
        let keep = self.keep_for(x.l);
        if self.threads == 1 {
            sparse::dsa_attention(x.q, x.k, x.v, x.l, x.dk, x.dv, keep)
        } else {
            parallel::dsa_attention_mt(x.q, x.k, x.v, x.l, x.dk, x.dv, keep, self.threads)
        }
    }
}

/// Kernel for a serving variant name: `"dense"`, or `"dsa<pct>"` with
/// integer percent sparsity in `[1, 99]` (e.g. `"dsa90"`). Unknown names
/// return `None`.
pub fn for_variant(variant: &str, threads: usize) -> Option<Box<dyn KernelDispatch>> {
    if variant == "dense" {
        return Some(Box::new(DenseKernel { threads }));
    }
    let pct: u32 = variant.strip_prefix("dsa")?.parse().ok()?;
    if !(1..=99).contains(&pct) {
        return None;
    }
    Some(Box::new(SparseKernel {
        sparsity: pct as f64 / 100.0,
        threads,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn variant_resolution() {
        assert_eq!(for_variant("dense", 1).unwrap().name(), "dense(t1)");
        assert_eq!(for_variant("dsa90", 0).unwrap().name(), "dsa90(t0)");
        assert!(for_variant("dsa0", 1).is_none());
        assert!(for_variant("dsa100", 1).is_none());
        assert!(for_variant("nope", 1).is_none());
        assert!(for_variant("dsaXY", 1).is_none());
    }

    #[test]
    fn keep_budgets() {
        let k = SparseKernel { sparsity: 0.90, threads: 1 };
        assert_eq!(k.keep_for(256), 26);
        assert_eq!(k.keep_for(1), 1);
        let k = SparseKernel { sparsity: 0.99, threads: 1 };
        assert_eq!(k.keep_for(256), 3);
        assert_eq!(for_variant("dense", 1).unwrap().keep(256), None);
    }

    #[test]
    fn dispatch_paths_agree_at_full_keep() {
        let mut rng = Rng::new(31);
        let (l, dk, dv) = (24, 6, 5);
        let q: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..l * dv).map(|_| rng.normal() as f32).collect();
        let x = AttnInput { q: &q, k: &k, v: &v, l, dk, dv };
        let dense_out = DenseKernel { threads: 1 }.forward(&x);
        // sparsity small enough that keep rounds to l
        let sparse_out = SparseKernel { sparsity: 1e-9, threads: 2 }.forward(&x);
        assert_eq!(dense_out, sparse_out);
    }
}
