//! [`KernelDispatch`]: one call surface over the native attention paths so
//! the engine backend, tests and benches can switch dense vs dynamic
//! sparse (and single- vs multi-threaded) without caring which kernels
//! run. Serving variant names ("dense", "dsa90", "dsa95", "dsa99", …)
//! resolve through [`for_variant`]. Problems come in two shapes: one
//! single-head [`AttnInput`], or a batched multi-head [`AttnBatch`] that
//! runs as **one** dispatch with workers balanced over `(batch, head,
//! row-range)` — bit-identical to dispatching each head separately.
//!
//! Every dispatch runs the **fused** tiled online-softmax kernels (see
//! `kernels::dense` / `kernels::sparse`) — the unfused three-pass forms
//! survive only as the property-test oracle and bench comparator, reached
//! directly (`dense::attention`, `sparse::dsa_attention`,
//! `parallel::*_unfused_mt_exec`), never through this surface.
//!
//! Multi-threaded forwards (`threads != 1`) execute on the process-wide
//! persistent [`WorkerPool`](super::pool::WorkerPool): one pool of parked
//! workers serves every kernel the engine, benches and tests dispatch, so
//! no `forward` call pays thread spawn/join (see `kernels::pool`);
//! `threads == 1` runs inline on the calling thread's warm local scratch.

use super::parallel;

/// One single-head attention problem, row-major f32.
#[derive(Debug, Clone, Copy)]
pub struct AttnInput<'a> {
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub l: usize,
    pub dk: usize,
    pub dv: usize,
}

impl AttnInput<'_> {
    fn validate(&self) {
        assert_eq!(self.q.len(), self.l * self.dk, "q shape");
        assert_eq!(self.k.len(), self.l * self.dk, "k shape");
        assert_eq!(self.v.len(), self.l * self.dv, "v shape");
    }
}

/// A batched multi-head attention problem: `q`/`k` laid out
/// `[b, h, l, dk]` and `v` laid out `[b, h, l, dv]`, row-major. Every
/// `(batch, head)` pair is an independent single-head problem; batching
/// them into one dispatch amortizes thread spawn/join and scorer setup
/// and lets workers balance across the whole batch.
#[derive(Debug, Clone, Copy)]
pub struct AttnBatch<'a> {
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub b: usize,
    pub h: usize,
    pub l: usize,
    pub dk: usize,
    pub dv: usize,
}

impl<'a> AttnBatch<'a> {
    /// Independent single-head problems in this batch (`b * h`).
    pub fn problems(&self) -> usize {
        self.b * self.h
    }

    fn validate(&self) {
        let p = self.problems();
        assert_eq!(self.q.len(), p * self.l * self.dk, "q shape");
        assert_eq!(self.k.len(), p * self.l * self.dk, "k shape");
        assert_eq!(self.v.len(), p * self.l * self.dv, "v shape");
    }

    /// View of problem `i` (flattened `(batch, head)` index) as a
    /// single-head input.
    pub fn problem(&self, i: usize) -> AttnInput<'a> {
        let (q, k, v) = (self.q, self.k, self.v);
        let (lk, lv) = (self.l * self.dk, self.l * self.dv);
        AttnInput {
            q: &q[i * lk..(i + 1) * lk],
            k: &k[i * lk..(i + 1) * lk],
            v: &v[i * lv..(i + 1) * lv],
            l: self.l,
            dk: self.dk,
            dv: self.dv,
        }
    }
}

/// A selectable attention implementation.
pub trait KernelDispatch: Send + Sync {
    /// Human-readable identifier (shows up in bench/metrics output).
    fn name(&self) -> String;

    /// Kept entries per mask row at sequence length `l`; `None` = dense.
    fn keep(&self, l: usize) -> Option<usize>;

    /// Compute the `l x dv` context matrix.
    fn forward(&self, x: &AttnInput) -> Vec<f32>;

    /// Compute the `[b, h, l, dv]` context batch in one dispatch. The
    /// default loops [`KernelDispatch::forward`] per problem; the native
    /// kernels override it with a single row-parallel pass over the whole
    /// batch. Implementations must match the looped form bit for bit.
    fn forward_batch(&self, x: &AttnBatch) -> Vec<f32> {
        x.validate();
        let mut out = Vec::with_capacity(x.problems() * x.l * x.dv);
        for i in 0..x.problems() {
            out.extend(self.forward(&x.problem(i)));
        }
        out
    }
}

/// Dense attention baseline — fused tiled kernel with online softmax
/// (`threads`: 0 = one per core, 1 = single-threaded on the calling
/// thread's warm local scratch).
#[derive(Debug, Clone)]
pub struct DenseKernel {
    pub threads: usize,
}

impl KernelDispatch for DenseKernel {
    fn name(&self) -> String {
        format!("dense(t{})", self.threads)
    }

    fn keep(&self, _l: usize) -> Option<usize> {
        None
    }

    fn forward(&self, x: &AttnInput) -> Vec<f32> {
        x.validate();
        parallel::dense_attention_mt(x.q, x.k, x.v, x.l, x.dk, x.dv, self.threads)
    }

    fn forward_batch(&self, x: &AttnBatch) -> Vec<f32> {
        x.validate();
        parallel::dense_attention_batch_mt(
            x.q,
            x.k,
            x.v,
            x.b,
            x.h,
            x.l,
            x.dk,
            x.dv,
            self.threads,
        )
    }
}

/// Dynamic-sparse attention at a target sparsity ratio in `(0, 1)` —
/// fused per-row predict → top-k → SDDMM/online-softmax/SpMM pipeline.
#[derive(Debug, Clone)]
pub struct SparseKernel {
    pub sparsity: f64,
    pub threads: usize,
}

impl SparseKernel {
    /// Mask budget: kept entries per row at sequence length `l`.
    pub fn keep_for(&self, l: usize) -> usize {
        (((1.0 - self.sparsity) * l as f64).round() as usize).clamp(1, l.max(1))
    }
}

impl KernelDispatch for SparseKernel {
    fn name(&self) -> String {
        format!("dsa{:.0}(t{})", self.sparsity * 100.0, self.threads)
    }

    fn keep(&self, l: usize) -> Option<usize> {
        Some(self.keep_for(l))
    }

    fn forward(&self, x: &AttnInput) -> Vec<f32> {
        x.validate();
        let keep = self.keep_for(x.l);
        parallel::dsa_attention_mt(x.q, x.k, x.v, x.l, x.dk, x.dv, keep, self.threads)
    }

    fn forward_batch(&self, x: &AttnBatch) -> Vec<f32> {
        x.validate();
        parallel::dsa_attention_batch_mt(
            x.q,
            x.k,
            x.v,
            x.b,
            x.h,
            x.l,
            x.dk,
            x.dv,
            self.keep_for(x.l),
            self.threads,
        )
    }
}

/// Kernel for a serving variant name: `"dense"`, or `"dsa<pct>"` with
/// integer percent sparsity in `[1, 99]` (e.g. `"dsa90"`). Unknown names
/// return `None`.
pub fn for_variant(variant: &str, threads: usize) -> Option<Box<dyn KernelDispatch>> {
    if variant == "dense" {
        return Some(Box::new(DenseKernel { threads }));
    }
    let pct: u32 = variant.strip_prefix("dsa")?.parse().ok()?;
    if !(1..=99).contains(&pct) {
        return None;
    }
    Some(Box::new(SparseKernel {
        sparsity: pct as f64 / 100.0,
        threads,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn variant_resolution() {
        assert_eq!(for_variant("dense", 1).unwrap().name(), "dense(t1)");
        assert_eq!(for_variant("dsa90", 0).unwrap().name(), "dsa90(t0)");
        assert!(for_variant("dsa0", 1).is_none());
        assert!(for_variant("dsa100", 1).is_none());
        assert!(for_variant("nope", 1).is_none());
        assert!(for_variant("dsaXY", 1).is_none());
    }

    #[test]
    fn keep_budgets() {
        let k = SparseKernel { sparsity: 0.90, threads: 1 };
        assert_eq!(k.keep_for(256), 26);
        assert_eq!(k.keep_for(1), 1);
        let k = SparseKernel { sparsity: 0.99, threads: 1 };
        assert_eq!(k.keep_for(256), 3);
        assert_eq!(for_variant("dense", 1).unwrap().keep(256), None);
    }

    /// Batched multi-head output equals per-head single dispatch bit for
    /// bit — for both kernels, across st/mt.
    #[test]
    fn forward_batch_matches_per_head_dispatch_bitwise() {
        let mut rng = Rng::new(41);
        let (b, h, l, dk, dv) = (2, 4, 21, 6, 5);
        let p = b * h;
        let q: Vec<f32> = (0..p * l * dk).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..p * l * dk).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..p * l * dv).map(|_| rng.normal() as f32).collect();
        let batch = AttnBatch { q: &q, k: &k, v: &v, b, h, l, dk, dv };
        for variant in ["dense", "dsa90", "dsa99"] {
            for threads in [1, 2, 8] {
                let kernel = for_variant(variant, threads).unwrap();
                let mut looped = Vec::with_capacity(p * l * dv);
                for i in 0..p {
                    looped.extend(kernel.forward(&batch.problem(i)));
                }
                let batched = kernel.forward_batch(&batch);
                assert_eq!(looped, batched, "{variant} t{threads}");
            }
        }
    }

    /// The trait's default (looped) `forward_batch` agrees with the
    /// overridden single-dispatch implementations bit for bit.
    #[test]
    fn default_forward_batch_agrees_with_override() {
        struct Looped(DenseKernel);
        impl KernelDispatch for Looped {
            fn name(&self) -> String {
                "looped".into()
            }
            fn keep(&self, l: usize) -> Option<usize> {
                self.0.keep(l)
            }
            fn forward(&self, x: &AttnInput) -> Vec<f32> {
                self.0.forward(x)
            }
        }
        let mut rng = Rng::new(43);
        let (b, h, l, dk, dv) = (2, 2, 13, 4, 3);
        let p = b * h;
        let q: Vec<f32> = (0..p * l * dk).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..p * l * dk).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..p * l * dv).map(|_| rng.normal() as f32).collect();
        let batch = AttnBatch { q: &q, k: &k, v: &v, b, h, l, dk, dv };
        let dense = DenseKernel { threads: 2 };
        assert_eq!(
            Looped(dense.clone()).forward_batch(&batch),
            dense.forward_batch(&batch)
        );
    }

    #[test]
    fn empty_batch_is_empty() {
        let kernel = for_variant("dense", 2).unwrap();
        let batch = AttnBatch { q: &[], k: &[], v: &[], b: 0, h: 4, l: 8, dk: 2, dv: 2 };
        assert!(kernel.forward_batch(&batch).is_empty());
    }

    /// The dispatch surface now runs the fused kernels: every variant and
    /// thread count must stay within the reassociation tolerance of the
    /// retained unfused oracle (`dense::attention` /
    /// `sparse::dsa_attention`) — the guarantee the engine's numerics
    /// rest on after the fusion switch.
    #[test]
    fn fused_dispatch_matches_unfused_oracle() {
        use crate::kernels::{dense, sparse};
        let mut rng = Rng::new(47);
        let (l, dk, dv) = (67, 7, 6);
        let q: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..l * dv).map(|_| rng.normal() as f32).collect();
        let x = AttnInput { q: &q, k: &k, v: &v, l, dk, dv };
        for variant in ["dense", "dsa90", "dsa99"] {
            let kernel1 = for_variant(variant, 1).unwrap();
            let want = match kernel1.keep(l) {
                None => dense::attention(&q, &k, &v, l, dk, dv),
                Some(keep) => sparse::dsa_attention(&q, &k, &v, l, dk, dv, keep),
            };
            for threads in [1, 2, 8] {
                let got = for_variant(variant, threads).unwrap().forward(&x);
                for (a, b) in got.iter().zip(&want) {
                    assert!(
                        (a - b).abs() <= 1e-5 + 1e-5 * b.abs(),
                        "{variant} t{threads} diverged from the unfused oracle"
                    );
                }
            }
        }
    }

    #[test]
    fn dispatch_paths_agree_at_full_keep() {
        let mut rng = Rng::new(31);
        let (l, dk, dv) = (24, 6, 5);
        let q: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..l * dv).map(|_| rng.normal() as f32).collect();
        let x = AttnInput { q: &q, k: &k, v: &v, l, dk, dv };
        let dense_out = DenseKernel { threads: 1 }.forward(&x);
        // sparsity small enough that keep rounds to l
        let sparse_out = SparseKernel { sparsity: 1e-9, threads: 2 }.forward(&x);
        assert_eq!(dense_out, sparse_out);
    }
}
