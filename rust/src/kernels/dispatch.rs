//! The typed kernel dispatch surface: one call boundary between the
//! serving stack and the native attention paths.
//!
//! * [`Variant`] — the **single source of truth** for serving-variant
//!   identity: a typed enum (`Dense`, `Dsa { pct }`, room for future
//!   families) with `FromStr`/`Display`, so the engine, router, backend,
//!   server protocol, CLI and benches all carry the same value instead of
//!   re-parsing `"dsa90"` strings at every layer. A typo'd variant fails
//!   at the parse boundary (CLI flag, protocol field, router rung), never
//!   as a dead route at batch-execution time.
//! * [`KernelSpec`] — *how* to run a kernel: worker `threads`, the
//!   [`ExecPolicy`] (persistent pool vs per-dispatch spawn) and a
//!   per-shape [`TilePlan`] resolved to one [`Tile`](super::tiles::Tile)
//!   per `(l, dk)` **before** dispatch, which is what keeps fused outputs
//!   bit-identical across thread counts, backends and batch shapes.
//! * [`KernelDispatch`] — the kernel trait. The **write-into forms**
//!   ([`KernelDispatch::forward_into`] /
//!   [`KernelDispatch::forward_batch_into`]) are the primitives: they
//!   fully overwrite a caller-owned output slice, so a warm buffer makes
//!   the engine's steady-state batch loop allocation-free end to end. The
//!   Vec-returning [`KernelDispatch::forward`] /
//!   [`KernelDispatch::forward_batch`] survive as default-method
//!   allocate-and-fill wrappers for tests and one-shot callers.
//! * [`KernelRegistry`] — the pluggable construction point: variant
//!   families register a builder `fn(&Variant, &KernelSpec) ->
//!   Option<Box<dyn KernelDispatch>>`; new kernel families (e.g. N:M
//!   structured sparsity) plug in here without touching the engine,
//!   router, server or benches. [`for_variant`] survives only as a thin
//!   parse-then-build shim over the global registry.
//!
//! Problems come in two shapes: one single-head [`AttnInput`], or a
//! batched multi-head [`AttnBatch`] that runs as **one** dispatch with
//! workers balanced over `(batch, head, row-range)` — bit-identical to
//! dispatching each head separately. Every dispatch runs the **fused**
//! tiled online-softmax kernels; the unfused three-pass forms survive
//! only as property-test oracles and bench comparators, reached directly
//! (`dense::attention`, `sparse::dsa_attention`,
//! `parallel::*_unfused_mt_exec`), never through this surface.

use std::fmt;
use std::str::FromStr;
use std::sync::OnceLock;

use super::decode;
use super::kvcache::KvCache;
use super::parallel::{self, Exec};
use super::scratch::Scratch;
use super::tiles::{Tile, TilePlan};
use crate::util::error::Error;

/// One single-head attention problem, row-major f32.
#[derive(Debug, Clone, Copy)]
pub struct AttnInput<'a> {
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub l: usize,
    pub dk: usize,
    pub dv: usize,
}

impl AttnInput<'_> {
    fn validate(&self) {
        assert_eq!(self.q.len(), self.l * self.dk, "q shape");
        assert_eq!(self.k.len(), self.l * self.dk, "k shape");
        assert_eq!(self.v.len(), self.l * self.dv, "v shape");
    }
}

/// A batched multi-head attention problem: `q`/`k` laid out
/// `[b, h, l, dk]` and `v` laid out `[b, h, l, dv]`, row-major. Every
/// `(batch, head)` pair is an independent single-head problem; batching
/// them into one dispatch amortizes thread spawn/join and scorer setup
/// and lets workers balance across the whole batch.
#[derive(Debug, Clone, Copy)]
pub struct AttnBatch<'a> {
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub b: usize,
    pub h: usize,
    pub l: usize,
    pub dk: usize,
    pub dv: usize,
}

impl<'a> AttnBatch<'a> {
    /// Independent single-head problems in this batch (`b * h`).
    pub fn problems(&self) -> usize {
        self.b * self.h
    }

    fn validate(&self) {
        let p = self.problems();
        assert_eq!(self.q.len(), p * self.l * self.dk, "q shape");
        assert_eq!(self.k.len(), p * self.l * self.dk, "k shape");
        assert_eq!(self.v.len(), p * self.l * self.dv, "v shape");
    }

    /// View of problem `i` (flattened `(batch, head)` index) as a
    /// single-head input.
    pub fn problem(&self, i: usize) -> AttnInput<'a> {
        let (q, k, v) = (self.q, self.k, self.v);
        let (lk, lv) = (self.l * self.dk, self.l * self.dv);
        AttnInput {
            q: &q[i * lk..(i + 1) * lk],
            k: &k[i * lk..(i + 1) * lk],
            v: &v[i * lv..(i + 1) * lv],
            l: self.l,
            dk: self.dk,
            dv: self.dv,
        }
    }
}

/// A serving variant, typed. This enum is the only place variant names
/// are parsed ([`Variant::from_str`]) or rendered ([`fmt::Display`]);
/// every other layer passes the value. `Dsa { pct }` carries the integer
/// percent sparsity in `[1, 99]` (`"dsa90"` ⇔ `Dsa { pct: 90 }`), which
/// keeps the type `Copy + Eq + Hash + Ord` — usable as a map key and in
/// protocol round trips without float comparison hazards.
///
/// The field is public for ergonomic literals (`Variant::Dsa { pct: 90 }`
/// is the crate idiom), so an out-of-range literal like
/// `Dsa { pct: 150 }` is *representable* — but it **fails closed**:
/// [`Variant::sparsity`] declines it, so no registry family claims it and
/// the backend reports "no registered kernel family" at preload/startup
/// instead of serving a variant whose name could never round-trip
/// through [`Variant::from_str`]. Use [`Variant::dsa`] to validate
/// runtime-derived percents up front.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Variant {
    /// Dense attention baseline.
    Dense,
    /// Dynamic-sparse attention at `pct`% target sparsity (valid range
    /// `[1, 99]`; out-of-range values build no kernel — see the enum
    /// docs).
    Dsa { pct: u8 },
}

impl Variant {
    /// A DSA variant at `pct`% sparsity; `None` outside `[1, 99]`.
    pub fn dsa(pct: u8) -> Option<Variant> {
        (1..=99).contains(&pct).then_some(Variant::Dsa { pct })
    }

    /// Target sparsity ratio in `(0, 1)`; `None` for dense **and** for
    /// out-of-range `Dsa` percents — the check that makes hand-rolled
    /// invalid literals fail closed at kernel construction. Delegates to
    /// [`Variant::dsa`] so the valid range lives in exactly one place.
    pub fn sparsity(&self) -> Option<f64> {
        match self {
            Variant::Dense => None,
            Variant::Dsa { pct } => Variant::dsa(*pct).map(|_| *pct as f64 / 100.0),
        }
    }

    /// Build this variant's kernel from the global [`KernelRegistry`].
    pub fn build(&self, spec: &KernelSpec) -> Option<Box<dyn KernelDispatch>> {
        KernelRegistry::global().build(self, spec)
    }
}

impl fmt::Display for Variant {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Variant::Dense => write!(f, "dense"),
            Variant::Dsa { pct } => write!(f, "dsa{pct}"),
        }
    }
}

impl FromStr for Variant {
    type Err = Error;

    /// Parse `"dense"` or `"dsa<pct>"` with integer percent in `[1, 99]`.
    /// The one place in the crate variant strings become values.
    fn from_str(s: &str) -> Result<Variant, Error> {
        if s == "dense" {
            return Ok(Variant::Dense);
        }
        let parsed = s
            .strip_prefix("dsa")
            .filter(|rest| !rest.is_empty() && rest.bytes().all(|b| b.is_ascii_digit()))
            .and_then(|rest| rest.parse::<u8>().ok())
            .and_then(Variant::dsa);
        parsed.ok_or_else(|| {
            Error::msg(format!(
                "unknown serving variant {s:?} (expected \"dense\" or \"dsa<pct>\" \
                 with pct in [1, 99], e.g. \"dsa90\")"
            ))
        })
    }
}

/// How a multi-threaded dispatch executes its row chunks — the
/// policy-level (owning) form of [`parallel::Exec`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecPolicy {
    /// The production default: tasks on the process-wide persistent
    /// [`WorkerPool`](super::pool::WorkerPool) (parked workers, warm
    /// per-worker scratch — no per-dispatch spawn/join).
    #[default]
    Pool,
    /// Per-dispatch `std::thread::scope` spawns — the legacy path, kept
    /// as the benchmarked comparator. Outputs are bit-identical to
    /// [`ExecPolicy::Pool`] (chunking depends only on the thread count).
    Spawn,
}

impl ExecPolicy {
    /// Resolve to the parallel drivers' execution backend.
    pub fn exec(self) -> Exec<'static> {
        match self {
            ExecPolicy::Pool => Exec::global_pool(),
            ExecPolicy::Spawn => Exec::Spawn,
        }
    }
}

/// *How* to run a kernel — the construction-time execution parameters
/// every kernel family consumes, replacing the bare `threads: usize` that
/// used to be plumbed through every layer:
///
/// * `threads` — workers per dispatch (0 = one per core, 1 = inline on
///   the calling thread's warm local scratch).
/// * `exec` — pool vs spawn ([`ExecPolicy`]).
/// * `tiles` — the per-shape [`TilePlan`]; each dispatch resolves one
///   tile from `(l, dk)` alone, so outputs never depend on thread count,
///   backend or batch shape.
///
/// `KernelSpec::default()` is the production configuration: all cores,
/// pool execution, the committed tile table ([`TilePlan::committed`] —
/// today equivalent to the `KEY_TILE = 256` / `QUERY_BLOCK = 8` fallback
/// for every shape).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KernelSpec {
    pub threads: usize,
    pub exec: ExecPolicy,
    pub tiles: TilePlan,
}

impl Default for KernelSpec {
    fn default() -> KernelSpec {
        KernelSpec {
            threads: 0,
            exec: ExecPolicy::Pool,
            tiles: TilePlan::committed(),
        }
    }
}

impl KernelSpec {
    /// The default spec at an explicit thread count — the shape every
    /// pre-`KernelSpec` call site (`for_variant(name, threads)`) maps to.
    pub fn with_threads(threads: usize) -> KernelSpec {
        KernelSpec { threads, ..KernelSpec::default() }
    }
}

/// A selectable attention implementation. [`KernelDispatch::forward_into`]
/// is the primitive every implementation provides; the batched form and
/// the Vec-returning conveniences have default implementations on top of
/// it. Implementations must fully overwrite the output slice (stale data
/// must never leak through), so callers may reuse warm buffers.
pub trait KernelDispatch: Send + Sync {
    /// Human-readable identifier (shows up in bench/metrics output).
    fn name(&self) -> String;

    /// Kept entries per mask row at sequence length `l`; `None` = dense.
    fn keep(&self, l: usize) -> Option<usize>;

    /// Compute the `l x dv` context matrix into `out` (`out.len() ==
    /// l * dv`; arbitrary stale contents allowed — every row is
    /// overwritten). The allocation-free primitive the serving hot path
    /// runs.
    fn forward_into(&self, x: &AttnInput, out: &mut [f32]);

    /// Compute the `[b, h, l, dv]` context batch into `out` in one
    /// dispatch. The default loops [`KernelDispatch::forward_into`] per
    /// problem; the native kernels override it with a single row-parallel
    /// pass over the whole batch. Implementations must match the looped
    /// form bit for bit.
    fn forward_batch_into(&self, x: &AttnBatch, out: &mut [f32]) {
        x.validate();
        let stride = x.l * x.dv;
        assert_eq!(out.len(), x.problems() * stride, "out shape");
        for i in 0..x.problems() {
            self.forward_into(&x.problem(i), &mut out[i * stride..(i + 1) * stride]);
        }
    }

    /// Allocating convenience over [`KernelDispatch::forward_into`].
    fn forward(&self, x: &AttnInput) -> Vec<f32> {
        let mut out = vec![0f32; x.l * x.dv];
        self.forward_into(x, &mut out);
        out
    }

    /// Allocating convenience over [`KernelDispatch::forward_batch_into`].
    fn forward_batch(&self, x: &AttnBatch) -> Vec<f32> {
        let mut out = vec![0f32; x.problems() * x.l * x.dv];
        self.forward_batch_into(x, &mut out);
        out
    }

    /// One autoregressive decode step: attention of the single query row
    /// `q` (`cache.dk()` entries) over every cached key/value row,
    /// written into `out` (`cache.dv()` entries, fully overwritten).
    ///
    /// Runs inline on the caller's [`Scratch`] — a decode step touches
    /// one query row, so there is nothing to parallelize and outputs are
    /// identical across [`KernelSpec`] thread counts and exec policies by
    /// construction (property-tested in `kernels::decode`). The default
    /// dispatches on [`KernelDispatch::keep`]: `None` runs the fused
    /// dense decode, `Some(keep)` the fused DSA decode (the int8
    /// predictor scores only the new row against the cached key mirror,
    /// top-k selects cached columns) at the default tile. The native
    /// kernels override it to use their committed per-shape [`TilePlan`]
    /// tile, which must match what their full forward would resolve at
    /// the same `(l, dk)` — that shared lookup is what keeps N decode
    /// steps bitwise-equal to the full fused dense forward.
    fn decode_into(&self, q: &[f32], cache: &KvCache, scratch: &mut Scratch, out: &mut [f32]) {
        match self.keep(cache.len()) {
            None => decode::decode_dense_tiled_scratch(q, cache, out, scratch, Tile::DEFAULT),
            Some(keep) => {
                decode::decode_dsa_tiled_scratch(q, cache, keep, out, scratch, Tile::DEFAULT.key_tile)
            }
        }
    }
}

/// Dense attention baseline — fused tiled kernel with online softmax,
/// executed per the [`KernelSpec`].
#[derive(Debug, Clone, Default)]
pub struct DenseKernel {
    pub spec: KernelSpec,
}

impl DenseKernel {
    pub fn new(spec: KernelSpec) -> DenseKernel {
        DenseKernel { spec }
    }

    /// Default spec at an explicit thread count (0 = one per core).
    pub fn with_threads(threads: usize) -> DenseKernel {
        DenseKernel::new(KernelSpec::with_threads(threads))
    }
}

impl KernelDispatch for DenseKernel {
    fn name(&self) -> String {
        format!("dense(t{})", self.spec.threads)
    }

    fn keep(&self, _l: usize) -> Option<usize> {
        None
    }

    // lint: hot-path
    fn forward_into(&self, x: &AttnInput, out: &mut [f32]) {
        x.validate();
        let tile = self.spec.tiles.lookup(x.l, x.dk);
        parallel::dense_attention_into_exec(
            x.q,
            x.k,
            x.v,
            x.l,
            x.dk,
            x.dv,
            self.spec.threads,
            self.spec.exec.exec(),
            tile,
            out,
        );
    }

    // lint: hot-path
    fn forward_batch_into(&self, x: &AttnBatch, out: &mut [f32]) {
        x.validate();
        let tile = self.spec.tiles.lookup(x.l, x.dk);
        parallel::dense_attention_batch_into_exec(
            x.q,
            x.k,
            x.v,
            x.b,
            x.h,
            x.l,
            x.dk,
            x.dv,
            self.spec.threads,
            self.spec.exec.exec(),
            tile,
            out,
        );
    }

    // lint: hot-path
    fn decode_into(&self, q: &[f32], cache: &KvCache, scratch: &mut Scratch, out: &mut [f32]) {
        // Same per-shape tile the full forward resolves at this (l, dk),
        // so a decode step stays bitwise-equal to its forward row even
        // once tuned TilePlan rows land.
        let tile = self.spec.tiles.lookup(cache.len(), cache.dk());
        decode::decode_dense_tiled_scratch(q, cache, out, scratch, tile);
    }
}

/// Dynamic-sparse attention at a target sparsity ratio in `(0, 1)` —
/// fused per-row predict → top-k → SDDMM/online-softmax/SpMM pipeline,
/// executed per the [`KernelSpec`].
#[derive(Debug, Clone)]
pub struct SparseKernel {
    pub sparsity: f64,
    pub spec: KernelSpec,
}

impl SparseKernel {
    pub fn new(sparsity: f64, spec: KernelSpec) -> SparseKernel {
        SparseKernel { sparsity, spec }
    }

    /// Default spec at an explicit thread count (0 = one per core).
    pub fn with_threads(sparsity: f64, threads: usize) -> SparseKernel {
        SparseKernel::new(sparsity, KernelSpec::with_threads(threads))
    }

    /// Mask budget: kept entries per row at sequence length `l`, i.e.
    /// `round((1 - sparsity) * l)` clamped into `[1, max(l, 1)]`.
    ///
    /// The clamp pins the degenerate edges on purpose:
    ///
    /// * `sparsity → 1.0` (or tiny `l`): the rounded budget hits 0, and
    ///   the lower clamp keeps **one** entry per row — a mask that keeps
    ///   nothing would serve all-zero contexts while claiming success.
    /// * `l = 0`: the empty problem reports `keep = 1` (the clamp range
    ///   collapses to `[1, 1]`), but no row exists to apply it to — the
    ///   fused pipeline iterates zero rows and returns an empty context,
    ///   without panicking (pinned by the `Variant`-layer tests).
    pub fn keep_for(&self, l: usize) -> usize {
        (((1.0 - self.sparsity) * l as f64).round() as usize).clamp(1, l.max(1))
    }
}

impl KernelDispatch for SparseKernel {
    fn name(&self) -> String {
        format!("dsa{:.0}(t{})", self.sparsity * 100.0, self.spec.threads)
    }

    fn keep(&self, l: usize) -> Option<usize> {
        Some(self.keep_for(l))
    }

    // lint: hot-path
    fn forward_into(&self, x: &AttnInput, out: &mut [f32]) {
        x.validate();
        let keep = self.keep_for(x.l);
        let tile = self.spec.tiles.lookup(x.l, x.dk);
        parallel::dsa_attention_into_exec(
            x.q,
            x.k,
            x.v,
            x.l,
            x.dk,
            x.dv,
            keep,
            self.spec.threads,
            self.spec.exec.exec(),
            tile,
            out,
        );
    }

    // lint: hot-path
    fn forward_batch_into(&self, x: &AttnBatch, out: &mut [f32]) {
        x.validate();
        let tile = self.spec.tiles.lookup(x.l, x.dk);
        parallel::dsa_attention_batch_into_exec(
            x.q,
            x.k,
            x.v,
            x.b,
            x.h,
            x.l,
            x.dk,
            x.dv,
            self.keep_for(x.l),
            self.spec.threads,
            self.spec.exec.exec(),
            tile,
            out,
        );
    }

    // lint: hot-path
    fn decode_into(&self, q: &[f32], cache: &KvCache, scratch: &mut Scratch, out: &mut [f32]) {
        let l = cache.len();
        let tile = self.spec.tiles.lookup(l, cache.dk());
        decode::decode_dsa_tiled_scratch(q, cache, self.keep_for(l), out, scratch, tile.key_tile);
    }
}

/// A variant-family builder: inspect the [`Variant`] and either claim it
/// (returning a kernel built per the [`KernelSpec`]) or decline with
/// `None` so the next family is consulted.
pub type KernelBuilder =
    Box<dyn Fn(&Variant, &KernelSpec) -> Option<Box<dyn KernelDispatch>> + Send + Sync>;

/// The pluggable kernel construction point: an ordered list of variant
/// families, each with a builder. [`KernelRegistry::build`] asks the
/// families in registration order and the first `Some` wins — so a new
/// kernel family (a future `Variant` arm, an alternate dense
/// implementation, …) plugs in with one [`KernelRegistry::register`]
/// call instead of edits to the engine, router, server and benches.
///
/// The process-wide [`KernelRegistry::global`] registry ships the native
/// families ([`KernelRegistry::native`]); embedders hand a custom
/// registry to the serving stack via
/// `NativeModelConfig::registry` (an `Arc<KernelRegistry>` the backend
/// consults instead of the global one), so extending serving does not
/// require editing this crate.
#[derive(Default)]
pub struct KernelRegistry {
    families: Vec<(String, KernelBuilder)>,
}

impl fmt::Debug for KernelRegistry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("KernelRegistry")
            .field("families", &self.families().collect::<Vec<_>>())
            .finish()
    }
}

impl KernelRegistry {
    /// A registry with no families (builds nothing).
    pub fn empty() -> KernelRegistry {
        KernelRegistry::default()
    }

    /// The native families: `"dense"` ([`DenseKernel`]) and `"dsa"`
    /// ([`SparseKernel`]).
    pub fn native() -> KernelRegistry {
        let mut r = KernelRegistry::empty();
        r.register("dense", |variant, spec| match variant {
            Variant::Dense => Some(Box::new(DenseKernel::new(spec.clone()))),
            _ => None,
        });
        r.register("dsa", |variant, spec| {
            let sparsity = variant.sparsity()?;
            Some(Box::new(SparseKernel::new(sparsity, spec.clone())))
        });
        r
    }

    /// Register a variant family (appended after existing families).
    pub fn register<F>(&mut self, family: &str, build: F)
    where
        F: Fn(&Variant, &KernelSpec) -> Option<Box<dyn KernelDispatch>> + Send + Sync + 'static,
    {
        self.families.push((family.to_string(), Box::new(build)));
    }

    /// Build a kernel for `variant`: first claiming family wins; `None`
    /// when no registered family recognizes the variant.
    pub fn build(&self, variant: &Variant, spec: &KernelSpec) -> Option<Box<dyn KernelDispatch>> {
        self.families.iter().find_map(|(_, b)| b(variant, spec))
    }

    /// Registered family names, in consultation order.
    pub fn families(&self) -> impl Iterator<Item = &str> {
        self.families.iter().map(|(n, _)| n.as_str())
    }

    /// The process-wide registry (native families preregistered).
    pub fn global() -> &'static KernelRegistry {
        static GLOBAL: OnceLock<KernelRegistry> = OnceLock::new();
        GLOBAL.get_or_init(KernelRegistry::native)
    }
}

/// Thin compatibility shim: parse a variant name ([`Variant::from_str`] —
/// the only string parse) and build it from the global registry at the
/// default spec with an explicit thread count. Typed callers should parse
/// once at their boundary and use [`Variant::build`] /
/// [`KernelRegistry::build`] directly.
pub fn for_variant(variant: &str, threads: usize) -> Option<Box<dyn KernelDispatch>> {
    let v = variant.parse::<Variant>().ok()?;
    KernelRegistry::global().build(&v, &KernelSpec::with_threads(threads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::tiles::{Tile, TilePlan};
    use crate::util::rng::Rng;

    #[test]
    fn variant_parse_and_display_roundtrip() {
        assert_eq!("dense".parse::<Variant>().unwrap(), Variant::Dense);
        assert_eq!("dsa90".parse::<Variant>().unwrap(), Variant::Dsa { pct: 90 });
        assert_eq!("dsa1".parse::<Variant>().unwrap(), Variant::Dsa { pct: 1 });
        assert_eq!("dsa99".parse::<Variant>().unwrap(), Variant::Dsa { pct: 99 });
        for v in [Variant::Dense, Variant::Dsa { pct: 90 }, Variant::Dsa { pct: 5 }] {
            assert_eq!(v.to_string().parse::<Variant>().unwrap(), v);
        }
        for bad in [
            "dsa0", "dsa100", "dsa255", "dsa256", "nope", "dsaXY", "dsa", "dsa-5", "dsa+90",
            "dsa9.5", "DENSE", "", "dense ",
        ] {
            assert!(bad.parse::<Variant>().is_err(), "{bad:?} must not parse");
        }
        // leading zeros normalize rather than reject (digit-only parse)
        assert_eq!("dsa090".parse::<Variant>().unwrap(), Variant::Dsa { pct: 90 });
        assert_eq!(Variant::dsa(90), Some(Variant::Dsa { pct: 90 }));
        assert_eq!(Variant::dsa(0), None);
        assert_eq!(Variant::dsa(100), None);
        assert_eq!(Variant::Dense.sparsity(), None);
        assert_eq!(Variant::Dsa { pct: 95 }.sparsity(), Some(0.95));
    }

    /// An out-of-range `Dsa { pct }` literal (representable because the
    /// field is public) fails closed: `sparsity()` declines it, no
    /// registry family claims it, so it surfaces as a startup/preload
    /// error — never as a served variant whose name cannot round-trip.
    #[test]
    fn out_of_range_dsa_literal_builds_no_kernel() {
        let spec = KernelSpec::with_threads(1);
        for pct in [0u8, 100, 150, 255] {
            let v = Variant::Dsa { pct };
            assert_eq!(v.sparsity(), None, "pct {pct} must be declined");
            assert!(
                KernelRegistry::global().build(&v, &spec).is_none(),
                "pct {pct} must not build a kernel"
            );
        }
        // In-range literals still build.
        assert!(KernelRegistry::global()
            .build(&Variant::Dsa { pct: 42 }, &spec)
            .is_some());
    }

    #[test]
    fn variant_resolution() {
        assert_eq!(for_variant("dense", 1).unwrap().name(), "dense(t1)");
        assert_eq!(for_variant("dsa90", 0).unwrap().name(), "dsa90(t0)");
        assert!(for_variant("dsa0", 1).is_none());
        assert!(for_variant("dsa100", 1).is_none());
        assert!(for_variant("nope", 1).is_none());
        assert!(for_variant("dsaXY", 1).is_none());
    }

    #[test]
    fn registry_is_pluggable_and_ordered() {
        let spec = KernelSpec::with_threads(1);
        // The global registry serves the native families.
        let names: Vec<&str> = KernelRegistry::global().families().collect();
        assert_eq!(names, vec!["dense", "dsa"]);
        assert!(Variant::Dense.build(&spec).is_some());
        assert!(Variant::Dsa { pct: 90 }.build(&spec).is_some());
        // An empty registry builds nothing; registering a family plugs a
        // new kernel in at exactly one point.
        let mut r = KernelRegistry::empty();
        assert!(r.build(&Variant::Dense, &spec).is_none());
        r.register("shadow-dense", |variant, spec| match variant {
            Variant::Dense => {
                let mut spec = spec.clone();
                spec.threads = 1;
                Some(Box::new(DenseKernel::new(spec)))
            }
            _ => None,
        });
        let k = r.build(&Variant::Dense, &spec).expect("family claims dense");
        assert_eq!(k.name(), "dense(t1)");
        assert!(r.build(&Variant::Dsa { pct: 90 }, &spec).is_none());
        // First claiming family wins: prepend-like shadowing is explicit
        // registration order, not string matching.
        r.register("dsa", |variant, spec| {
            let sparsity = variant.sparsity()?;
            Some(Box::new(SparseKernel::new(sparsity, spec.clone())))
        });
        assert!(r.build(&Variant::Dsa { pct: 95 }, &spec).is_some());
    }

    #[test]
    fn keep_budgets() {
        let k = SparseKernel::with_threads(0.90, 1);
        assert_eq!(k.keep_for(256), 26);
        assert_eq!(k.keep_for(1), 1);
        let k = SparseKernel::with_threads(0.99, 1);
        assert_eq!(k.keep_for(256), 3);
        assert_eq!(for_variant("dense", 1).unwrap().keep(256), None);
    }

    /// The documented `keep_for` clamp edges, pinned at the `Variant`
    /// layer: `l = 0` and `sparsity → 1.0` both clamp to a 1-entry
    /// budget, and the degenerate shapes still route through the fused
    /// dispatch path without panicking.
    #[test]
    fn keep_clamp_edges_route_through_fused_path() {
        // sparsity → 1.0: the rounded budget is 0; the clamp keeps 1.
        let k = SparseKernel::with_threads(0.999_999, 1);
        assert_eq!(k.keep_for(256), 1);
        assert_eq!(k.keep_for(1), 1);
        // l = 0: the clamp range collapses to [1, 1] — keep reports 1
        // with no rows to apply it to.
        assert_eq!(k.keep_for(0), 1);
        assert_eq!(k.keep(0), Some(1));
        let spec = KernelSpec::default();
        for variant in [Variant::Dense, Variant::Dsa { pct: 90 }, Variant::Dsa { pct: 99 }] {
            let kernel = variant.build(&spec).expect("native variant");
            // empty problem: zero rows in, zero rows out, no panic
            let empty = AttnInput { q: &[], k: &[], v: &[], l: 0, dk: 4, dv: 4 };
            assert!(kernel.forward(&empty).is_empty(), "{variant}");
            kernel.forward_into(&empty, &mut []);
            // l = 1: one row, budget clamps to the single key
            let one = AttnInput { q: &[0.5], k: &[0.5], v: &[2.0], l: 1, dk: 1, dv: 1 };
            assert_eq!(kernel.forward(&one), vec![2.0], "{variant}");
            // empty batch (b = 0) through the batched fused path
            let batch = AttnBatch { q: &[], k: &[], v: &[], b: 0, h: 2, l: 0, dk: 4, dv: 4 };
            assert!(kernel.forward_batch(&batch).is_empty(), "{variant}");
        }
    }

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// Satellite property: `forward_into` (into a poisoned warm buffer)
    /// is bitwise equal to `forward`, and `forward_batch_into` to
    /// `forward_batch`, for every variant × thread count × exec policy.
    /// The allocation-free serving path can never drift from the
    /// allocating one.
    #[test]
    fn forward_into_matches_forward_bitwise_property() {
        let mut rng = Rng::new(0x1D5A);
        let (b, h, l, dk, dv) = (2, 2, 29, 6, 5);
        let p = b * h;
        let q = randv(&mut rng, p * l * dk);
        let k = randv(&mut rng, p * l * dk);
        let v = randv(&mut rng, p * l * dv);
        let batch = AttnBatch { q: &q, k: &k, v: &v, b, h, l, dk, dv };
        let single = batch.problem(1);
        for variant in [Variant::Dense, Variant::Dsa { pct: 90 }, Variant::Dsa { pct: 99 }] {
            for threads in [1, 2, 7, 0] {
                for exec in [ExecPolicy::Pool, ExecPolicy::Spawn] {
                    let spec = KernelSpec { threads, exec, ..KernelSpec::default() };
                    let kernel = variant.build(&spec).expect("native variant");
                    let want = kernel.forward(&single);
                    let mut got = vec![f32::NAN; l * dv];
                    kernel.forward_into(&single, &mut got);
                    assert_eq!(want, got, "{variant} t{threads} {exec:?} forward_into");
                    let want = kernel.forward_batch(&batch);
                    let mut got = vec![f32::NAN; p * l * dv];
                    kernel.forward_batch_into(&batch, &mut got);
                    assert_eq!(want, got, "{variant} t{threads} {exec:?} forward_batch_into");
                }
            }
        }
    }

    /// Satellite property: a `TilePlan` entry is resolved from the shape
    /// alone, so dispatches at a **non-default** tile stay bit-identical
    /// across thread counts and Spawn/Pool backends — and the fused
    /// outputs still match the unfused oracle within tolerance (the
    /// fused-vs-unfused guarantee survives tile tuning).
    #[test]
    fn tile_plan_dispatch_deterministic_across_threads_property() {
        use crate::kernels::{dense, sparse};
        let mut rng = Rng::new(0x71E5);
        let (l, dk, dv) = (53, 7, 6);
        let q = randv(&mut rng, l * dk);
        let k = randv(&mut rng, l * dk);
        let v = randv(&mut rng, l * dv);
        let x = AttnInput { q: &q, k: &k, v: &v, l, dk, dv };
        let tile = Tile { key_tile: 11, query_block: 3 }; // deliberately odd
        let tiles = TilePlan::empty().with_entry(l, dk, tile);
        // The plan resolves the same tile for the same shape, always.
        for _ in 0..3 {
            assert_eq!(tiles.lookup(l, dk), tile);
        }
        // Single-threaded fused references at the planned tile.
        let dense_ref = dense::attention_fused_tiled(&q, &k, &v, l, dk, dv, tile);
        let keep = SparseKernel::with_threads(0.90, 1).keep_for(l);
        let dsa_ref = sparse::dsa_attention_fused_tile(&q, &k, &v, l, dk, dv, keep, tile.key_tile);
        for variant in [Variant::Dense, Variant::Dsa { pct: 90 }] {
            let want = if variant == Variant::Dense { &dense_ref } else { &dsa_ref };
            // Unfused oracle for the tolerance check.
            let oracle = match variant {
                Variant::Dense => dense::attention(&q, &k, &v, l, dk, dv),
                Variant::Dsa { .. } => sparse::dsa_attention(&q, &k, &v, l, dk, dv, keep),
            };
            for threads in [1, 2, 8, 0] {
                for exec in [ExecPolicy::Pool, ExecPolicy::Spawn] {
                    let spec = KernelSpec { threads, exec, tiles: tiles.clone() };
                    let got = variant.build(&spec).unwrap().forward(&x);
                    assert_eq!(
                        want, &got,
                        "{variant} t{threads} {exec:?} diverged at the planned tile"
                    );
                    for (a, b) in got.iter().zip(&oracle) {
                        assert!(
                            (a - b).abs() <= 1e-5 + 1e-5 * b.abs(),
                            "{variant} t{threads}: fused at non-default tile left the \
                             unfused oracle's tolerance"
                        );
                    }
                }
            }
        }
    }

    /// Batched multi-head output equals per-head single dispatch bit for
    /// bit — for both kernels, across st/mt.
    #[test]
    fn forward_batch_matches_per_head_dispatch_bitwise() {
        let mut rng = Rng::new(41);
        let (b, h, l, dk, dv) = (2, 4, 21, 6, 5);
        let p = b * h;
        let q: Vec<f32> = (0..p * l * dk).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..p * l * dk).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..p * l * dv).map(|_| rng.normal() as f32).collect();
        let batch = AttnBatch { q: &q, k: &k, v: &v, b, h, l, dk, dv };
        for variant in ["dense", "dsa90", "dsa99"] {
            for threads in [1, 2, 8] {
                let kernel = for_variant(variant, threads).unwrap();
                let mut looped = Vec::with_capacity(p * l * dv);
                for i in 0..p {
                    looped.extend(kernel.forward(&batch.problem(i)));
                }
                let batched = kernel.forward_batch(&batch);
                assert_eq!(looped, batched, "{variant} t{threads}");
            }
        }
    }

    /// The trait's default (looped) `forward_batch_into` agrees with the
    /// overridden single-dispatch implementations bit for bit.
    #[test]
    fn default_forward_batch_agrees_with_override() {
        struct Looped(DenseKernel);
        impl KernelDispatch for Looped {
            fn name(&self) -> String {
                "looped".into()
            }
            fn keep(&self, l: usize) -> Option<usize> {
                self.0.keep(l)
            }
            fn forward_into(&self, x: &AttnInput, out: &mut [f32]) {
                self.0.forward_into(x, out)
            }
        }
        let mut rng = Rng::new(43);
        let (b, h, l, dk, dv) = (2, 2, 13, 4, 3);
        let p = b * h;
        let q: Vec<f32> = (0..p * l * dk).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..p * l * dk).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..p * l * dv).map(|_| rng.normal() as f32).collect();
        let batch = AttnBatch { q: &q, k: &k, v: &v, b, h, l, dk, dv };
        let dense = DenseKernel::with_threads(2);
        assert_eq!(
            Looped(dense.clone()).forward_batch(&batch),
            dense.forward_batch(&batch)
        );
    }

    #[test]
    fn empty_batch_is_empty() {
        let kernel = for_variant("dense", 2).unwrap();
        let batch = AttnBatch { q: &[], k: &[], v: &[], b: 0, h: 4, l: 8, dk: 2, dv: 2 };
        assert!(kernel.forward_batch(&batch).is_empty());
    }

    /// The trait's default `decode_into` (keep-dispatched, default tile)
    /// agrees with the overridden native implementations bit for bit
    /// while the committed tile table is empty — a minimal external
    /// implementation (only `forward_into`) decodes for free.
    #[test]
    fn default_decode_agrees_with_override() {
        use crate::kernels::kvcache::KvCache;
        use crate::kernels::scratch::Scratch;

        struct Minimal(SparseKernel);
        impl KernelDispatch for Minimal {
            fn name(&self) -> String {
                "minimal".into()
            }
            fn keep(&self, l: usize) -> Option<usize> {
                self.0.keep(l)
            }
            fn forward_into(&self, x: &AttnInput, out: &mut [f32]) {
                self.0.forward_into(x, out)
            }
        }
        let mut rng = Rng::new(53);
        let (l, dk, dv) = (21, 4, 3);
        let mut cache = KvCache::new(dk, dv);
        for _ in 0..l {
            let kr: Vec<f32> = (0..dk).map(|_| rng.normal() as f32).collect();
            let vr: Vec<f32> = (0..dv).map(|_| rng.normal() as f32).collect();
            cache.append(&kr, &vr);
        }
        let q: Vec<f32> = (0..dk).map(|_| rng.normal() as f32).collect();
        let mut scratch = Scratch::new();
        let (mut a, mut b) = (vec![0f32; dv], vec![9f32; dv]);

        let sparse = SparseKernel::with_threads(0.90, 2);
        sparse.decode_into(&q, &cache, &mut scratch, &mut a);
        Minimal(sparse).decode_into(&q, &cache, &mut scratch, &mut b);
        assert_eq!(a, b);

        struct MinimalDense(DenseKernel);
        impl KernelDispatch for MinimalDense {
            fn name(&self) -> String {
                "minimal-dense".into()
            }
            fn keep(&self, l: usize) -> Option<usize> {
                self.0.keep(l)
            }
            fn forward_into(&self, x: &AttnInput, out: &mut [f32]) {
                self.0.forward_into(x, out)
            }
        }
        let dense = DenseKernel::with_threads(2);
        dense.decode_into(&q, &cache, &mut scratch, &mut a);
        MinimalDense(dense).decode_into(&q, &cache, &mut scratch, &mut b);
        assert_eq!(a, b);
    }

    /// The dispatch surface runs the fused kernels: every variant and
    /// thread count must stay within the reassociation tolerance of the
    /// retained unfused oracle (`dense::attention` /
    /// `sparse::dsa_attention`) — the guarantee the engine's numerics
    /// rest on after the fusion switch.
    #[test]
    fn fused_dispatch_matches_unfused_oracle() {
        use crate::kernels::{dense, sparse};
        let mut rng = Rng::new(47);
        let (l, dk, dv) = (67, 7, 6);
        let q: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..l * dv).map(|_| rng.normal() as f32).collect();
        let x = AttnInput { q: &q, k: &k, v: &v, l, dk, dv };
        for variant in ["dense", "dsa90", "dsa99"] {
            let kernel1 = for_variant(variant, 1).unwrap();
            let want = match kernel1.keep(l) {
                None => dense::attention(&q, &k, &v, l, dk, dv),
                Some(keep) => sparse::dsa_attention(&q, &k, &v, l, dk, dv, keep),
            };
            for threads in [1, 2, 8] {
                let got = for_variant(variant, threads).unwrap().forward(&x);
                for (a, b) in got.iter().zip(&want) {
                    assert!(
                        (a - b).abs() <= 1e-5 + 1e-5 * b.abs(),
                        "{variant} t{threads} diverged from the unfused oracle"
                    );
                }
            }
        }
    }

    #[test]
    fn dispatch_paths_agree_at_full_keep() {
        let mut rng = Rng::new(31);
        let (l, dk, dv) = (24, 6, 5);
        let q: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..l * dv).map(|_| rng.normal() as f32).collect();
        let x = AttnInput { q: &q, k: &k, v: &v, l, dk, dv };
        let dense_out = DenseKernel::with_threads(1).forward(&x);
        // sparsity small enough that keep rounds to l
        let sparse_out = SparseKernel::with_threads(1e-9, 2).forward(&x);
        assert_eq!(dense_out, sparse_out);
    }
}
