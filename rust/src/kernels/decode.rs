//! Fused single-query decode kernels over a [`KvCache`]: one
//! autoregressive step = attention of the session's query row against
//! every cached key/value row, dense and DSA forms.
//!
//! Both forms run **inline** (a decode step touches one query row —
//! there is nothing to split across workers), on the caller's
//! [`Scratch`], with zero per-step allocations once scratch and cache
//! are warm (grow-counter tested).
//!
//! Equivalence contracts, pinned by the property tests below:
//!
//! - **Dense** decode is literally [`dense::attention_rows_fused_tiled_scratch`]
//!   at `(r0, r1) = (0, 1)` over the cache, so a step at cache length `l`
//!   is **bitwise equal** to row `r` of the full fused forward on any
//!   `l`-row problem whose row `r` carries the same query — across
//!   thread counts, exec policies, and query blocking (the fused
//!   kernel's row-split/query-block invariance).
//! - **DSA** decode re-runs the paper's per-row pipeline against the
//!   cache: the int8 predictor scores *only the new query row* against
//!   the cached key mirror, top-k selects cached columns, and the kept
//!   entries are recomputed exactly under the fused online softmax —
//!   the same operation sequence as one row of
//!   [`sparse::dsa_attention_rows_fused_tile_scratch`]. With the query
//!   row quantized at the same scale the one-shot scorer would use
//!   (e.g. every query row shares one max-|q|, as in the serving
//!   classifier where |q| ≡ beta), the step is bitwise equal to the
//!   full fused DSA forward's row; for arbitrary queries it matches the
//!   unfused decode reference within online-softmax tolerance with a
//!   bitwise-identical mask.

use super::dense;
use super::kvcache::KvCache;
use super::scratch::Scratch;
use super::simd;
use super::sparse;
use super::tiles::Tile;
use crate::sparse::topk;

/// Fused dense decode at an explicit [`Tile`]: attention of the single
/// query row `q` (`dk` entries) over every cached row, written into
/// `out` (`dv` entries, fully overwritten). An empty cache yields zeros
/// (the fused kernel's empty-key-set semantics).
// lint: hot-path
pub fn decode_dense_tiled_scratch(
    q: &[f32],
    cache: &KvCache,
    out: &mut [f32],
    scratch: &mut Scratch,
    tile: Tile,
) {
    let (l, dk, dv) = (cache.len(), cache.dk(), cache.dv());
    assert_eq!(q.len(), dk, "q shape");
    assert_eq!(out.len(), dv, "out shape");
    dense::attention_rows_fused_tiled_scratch(q, cache.k(), cache.v(), l, dk, dv, 0, 1, out, scratch, tile);
}

/// [`decode_dense_tiled_scratch`] at [`Tile::DEFAULT`].
pub fn decode_dense_scratch(q: &[f32], cache: &KvCache, out: &mut [f32], scratch: &mut Scratch) {
    decode_dense_tiled_scratch(q, cache, out, scratch, Tile::DEFAULT);
}

/// Fused DSA decode at an explicit key tile: int8-predict the new row's
/// scores against the cached key mirror, top-k select cached columns,
/// then fused exact SDDMM + online softmax + SpMM over the kept columns
/// in `tile`-sized chunks. `out` (`dv` entries) is fully overwritten.
// lint: hot-path
pub fn decode_dsa_tiled_scratch(
    q: &[f32],
    cache: &KvCache,
    keep: usize,
    out: &mut [f32],
    scratch: &mut Scratch,
    tile: usize,
) {
    let (l, dk, dv) = (cache.len(), cache.dk(), cache.dv());
    assert_eq!(q.len(), dk, "q shape");
    assert_eq!(out.len(), dv, "out shape");
    if l == 0 {
        out.fill(0.0);
        return;
    }
    let tile = tile.clamp(1, l.max(1));
    scratch.reserve(l, keep.min(l.max(1)));
    scratch.reserve_qi8(dk);

    // Quantize the new query row with exactly `quantize_i8`'s fold and
    // per-entry expression (but into warm scratch): bitwise-equal scores
    // to a full `ApproxScorer` whose joint Q max equals this row's max.
    let qmax = q.iter().fold(0f32, |m, &x| m.max(x.abs()));
    let qs = if qmax == 0.0 {
        scratch.qi8[..dk].fill(0);
        0.0
    } else {
        let inv = 127.0 / qmax;
        for (o, &x) in scratch.qi8[..dk].iter_mut().zip(q.iter()) {
            *o = (x * inv).round().clamp(-127.0, 127.0) as i8;
        }
        qmax / 127.0
    };
    let pscale = qs * cache.k_scale() / (dk as f32).sqrt();

    // Predict: score the new row against every cached key (int8, exact
    // i32 accumulation — bitwise identical across SIMD tiers, so the
    // selected mask never varies by ISA).
    {
        let (row, qi8, ki8) = (&mut scratch.row, &scratch.qi8, cache.ki8());
        for (c, o) in row[..l].iter_mut().enumerate() {
            *o = simd::dot_i8(&qi8[..dk], &ki8[c * dk..(c + 1) * dk]) as f32 * pscale;
        }
    }
    topk::topk_row_indices_into(&scratch.row[..l], keep, &mut scratch.kept);

    // Execute exactly: the fused per-row DSA body from
    // `sparse::dsa_attention_rows_fused_tile_scratch`, against the cache.
    let scale = 1.0 / (dk as f32).sqrt();
    let (k, v) = (cache.k(), cache.v());
    out.fill(0.0);
    let (mut m, mut den, mut nanp) = (f32::NEG_INFINITY, 0.0f32, false);
    for chunk in scratch.kept.chunks(tile) {
        scratch.vals.clear();
        for &c in chunk {
            scratch.vals.push(simd::dot_f32(q, &k[c * dk..(c + 1) * dk]) * scale);
        }
        if dense::online_rescale(simd::max_f32(&scratch.vals), &mut m, &mut den, out) {
            for (&c, &s) in chunk.iter().zip(scratch.vals.iter()) {
                let w = (s - m).exp();
                den += w;
                if w != 0.0 {
                    simd::axpy_f32(out, w, &v[c * dv..(c + 1) * dv]);
                }
            }
        } else if m == f32::NEG_INFINITY {
            nanp = nanp || scratch.vals.iter().any(|s| s.is_nan());
        }
    }
    dense::online_finish(m, den, nanp, out);
}

/// [`decode_dsa_tiled_scratch`] at the default [`dense::KEY_TILE`].
pub fn decode_dsa_scratch(
    q: &[f32],
    cache: &KvCache,
    keep: usize,
    out: &mut [f32],
    scratch: &mut Scratch,
) {
    decode_dsa_tiled_scratch(q, cache, keep, out, scratch, dense::KEY_TILE);
}

/// Unfused DSA decode reference (predict → top-k → exact scores →
/// two-pass softmax → SpMM), the oracle the fused form is tested
/// against: bitwise-identical mask, online-softmax-tolerance outputs.
/// Allocates freely — tests only.
pub fn decode_dsa_reference(q: &[f32], cache: &KvCache, keep: usize) -> Vec<f32> {
    let (l, dk, dv) = (cache.len(), cache.dk(), cache.dv());
    assert_eq!(q.len(), dk, "q shape");
    let mut out = vec![0f32; dv];
    if l == 0 {
        return out;
    }
    let (qq, qs) = sparse::quantize_i8(q);
    let pscale = qs * cache.k_scale() / (dk as f32).sqrt();
    let mut srow = vec![0f32; l];
    for (c, o) in srow.iter_mut().enumerate() {
        *o = simd::dot_i8(&qq, &cache.ki8()[c * dk..(c + 1) * dk]) as f32 * pscale;
    }
    let mut kept = Vec::new();
    topk::topk_row_indices_into(&srow, keep, &mut kept);
    let scale = 1.0 / (dk as f32).sqrt();
    let mut vals: Vec<f32> = kept
        .iter()
        .map(|&c| simd::dot_f32(q, &cache.k()[c * dk..(c + 1) * dk]) * scale)
        .collect();
    dense::softmax_in_place(&mut vals);
    for (&c, &w) in kept.iter().zip(vals.iter()) {
        if w != 0.0 {
            simd::axpy_f32(&mut out, w, &cache.v()[c * dv..(c + 1) * dv]);
        }
    }
    out
}

/// The fused DSA decode's selected mask (kept cached-column indices),
/// exposed for the mask-identity tests.
pub fn decode_dsa_mask(q: &[f32], cache: &KvCache, keep: usize) -> Vec<usize> {
    let (l, dk) = (cache.len(), cache.dk());
    assert_eq!(q.len(), dk, "q shape");
    if l == 0 {
        return Vec::new();
    }
    let (qq, qs) = sparse::quantize_i8(q);
    let pscale = qs * cache.k_scale() / (dk as f32).sqrt();
    let mut srow = vec![0f32; l];
    for (c, o) in srow.iter_mut().enumerate() {
        *o = simd::dot_i8(&qq, &cache.ki8()[c * dk..(c + 1) * dk]) as f32 * pscale;
    }
    let mut kept = Vec::new();
    topk::topk_row_indices_into(&srow, keep, &mut kept);
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dispatch::{AttnInput, ExecPolicy, KernelDispatch, KernelSpec, Variant};
    use crate::kernels::kvcache::BUCKET_ROWS;
    use crate::kernels::tiles::TilePlan;
    use crate::util::rng::Rng;

    fn randv(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn specs() -> Vec<KernelSpec> {
        let mut out = Vec::new();
        for &threads in &[1usize, 2, 7, 0] {
            for exec in [ExecPolicy::Pool, ExecPolicy::Spawn] {
                out.push(KernelSpec {
                    threads,
                    exec,
                    tiles: TilePlan::committed(),
                });
            }
        }
        out
    }

    /// Dense: every decode step is bitwise equal to its row of the full
    /// fused forward over the same prefix, through the dispatch surface,
    /// across thread counts {1,2,7,ncpu} x {Pool,Spawn}.
    #[test]
    fn dense_decode_steps_match_full_fused_forward_bitwise() {
        let (dk, dv, l) = (16usize, 8usize, 37usize);
        let mut rng = Rng::new(41);
        let qs = randv(l * dk, &mut rng);
        let k = randv(l * dk, &mut rng);
        let v = randv(l * dv, &mut rng);
        let mut cache = KvCache::new(dk, dv);
        let mut scratch = Scratch::new();
        let mut out = vec![0f32; dv];
        let mut full = Vec::new();
        for t in 0..l {
            cache.append(&k[t * dk..(t + 1) * dk], &v[t * dv..(t + 1) * dv]);
            let lcur = t + 1;
            for spec in specs() {
                let kernel = Variant::Dense.build(&spec).expect("dense kernel");
                kernel.decode_into(&qs[t * dk..(t + 1) * dk], &cache, &mut scratch, &mut out);
                full.resize(lcur * dv, 0.0);
                kernel.forward_into(
                    &AttnInput {
                        q: &qs[..lcur * dk],
                        k: &k[..lcur * dk],
                        v: &v[..lcur * dv],
                        l: lcur,
                        dk,
                        dv,
                    },
                    &mut full,
                );
                for (a, b) in out.iter().zip(full[t * dv..(t + 1) * dv].iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "dense decode diverged at step {t}");
                }
            }
        }
    }

    /// DSA: with row-max-normalized queries (every row's max-|q| is
    /// exactly 1.0, so single-row quantization equals the one-shot
    /// scorer's joint quantization bitwise), every decode step is
    /// bitwise equal to its row of the full fused DSA forward, across
    /// thread counts x exec policies.
    #[test]
    fn dsa_decode_steps_match_full_fused_forward_bitwise() {
        let (dk, dv, l) = (16usize, 8usize, 33usize);
        let mut rng = Rng::new(42);
        let mut qs = randv(l * dk, &mut rng);
        for row in qs.chunks_exact_mut(dk) {
            let m = row.iter().fold(0f32, |m, &x| m.max(x.abs()));
            if m > 0.0 {
                for x in row.iter_mut() {
                    *x /= m; // the max element becomes exactly +-1.0
                }
            }
        }
        let k = randv(l * dk, &mut rng);
        let v = randv(l * dv, &mut rng);
        let mut cache = KvCache::new(dk, dv);
        let mut scratch = Scratch::new();
        let mut out = vec![0f32; dv];
        let mut full = Vec::new();
        for t in 0..l {
            cache.append(&k[t * dk..(t + 1) * dk], &v[t * dv..(t + 1) * dv]);
            let lcur = t + 1;
            for spec in specs() {
                let kernel = Variant::Dsa { pct: 90 }.build(&spec).expect("dsa kernel");
                kernel.decode_into(&qs[t * dk..(t + 1) * dk], &cache, &mut scratch, &mut out);
                full.resize(lcur * dv, 0.0);
                kernel.forward_into(
                    &AttnInput {
                        q: &qs[..lcur * dk],
                        k: &k[..lcur * dk],
                        v: &v[..lcur * dv],
                        l: lcur,
                        dk,
                        dv,
                    },
                    &mut full,
                );
                for (a, b) in out.iter().zip(full[t * dv..(t + 1) * dv].iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "dsa decode diverged at step {t}");
                }
            }
        }
    }

    /// Arbitrary (un-normalized) queries: the fused DSA decode selects a
    /// bitwise-identical mask to the unfused decode reference and matches
    /// its output within online-softmax tolerance, across key tiles.
    #[test]
    fn dsa_decode_matches_unfused_reference() {
        let (dk, dv, l, keep) = (8usize, 6usize, 29usize, 7usize);
        let mut rng = Rng::new(43);
        let k = randv(l * dk, &mut rng);
        let v = randv(l * dv, &mut rng);
        let mut cache = KvCache::new(dk, dv);
        for t in 0..l {
            cache.append(&k[t * dk..(t + 1) * dk], &v[t * dv..(t + 1) * dv]);
        }
        let mut scratch = Scratch::new();
        let mut out = vec![0f32; dv];
        for trial in 0..10 {
            let q = randv(dk, &mut rng);
            let oracle = decode_dsa_reference(&q, &cache, keep);
            let mask = decode_dsa_mask(&q, &cache, keep);
            assert_eq!(mask.len(), keep);
            for &tile in &[1usize, 3, 256] {
                decode_dsa_tiled_scratch(&q, &cache, keep, &mut out, &mut scratch, tile);
                for (i, (a, b)) in out.iter().zip(oracle.iter()).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-5,
                        "trial {trial} tile {tile} out[{i}]: fused {a} vs oracle {b}"
                    );
                }
            }
        }
    }

    /// Steady-state decode performs zero per-step allocations: with a
    /// warm scratch and a cache whose buckets were grown by a previous
    /// session (pool-recycle path), append + dense decode + DSA decode
    /// record no further grow events on either instance counter.
    #[test]
    fn warm_decode_steps_are_allocation_free() {
        let (dk, dv, keep) = (8usize, 4usize, 7usize);
        let l = BUCKET_ROWS + 9;
        let mut rng = Rng::new(44);
        let k = randv(l * dk, &mut rng);
        let v = randv(l * dv, &mut rng);
        let q = randv(dk, &mut rng);
        let mut cache = KvCache::new(dk, dv);
        for t in 0..l {
            cache.append(&k[t * dk..(t + 1) * dk], &v[t * dv..(t + 1) * dv]);
        }
        let mut scratch = Scratch::new();
        let mut out = vec![0f32; dv];
        decode_dense_scratch(&q, &cache, &mut out, &mut scratch);
        decode_dsa_scratch(&q, &cache, keep, &mut out, &mut scratch);
        let (cg, sg) = (cache.grow_events(), scratch.grow_events());
        assert!(cg >= 2 && sg >= 1);

        cache.reset(); // recycled-session shape: empty, warm buckets
        for t in 0..l {
            cache.append(&k[t * dk..(t + 1) * dk], &v[t * dv..(t + 1) * dv]);
            decode_dense_scratch(&q, &cache, &mut out, &mut scratch);
            decode_dsa_scratch(&q, &cache, keep, &mut out, &mut scratch);
        }
        assert_eq!(cache.grow_events(), cg, "cache re-grew during warm decode");
        assert_eq!(scratch.grow_events(), sg, "scratch re-grew during warm decode");
    }

    #[test]
    fn empty_cache_decodes_to_zeros() {
        let cache = KvCache::new(4, 3);
        let q = [1.0f32, -2.0, 3.0, 0.5];
        let mut scratch = Scratch::new();
        let mut out = vec![9.0f32; 3];
        decode_dense_scratch(&q, &cache, &mut out, &mut scratch);
        assert_eq!(out, vec![0.0; 3]);
        let mut out = vec![9.0f32; 3];
        decode_dsa_scratch(&q, &cache, 1, &mut out, &mut scratch);
        assert_eq!(out, vec![0.0; 3]);
    }
}
