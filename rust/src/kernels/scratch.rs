//! Reusable per-worker scratch buffers for the attention row drivers.
//!
//! The hot row loops (dense scoring/accumulation and the per-row DSA
//! pipeline, fused and unfused alike) need an `l`-length score row — which
//! doubles as the fused kernels' key-tile score buffer — a `keep`-length
//! softmax/chunk-score row and a kept-column index buffer. Allocating
//! those per call — let alone per row, as the old `topk_row_indices`
//! return value did — puts the allocator on the hot path. Each worker
//! thread instead owns one [`Scratch`] for the lifetime of a dispatch:
//! buffers grow monotonically to the largest problem seen and are reused
//! across every row and every `(batch, head)` problem the worker
//! processes. (The fused kernels' per-row running max / denominator are
//! `QUERY_BLOCK`-sized stack arrays — nothing to pool.) The whole-matrix
//! predictor reference additionally routes its `l x l` approximate-score
//! matrix through [`Scratch::scores`] ([`Scratch::reserve_scores`]), so
//! even that path stops allocating once warm.
//!
//! Growth is observable: every buffer grow bumps both the instance counter
//! ([`Scratch::grow_events`]) and a process-wide counter
//! ([`grow_events`]). The unit tests assert a warm scratch processes
//! arbitrarily many rows with **zero** further grow events, and
//! `bench_kernels` prints the global counter so allocation regressions
//! show up next to the timings they would explain.

use std::sync::atomic::{AtomicU64, Ordering};

static GROW_EVENTS: AtomicU64 = AtomicU64::new(0);

/// Total scratch-buffer grow events process-wide (bench-mode counter).
pub fn grow_events() -> u64 {
    GROW_EVENTS.load(Ordering::Relaxed)
}

/// Per-worker scratch for the attention row drivers. Construct once per
/// worker (or reuse across dispatches); [`Scratch::reserve`] sizes it for
/// a problem and the drivers index the buffers directly.
#[derive(Debug, Default)]
pub struct Scratch {
    /// Score row of the current problem (`l` entries live; the fused
    /// kernels use its `[..tile]` prefix as the key-tile score buffer).
    pub row: Vec<f32>,
    /// Softmax row over the kept entries (used via `clear` + `push`; the
    /// fused DSA driver reuses it as the per-chunk exact-score buffer).
    pub vals: Vec<f32>,
    /// Kept column indices (doubles as the top-k selection buffer, so its
    /// capacity is `l`, not `keep`).
    pub kept: Vec<usize>,
    /// Whole-matrix approximate-score buffer (`l * l`), grown only by the
    /// unfused whole-matrix reference via [`Scratch::reserve_scores`] —
    /// the per-row fused paths never touch it, so warming `(l, keep)`
    /// never pays for it.
    pub scores: Vec<f32>,
    /// Quantized query row for the decode path (`dk` entries; the DSA
    /// decode kernel quantizes the new query into it each step), grown
    /// only by [`Scratch::reserve_qi8`] — forward dispatches and pool
    /// warm-up never touch it.
    pub qi8: Vec<i8>,
    grows: u64,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Grow events observed by this instance (monotone; a warm scratch
    /// reused at the same problem size records none).
    pub fn grow_events(&self) -> u64 {
        self.grows
    }

    fn note_grow(&mut self) {
        self.grows += 1;
        GROW_EVENTS.fetch_add(1, Ordering::Relaxed);
    }

    /// Ensure capacity for one `(l, keep)` problem: `row` holds at least
    /// `l` initialized entries and, when the mask path is in use
    /// (`keep > 0`), `vals` can hold `keep` and `kept` can hold `l`
    /// without reallocating. The dense path passes `keep = 0` and only
    /// pays for the score row. Shrinks nothing.
    pub fn reserve(&mut self, l: usize, keep: usize) {
        if self.row.len() < l {
            self.note_grow();
            self.row.resize(l, 0.0);
        }
        if keep == 0 {
            return;
        }
        if self.vals.capacity() < keep {
            self.note_grow();
            let need = keep - self.vals.len();
            self.vals.reserve(need);
        }
        if self.kept.capacity() < l {
            self.note_grow();
            let need = l - self.kept.len();
            self.kept.reserve(need);
        }
    }

    /// Ensure `scores` holds at least `n` initialized entries (the
    /// whole-matrix predictor reference passes `l * l`). Kept separate
    /// from [`Scratch::reserve`] so per-row pipelines and pool warm-up
    /// never allocate a quadratic buffer they will not use.
    pub fn reserve_scores(&mut self, n: usize) {
        if self.scores.len() < n {
            self.note_grow();
            self.scores.resize(n, 0.0);
        }
    }

    /// Ensure `qi8` holds at least `dk` initialized entries (the DSA
    /// decode path quantizes one query row into it per step). Kept
    /// separate from [`Scratch::reserve`] so forward dispatches never
    /// pay for a buffer only decode uses.
    pub fn reserve_qi8(&mut self, dk: usize) {
        if self.qi8.len() < dk {
            self.note_grow();
            self.qi8.resize(dk, 0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warm_scratch_never_regrows() {
        let mut s = Scratch::new();
        s.reserve(64, 9);
        s.reserve_scores(64 * 64);
        let warm = s.grow_events();
        assert!(warm >= 1);
        for _ in 0..100 {
            s.reserve(64, 9);
            s.reserve(13, 2); // smaller problems must not shrink or grow
            s.reserve_scores(64 * 64);
            s.reserve_scores(13 * 13);
        }
        assert_eq!(s.grow_events(), warm, "warm scratch reallocated");
        assert!(s.row.len() >= 64);
        assert!(s.vals.capacity() >= 9);
        assert!(s.kept.capacity() >= 64);
        assert!(s.scores.len() >= 64 * 64);
    }

    /// `reserve` (the pool-warm path) never grows the quadratic `scores`
    /// buffer — only the whole-matrix predictor reference pays for it —
    /// nor the decode-only `qi8` row.
    #[test]
    fn reserve_never_touches_scores() {
        let mut s = Scratch::new();
        s.reserve(256, 256);
        assert_eq!(s.scores.capacity(), 0, "warm-up must not allocate l*l");
        assert_eq!(s.qi8.capacity(), 0, "warm-up must not allocate qi8");
    }

    /// A warm `qi8` row never re-grows (the per-step decode reserve).
    #[test]
    fn warm_qi8_never_regrows() {
        let mut s = Scratch::new();
        s.reserve_qi8(64);
        let warm = s.grow_events();
        for _ in 0..100 {
            s.reserve_qi8(64);
            s.reserve_qi8(8);
        }
        assert_eq!(s.grow_events(), warm, "warm qi8 reallocated");
        assert!(s.qi8.len() >= 64);
    }

    #[test]
    fn growth_is_counted_globally() {
        let before = grow_events();
        let mut s = Scratch::new();
        s.reserve(8, 4);
        assert!(grow_events() > before);
    }

    /// A warm worker pool does **zero** scratch re-grows across repeated
    /// full dispatches: `WorkerPool::warm` pre-grows every worker's
    /// scratch, after which dense and DSA dispatches of any smaller-or-
    /// equal problem allocate nothing (tracked by the pool's aggregated
    /// per-worker grow counter, so concurrent tests on the global counter
    /// can't perturb this assertion).
    #[test]
    fn warm_pool_dispatches_never_regrow() {
        use crate::kernels::parallel::{self, Exec};
        use crate::kernels::pool::WorkerPool;
        use crate::util::rng::Rng;

        let pool = WorkerPool::new(3);
        let (l, dk, dv, keep) = (48usize, 8usize, 6usize, 9usize);
        pool.warm(l, l);
        let warm = pool.stats().scratch_grows;
        assert!(warm >= 3, "warm must touch every worker");

        let mut rng = Rng::new(77);
        let q: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..l * dv).map(|_| rng.normal() as f32).collect();
        let exec = Exec::Pool(&pool);
        for _ in 0..5 {
            parallel::dense_attention_mt_exec(&q, &k, &v, l, dk, dv, 3, exec);
            parallel::dsa_attention_mt_exec(&q, &k, &v, l, dk, dv, keep, 3, exec);
        }
        assert_eq!(
            pool.stats().scratch_grows,
            warm,
            "warm pool re-grew scratch during dispatches"
        );
    }
}
