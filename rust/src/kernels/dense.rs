//! Dense attention reference: `softmax(Q K^T / sqrt(dk)) V` over row-major
//! f32 buffers. This is the baseline every sparse path is validated
//! against: at `keep = l` the dynamic-sparse pipeline in
//! [`super::sparse`] performs the exact same float operations in the same
//! order, so the two agree bit for bit. Both paths share one inner-product
//! implementation ([`super::simd`]) so that guarantee survives the SIMD
//! dispatch: whatever tier runs, it runs on both sides.

use super::scratch::Scratch;
use super::simd;

/// Scaled attention scores for query row `r`:
/// `out[c] = (q_r . k_c) / sqrt(dk)`.
pub fn score_row(q: &[f32], k: &[f32], l: usize, dk: usize, r: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), l);
    score_row_scaled(q, k, dk, r, 1.0 / (dk as f32).sqrt(), out);
}

/// [`score_row`] with the `1 / sqrt(dk)` scale hoisted out — the row
/// drivers compute it once per call instead of once per row. One score per
/// `out` entry: `out[c] = (q_r . k_c) * scale`.
pub fn score_row_scaled(q: &[f32], k: &[f32], dk: usize, r: usize, scale: f32, out: &mut [f32]) {
    let qr = &q[r * dk..(r + 1) * dk];
    for (c, o) in out.iter_mut().enumerate() {
        *o = simd::dot_f32(qr, &k[c * dk..(c + 1) * dk]) * scale;
    }
}

/// Numerically-stable softmax over `row`, in place. A row whose maximum is
/// not finite — e.g. every entry `-inf`, the fully-masked case — becomes
/// all zeros instead of NaN, so downstream SpMM rows renormalize to a zero
/// context vector rather than poisoning the output.
pub fn softmax_in_place(row: &mut [f32]) {
    let mut max = f32::NEG_INFINITY;
    for &x in row.iter() {
        if x > max {
            max = x;
        }
    }
    if !max.is_finite() {
        for x in row.iter_mut() {
            *x = 0.0;
        }
        return;
    }
    let mut sum = 0.0f32;
    for x in row.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    if sum > 0.0 {
        let inv = 1.0 / sum;
        for x in row.iter_mut() {
            *x *= inv;
        }
    }
}

/// Dense attention for query rows `r0..r1`, writing the `(r1 - r0) x dv`
/// context rows into `out`. Row ranges are independent, so disjoint ranges
/// can run on different threads (see [`super::parallel`]) with results
/// identical to a single-threaded pass. Allocates a throwaway scratch; the
/// parallel drivers use [`attention_rows_scratch`] to reuse one per
/// worker.
#[allow(clippy::too_many_arguments)]
pub fn attention_rows(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    r0: usize,
    r1: usize,
    out: &mut [f32],
) {
    let mut scratch = Scratch::new();
    attention_rows_scratch(q, k, v, l, dk, dv, r0, r1, out, &mut scratch);
}

/// [`attention_rows`] over a caller-owned [`Scratch`]: the row loop itself
/// performs no allocations, so a warm scratch records zero grow events no
/// matter how many rows pass through (asserted by the tests).
#[allow(clippy::too_many_arguments)]
pub fn attention_rows_scratch(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    r0: usize,
    r1: usize,
    out: &mut [f32],
    scratch: &mut Scratch,
) {
    debug_assert_eq!(out.len(), (r1 - r0) * dv);
    scratch.reserve(l, 0);
    let scale = 1.0 / (dk as f32).sqrt();
    let row = &mut scratch.row[..l];
    for r in r0..r1 {
        score_row_scaled(q, k, dk, r, scale, row);
        softmax_in_place(row);
        let o = &mut out[(r - r0) * dv..(r - r0 + 1) * dv];
        o.fill(0.0);
        for (c, &w) in row.iter().enumerate() {
            if w != 0.0 {
                simd::axpy_f32(o, w, &v[c * dv..(c + 1) * dv]);
            }
        }
    }
}

/// Full dense attention: returns the `l x dv` context matrix.
pub fn attention(q: &[f32], k: &[f32], v: &[f32], l: usize, dk: usize, dv: usize) -> Vec<f32> {
    assert_eq!(q.len(), l * dk, "q shape");
    assert_eq!(k.len(), l * dk, "k shape");
    assert_eq!(v.len(), l * dv, "v shape");
    let mut out = vec![0f32; l * dv];
    attention_rows(q, k, v, l, dk, dv, 0, l, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::assert_allclose;

    #[test]
    fn softmax_normalizes() {
        let mut row = vec![1.0f32, 2.0, 3.0];
        softmax_in_place(&mut row);
        let sum: f32 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "sum {sum}");
        assert!(row[2] > row[1] && row[1] > row[0]);
    }

    #[test]
    fn softmax_fully_masked_row_is_zero_not_nan() {
        let mut row = vec![f32::NEG_INFINITY; 4];
        softmax_in_place(&mut row);
        assert_eq!(row, vec![0.0; 4]);
        let mut empty: Vec<f32> = Vec::new();
        softmax_in_place(&mut empty); // must not panic
    }

    #[test]
    fn softmax_is_shift_invariant() {
        let mut a = vec![1.0f32, 2.0, 4.0];
        let mut b = vec![1001.0f32, 1002.0, 1004.0];
        softmax_in_place(&mut a);
        softmax_in_place(&mut b);
        assert_allclose(&a, &b, 1e-6, 1e-7);
    }

    #[test]
    fn uniform_scores_average_v() {
        // q orthogonal to every k => all scores 0 => uniform weights.
        let l = 4;
        let (dk, dv) = (2, 3);
        let q = vec![0.0f32; l * dk];
        let k = vec![1.0f32; l * dk];
        let v: Vec<f32> = (0..l * dv).map(|i| i as f32).collect();
        let out = attention(&q, &k, &v, l, dk, dv);
        // mean of rows [0,1,2],[3,4,5],[6,7,8],[9,10,11] = [4.5,5.5,6.5]
        for r in 0..l {
            assert_allclose(&out[r * dv..(r + 1) * dv], &[4.5, 5.5, 6.5], 1e-5, 1e-5);
        }
    }

    /// Test-local strictly-scalar dense attention (every inner product
    /// through the `simd::scalar` oracle) — the reference the dispatched
    /// path is compared against without touching the global SIMD mode.
    fn scalar_attention(
        q: &[f32],
        k: &[f32],
        v: &[f32],
        l: usize,
        dk: usize,
        dv: usize,
    ) -> Vec<f32> {
        use crate::kernels::simd::scalar;
        let scale = 1.0 / (dk as f32).sqrt();
        let mut out = vec![0f32; l * dv];
        let mut row = vec![0f32; l];
        for r in 0..l {
            let qr = &q[r * dk..(r + 1) * dk];
            for (c, o) in row.iter_mut().enumerate() {
                *o = scalar::dot_f32(qr, &k[c * dk..(c + 1) * dk]) * scale;
            }
            softmax_in_place(&mut row);
            let o = &mut out[r * dv..(r + 1) * dv];
            for (c, &w) in row.iter().enumerate() {
                if w != 0.0 {
                    scalar::axpy_f32(o, w, &v[c * dv..(c + 1) * dv]);
                }
            }
        }
        out
    }

    #[test]
    fn simd_attention_matches_scalar_oracle_prop() {
        use crate::util::prop::{forall, Config};
        use crate::util::rng::Rng;
        forall(
            &Config { cases: 24, ..Default::default() },
            |rng: &mut Rng, size| {
                // Odd lengths exercise the remainder lanes of every dot.
                let l = 2 + rng.below(3 * size as u64) as usize;
                let dk = 1 + rng.below(20) as usize;
                let dv = 1 + rng.below(20) as usize;
                let q: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
                let k: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
                let v: Vec<f32> = (0..l * dv).map(|_| rng.normal() as f32).collect();
                (q, k, v, l, dk, dv)
            },
            |(q, k, v, l, dk, dv)| {
                let got = attention(q, k, v, *l, *dk, *dv);
                let want = scalar_attention(q, k, v, *l, *dk, *dv);
                got.iter().zip(&want).all(|(a, b)| (a - b).abs() <= 1e-5 + 1e-5 * b.abs())
            },
        );
    }

    #[test]
    fn warm_scratch_rows_are_allocation_free() {
        use crate::kernels::scratch::Scratch;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        let (l, dk, dv) = (33, 7, 5);
        let q: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..l * dv).map(|_| rng.normal() as f32).collect();
        let mut out = vec![0f32; l * dv];
        let mut scratch = Scratch::new();
        attention_rows_scratch(&q, &k, &v, l, dk, dv, 0, l, &mut out, &mut scratch);
        let warm = scratch.grow_events();
        let mut again = vec![0f32; l * dv];
        attention_rows_scratch(&q, &k, &v, l, dk, dv, 0, l, &mut again, &mut scratch);
        assert_eq!(scratch.grow_events(), warm, "hot loop allocated");
        assert_eq!(out, again, "scratch reuse changed results");
    }

    #[test]
    fn one_hot_scores_select_v_row() {
        // Orthogonal q/k rows with large magnitude: row r attends ~only to
        // the column sharing its axis, i.e. itself.
        let l = 2;
        let (dk, dv) = (2, 2);
        let mut q = vec![0f32; l * dk];
        for (r, chunk) in q.chunks_exact_mut(dk).enumerate() {
            chunk[r] = 30.0;
        }
        let k = q.clone();
        let v: Vec<f32> = (0..l * dv).map(|i| i as f32).collect();
        let out = attention(&q, &k, &v, l, dk, dv);
        for r in 0..l {
            assert_allclose(
                &out[r * dv..(r + 1) * dv],
                &v[r * dv..(r + 1) * dv],
                1e-3,
                1e-3,
            );
        }
    }
}
