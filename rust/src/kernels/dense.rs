//! Dense attention: `softmax(Q K^T / sqrt(dk)) V` over row-major f32
//! buffers, in two forms.
//!
//! * **Fused, cache-tiled, online softmax** ([`attention_rows_fused_scratch`])
//!   — the production path. Query rows are processed in [`QUERY_BLOCK`]-row
//!   blocks against [`KEY_TILE`]-key K/V tiles; each row carries a running
//!   maximum and denominator (flash-attention-style rescaling via
//!   [`online_rescale`] / [`online_finish`]) and accumulates its
//!   unnormalized context directly in the output row. The `l`-length score
//!   row, the separate softmax pass and the separate weighted-sum pass of
//!   the unfused form collapse into one pass with an `O(tile · d)` working
//!   set — each K/V tile is read once per query block instead of once per
//!   query row, which is what the paper's memory-traffic bottleneck
//!   argument asks for.
//! * **Unfused reference** ([`attention_rows_scratch`]) — score row →
//!   [`softmax_in_place`] → weighted sum, three passes. Retained as the
//!   property-test oracle and the bench comparator; the fused kernel must
//!   stay within a tight tolerance of it (asserted by the tests, including
//!   ragged `l` vs tile, `l` smaller than one tile and fully-masked rows).
//!
//! At `keep = l` the dynamic-sparse pipeline in [`super::sparse`] performs
//! the exact same float operations in the same order — unfused matching
//! unfused and fused matching fused **bit for bit**. Both paths share one
//! inner-product implementation ([`super::simd`]) so that guarantee
//! survives the SIMD dispatch: whatever tier runs, it runs on both sides.

use super::scratch::Scratch;
use super::simd;
use super::tiles::{Tile, MAX_QUERY_BLOCK};

/// Keys (and value rows) per K/V tile of the fused kernels — the
/// [`Tile::DEFAULT`] fallback every shape runs at unless a
/// [`TilePlan`](super::tiles::TilePlan) entry overrides it. At the bench
/// head width `d = 64` one K tile plus one V tile is `2 · 256 · 64 · 4 B
/// = 128 KiB` — resident in any contemporary L2 — and the per-row score
/// buffer is `tile` floats instead of `l`. The fused outputs depend on
/// the tile size, so whatever tile runs must be **fixed per shape before
/// dispatch** (one constant here, or one committed plan entry per
/// `(l, dk)`): that keeps results bit-identical across thread counts,
/// dispatch backends and batch shapes.
pub const KEY_TILE: usize = 256;

/// Query rows processed per tile pass of the fused kernels (the
/// [`Tile::DEFAULT`] fallback): each K/V tile is streamed from memory
/// once and reused by this many query rows, so tile traffic drops by
/// `QUERY_BLOCK`× vs the unfused per-row streaming. Per-row results never
/// depend on this blocking (each row owns its running max / denominator /
/// accumulator) — only locality does. Per-shape overrides are capped at
/// [`MAX_QUERY_BLOCK`] (the kernels' stack-array bound).
pub const QUERY_BLOCK: usize = 8;

/// Scaled attention scores for query row `r`:
/// `out[c] = (q_r . k_c) / sqrt(dk)`.
pub fn score_row(q: &[f32], k: &[f32], l: usize, dk: usize, r: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), l);
    score_row_scaled(q, k, dk, r, 1.0 / (dk as f32).sqrt(), out);
}

/// [`score_row`] with the `1 / sqrt(dk)` scale hoisted out — the row
/// drivers compute it once per call instead of once per row. One score per
/// `out` entry: `out[c] = (q_r . k_c) * scale`.
pub fn score_row_scaled(q: &[f32], k: &[f32], dk: usize, r: usize, scale: f32, out: &mut [f32]) {
    let qr = &q[r * dk..(r + 1) * dk];
    for (c, o) in out.iter_mut().enumerate() {
        *o = simd::dot_f32(qr, &k[c * dk..(c + 1) * dk]) * scale;
    }
}

/// Numerically-stable softmax over `row`, in place. A row whose maximum is
/// not finite — e.g. every entry `-inf`, the fully-masked case — becomes
/// all zeros instead of NaN, so downstream SpMM rows renormalize to a zero
/// context vector rather than poisoning the output.
pub fn softmax_in_place(row: &mut [f32]) {
    let mut max = f32::NEG_INFINITY;
    for &x in row.iter() {
        if x > max {
            max = x;
        }
    }
    if !max.is_finite() {
        for x in row.iter_mut() {
            *x = 0.0;
        }
        return;
    }
    let mut sum = 0.0f32;
    for x in row.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    if sum > 0.0 {
        let inv = 1.0 / sum;
        for x in row.iter_mut() {
            *x *= inv;
        }
    }
}

/// Online-softmax tile step, part 1: fold a tile's score maximum
/// `tile_max` into the row's running maximum `m`, rescaling the running
/// denominator `den` and the unnormalized accumulator row `acc` by
/// `exp(m_old - m_new)` when the maximum moves. Returns whether the row
/// is currently *accumulable* — `m` finite. A row whose maximum never
/// becomes finite (all scores `-inf`/NaN) or reaches `+inf` accumulates
/// nothing and is zeroed by [`online_finish`]. Callers of a skipped tile
/// must still record NaN scores seen while `m` is `-inf` (the
/// `nan_pending` input of [`online_finish`]) — the unfused softmax skips
/// NaN in its max scan but the NaN weights poison the row once the max
/// turns finite, and the fused kernels reproduce that exactly.
#[inline]
pub fn online_rescale(tile_max: f32, m: &mut f32, den: &mut f32, acc: &mut [f32]) -> bool {
    if tile_max > *m {
        if m.is_finite() {
            let c = (*m - tile_max).exp();
            *den *= c;
            simd::scale_f32(acc, c);
        }
        *m = tile_max;
    }
    m.is_finite()
}

/// Online-softmax finalization, part 2: after every tile has been folded
/// in, normalize the accumulator by the running denominator. Matches the
/// unfused [`softmax_in_place`] + weighted-sum pass case for case:
/// degenerate rows (non-finite running max: fully masked, or a `+inf`
/// score — NaN entries notwithstanding, since the max scan skips NaN)
/// become exactly zero; `nan_pending` (a NaN score seen in a tile skipped
/// while the max was still `-inf`) poisons the whole row to NaN exactly
/// as the unfused NaN weights would; a zero/NaN denominator leaves the
/// accumulator unnormalized (NaN scores seen *after* the max turned
/// finite already poisoned `den` and `acc` through the exp/axpy path).
#[inline]
pub fn online_finish(m: f32, den: f32, nan_pending: bool, acc: &mut [f32]) {
    if !m.is_finite() {
        acc.fill(0.0);
    } else if nan_pending {
        acc.fill(f32::NAN);
    } else if den > 0.0 {
        simd::scale_f32(acc, 1.0 / den);
    }
}

/// Fused dense attention for query rows `r0..r1` at the default
/// [`KEY_TILE`]: one pass over K/V per query block, no `l`-length score
/// row, no separate softmax or weighted-sum pass. Row ranges are
/// independent and per-row results do not depend on `r0`/`r1` or the
/// query blocking, so disjoint ranges parallelize bit-identically to a
/// single-threaded pass (asserted by the `parallel` tests).
#[allow(clippy::too_many_arguments)]
pub fn attention_rows_fused_scratch(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    r0: usize,
    r1: usize,
    out: &mut [f32],
    scratch: &mut Scratch,
) {
    attention_rows_fused_tiled_scratch(q, k, v, l, dk, dv, r0, r1, out, scratch, Tile::DEFAULT);
}

/// [`attention_rows_fused_scratch`] with an explicit key-tile size at the
/// default query block (the property tests sweep it; fused outputs are
/// only comparable bit-for-bit at equal key-tile sizes).
#[allow(clippy::too_many_arguments)]
pub fn attention_rows_fused_tile_scratch(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    r0: usize,
    r1: usize,
    out: &mut [f32],
    scratch: &mut Scratch,
    tile: usize,
) {
    let tile = Tile { key_tile: tile, query_block: QUERY_BLOCK };
    attention_rows_fused_tiled_scratch(q, k, v, l, dk, dv, r0, r1, out, scratch, tile);
}

/// The fused-kernel primitive: [`attention_rows_fused_scratch`] with an
/// explicit [`Tile`] geometry (one `TilePlan` entry — production resolves
/// it per `(l, dk)` shape before dispatch, so results stay bit-identical
/// across thread counts and backends; see `kernels::tiles`). The score
/// tile reuses `scratch.row`, so a warm scratch runs the whole loop
/// allocation-free; per-row running state lives in
/// [`MAX_QUERY_BLOCK`]-sized stack arrays (the `query_block` cap).
#[allow(clippy::too_many_arguments)]
// lint: hot-path
pub fn attention_rows_fused_tiled_scratch(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    r0: usize,
    r1: usize,
    out: &mut [f32],
    scratch: &mut Scratch,
    tile: Tile,
) {
    debug_assert_eq!(out.len(), (r1 - r0) * dv);
    if r0 == r1 {
        return;
    }
    let kt = tile.key_tile.clamp(1, l.max(1));
    let qb = tile.query_block.clamp(1, MAX_QUERY_BLOCK);
    scratch.reserve(l, 0);
    let scale = 1.0 / (dk as f32).sqrt();
    let mut rb = r0;
    while rb < r1 {
        let re = (rb + qb).min(r1);
        let mut mx = [f32::NEG_INFINITY; MAX_QUERY_BLOCK];
        let mut den = [0.0f32; MAX_QUERY_BLOCK];
        let mut nanp = [false; MAX_QUERY_BLOCK];
        out[(rb - r0) * dv..(re - r0) * dv].fill(0.0);
        let mut c0 = 0;
        while c0 < l {
            let c1 = (c0 + kt).min(l);
            let buf = &mut scratch.row[..c1 - c0];
            for r in rb..re {
                let bi = r - rb;
                let qr = &q[r * dk..(r + 1) * dk];
                for (j, o) in buf.iter_mut().enumerate() {
                    let c = c0 + j;
                    *o = simd::dot_f32(qr, &k[c * dk..(c + 1) * dk]) * scale;
                }
                let orow = &mut out[(r - r0) * dv..(r - r0 + 1) * dv];
                if online_rescale(simd::max_f32(buf), &mut mx[bi], &mut den[bi], orow) {
                    let m = mx[bi];
                    for (j, &s) in buf.iter().enumerate() {
                        let w = (s - m).exp();
                        den[bi] += w;
                        if w != 0.0 {
                            let c = c0 + j;
                            simd::axpy_f32(orow, w, &v[c * dv..(c + 1) * dv]);
                        }
                    }
                } else if mx[bi] == f32::NEG_INFINITY {
                    nanp[bi] = nanp[bi] || buf.iter().any(|s| s.is_nan());
                }
            }
            c0 = c1;
        }
        for r in rb..re {
            let orow = &mut out[(r - r0) * dv..(r - r0 + 1) * dv];
            online_finish(mx[r - rb], den[r - rb], nanp[r - rb], orow);
        }
        rb = re;
    }
}

/// Full fused dense attention at the default [`KEY_TILE`]: returns the
/// `l x dv` context matrix. The single-threaded fused reference the
/// multi-threaded fused drivers are bit-identical to.
pub fn attention_fused(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
) -> Vec<f32> {
    attention_fused_tile(q, k, v, l, dk, dv, KEY_TILE)
}

/// [`attention_fused`] with an explicit key-tile size at the default
/// query block (test sweeps).
pub fn attention_fused_tile(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    tile: usize,
) -> Vec<f32> {
    let tile = Tile { key_tile: tile, query_block: QUERY_BLOCK };
    attention_fused_tiled(q, k, v, l, dk, dv, tile)
}

/// [`attention_fused`] with an explicit [`Tile`] geometry — the
/// single-threaded reference of the per-shape `TilePlan` paths (and the
/// `bench_kernels` tile-sweep kernel).
pub fn attention_fused_tiled(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    tile: Tile,
) -> Vec<f32> {
    assert_eq!(q.len(), l * dk, "q shape");
    assert_eq!(k.len(), l * dk, "k shape");
    assert_eq!(v.len(), l * dv, "v shape");
    let mut out = vec![0f32; l * dv];
    let mut scratch = Scratch::new();
    attention_rows_fused_tiled_scratch(q, k, v, l, dk, dv, 0, l, &mut out, &mut scratch, tile);
    out
}

/// Dense attention for query rows `r0..r1`, writing the `(r1 - r0) x dv`
/// context rows into `out`. Row ranges are independent, so disjoint ranges
/// can run on different threads (see [`super::parallel`]) with results
/// identical to a single-threaded pass. Allocates a throwaway scratch; the
/// parallel drivers use [`attention_rows_scratch`] to reuse one per
/// worker.
#[allow(clippy::too_many_arguments)]
pub fn attention_rows(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    r0: usize,
    r1: usize,
    out: &mut [f32],
) {
    let mut scratch = Scratch::new();
    attention_rows_scratch(q, k, v, l, dk, dv, r0, r1, out, &mut scratch);
}

/// [`attention_rows`] over a caller-owned [`Scratch`]: the row loop itself
/// performs no allocations, so a warm scratch records zero grow events no
/// matter how many rows pass through (asserted by the tests).
#[allow(clippy::too_many_arguments)]
pub fn attention_rows_scratch(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    r0: usize,
    r1: usize,
    out: &mut [f32],
    scratch: &mut Scratch,
) {
    debug_assert_eq!(out.len(), (r1 - r0) * dv);
    scratch.reserve(l, 0);
    let scale = 1.0 / (dk as f32).sqrt();
    let row = &mut scratch.row[..l];
    for r in r0..r1 {
        score_row_scaled(q, k, dk, r, scale, row);
        softmax_in_place(row);
        let o = &mut out[(r - r0) * dv..(r - r0 + 1) * dv];
        o.fill(0.0);
        for (c, &w) in row.iter().enumerate() {
            if w != 0.0 {
                simd::axpy_f32(o, w, &v[c * dv..(c + 1) * dv]);
            }
        }
    }
}

/// Full **unfused** dense attention: returns the `l x dv` context matrix.
/// The three-pass reference the fused kernels are property-tested against
/// and the bench comparator of the fused-vs-unfused sweep.
pub fn attention(q: &[f32], k: &[f32], v: &[f32], l: usize, dk: usize, dv: usize) -> Vec<f32> {
    assert_eq!(q.len(), l * dk, "q shape");
    assert_eq!(k.len(), l * dk, "k shape");
    assert_eq!(v.len(), l * dv, "v shape");
    let mut out = vec![0f32; l * dv];
    attention_rows(q, k, v, l, dk, dv, 0, l, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::assert_allclose;

    #[test]
    fn softmax_normalizes() {
        let mut row = vec![1.0f32, 2.0, 3.0];
        softmax_in_place(&mut row);
        let sum: f32 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "sum {sum}");
        assert!(row[2] > row[1] && row[1] > row[0]);
    }

    #[test]
    fn softmax_fully_masked_row_is_zero_not_nan() {
        let mut row = vec![f32::NEG_INFINITY; 4];
        softmax_in_place(&mut row);
        assert_eq!(row, vec![0.0; 4]);
        let mut empty: Vec<f32> = Vec::new();
        softmax_in_place(&mut empty); // must not panic
    }

    #[test]
    fn softmax_is_shift_invariant() {
        let mut a = vec![1.0f32, 2.0, 4.0];
        let mut b = vec![1001.0f32, 1002.0, 1004.0];
        softmax_in_place(&mut a);
        softmax_in_place(&mut b);
        assert_allclose(&a, &b, 1e-6, 1e-7);
    }

    #[test]
    fn uniform_scores_average_v() {
        // q orthogonal to every k => all scores 0 => uniform weights.
        let l = 4;
        let (dk, dv) = (2, 3);
        let q = vec![0.0f32; l * dk];
        let k = vec![1.0f32; l * dk];
        let v: Vec<f32> = (0..l * dv).map(|i| i as f32).collect();
        let out = attention(&q, &k, &v, l, dk, dv);
        // mean of rows [0,1,2],[3,4,5],[6,7,8],[9,10,11] = [4.5,5.5,6.5]
        for r in 0..l {
            assert_allclose(&out[r * dv..(r + 1) * dv], &[4.5, 5.5, 6.5], 1e-5, 1e-5);
        }
    }

    /// Test-local strictly-scalar dense attention (every inner product
    /// through the `simd::scalar` oracle) — the reference the dispatched
    /// path is compared against without touching the global SIMD mode.
    fn scalar_attention(
        q: &[f32],
        k: &[f32],
        v: &[f32],
        l: usize,
        dk: usize,
        dv: usize,
    ) -> Vec<f32> {
        use crate::kernels::simd::scalar;
        let scale = 1.0 / (dk as f32).sqrt();
        let mut out = vec![0f32; l * dv];
        let mut row = vec![0f32; l];
        for r in 0..l {
            let qr = &q[r * dk..(r + 1) * dk];
            for (c, o) in row.iter_mut().enumerate() {
                *o = scalar::dot_f32(qr, &k[c * dk..(c + 1) * dk]) * scale;
            }
            softmax_in_place(&mut row);
            let o = &mut out[r * dv..(r + 1) * dv];
            for (c, &w) in row.iter().enumerate() {
                if w != 0.0 {
                    scalar::axpy_f32(o, w, &v[c * dv..(c + 1) * dv]);
                }
            }
        }
        out
    }

    #[test]
    fn simd_attention_matches_scalar_oracle_prop() {
        use crate::util::prop::{forall, Config};
        use crate::util::rng::Rng;
        forall(
            &Config { cases: 24, ..Default::default() },
            |rng: &mut Rng, size| {
                // Odd lengths exercise the remainder lanes of every dot.
                let l = 2 + rng.below(3 * size as u64) as usize;
                let dk = 1 + rng.below(20) as usize;
                let dv = 1 + rng.below(20) as usize;
                let q: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
                let k: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
                let v: Vec<f32> = (0..l * dv).map(|_| rng.normal() as f32).collect();
                (q, k, v, l, dk, dv)
            },
            |(q, k, v, l, dk, dv)| {
                let got = attention(q, k, v, *l, *dk, *dv);
                let want = scalar_attention(q, k, v, *l, *dk, *dv);
                got.iter().zip(&want).all(|(a, b)| (a - b).abs() <= 1e-5 + 1e-5 * b.abs())
            },
        );
    }

    #[test]
    fn warm_scratch_rows_are_allocation_free() {
        use crate::kernels::scratch::Scratch;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        let (l, dk, dv) = (33, 7, 5);
        let q: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..l * dv).map(|_| rng.normal() as f32).collect();
        let mut out = vec![0f32; l * dv];
        let mut scratch = Scratch::new();
        attention_rows_scratch(&q, &k, &v, l, dk, dv, 0, l, &mut out, &mut scratch);
        let warm = scratch.grow_events();
        let mut again = vec![0f32; l * dv];
        attention_rows_scratch(&q, &k, &v, l, dk, dv, 0, l, &mut again, &mut scratch);
        assert_eq!(scratch.grow_events(), warm, "hot loop allocated");
        assert_eq!(out, again, "scratch reuse changed results");
    }

    /// Tentpole invariant: the fused online-softmax kernel matches the
    /// unfused three-pass reference within a tight tolerance — across
    /// tile sizes (including `tile = 1`, tiles that do not divide `l`,
    /// and tiles larger than `l`), ragged shapes, and NaN-bearing keys
    /// (a NaN key column makes that column's score NaN in every row, so
    /// small tiles hit the nan-pending path where the NaN tile is seen
    /// while the running max is still `-inf`).
    #[test]
    fn fused_matches_unfused_across_tiles_prop() {
        use crate::util::prop::{forall, Config};
        use crate::util::rng::Rng;
        forall(
            &Config { cases: 24, ..Default::default() },
            |rng: &mut Rng, size| {
                let l = 2 + rng.below(3 * size as u64) as usize;
                let dk = 1 + rng.below(16) as usize;
                let dv = 1 + rng.below(16) as usize;
                // Tile candidates deliberately straddle l: smaller, equal,
                // non-dividing, and larger than one tile.
                let tiles = [1, 2, 3, 5, 8, l / 2, l, l + 7, KEY_TILE];
                let tile = tiles[rng.below(tiles.len() as u64) as usize].max(1);
                let q: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
                let mut k: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
                let v: Vec<f32> = (0..l * dv).map(|_| rng.normal() as f32).collect();
                if size > 16 && rng.f64() < 0.3 {
                    let i = rng.below((l * dk) as u64) as usize;
                    k[i] = f32::NAN;
                }
                (q, k, v, l, dk, dv, tile)
            },
            |(q, k, v, l, dk, dv, tile)| {
                let fused = attention_fused_tile(q, k, v, *l, *dk, *dv, *tile);
                let want = attention(q, k, v, *l, *dk, *dv);
                fused.iter().zip(&want).all(|(a, b)| {
                    (a.is_nan() && b.is_nan()) || (a - b).abs() <= 1e-5 + 1e-5 * b.abs()
                })
            },
        );
    }

    /// The nan-pending path, pinned: with `tile = 1` the NaN score column
    /// is processed while the row's running max is still `-inf` (the max
    /// scan skips NaN), yet the unfused softmax poisons the whole row
    /// once its global max is finite — the fused kernel must agree at
    /// every tile size, not just the ones where the NaN shares a tile
    /// with a finite score.
    #[test]
    fn fused_nan_scores_poison_rows_like_unfused() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        let (l, dk, dv) = (6, 3, 2);
        let q: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let mut k: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..l * dv).map(|_| rng.normal() as f32).collect();
        k[0] = f32::NAN; // key row 0 => score column 0 is NaN in every row
        let want = attention(&q, &k, &v, l, dk, dv);
        assert!(want.iter().all(|x| x.is_nan()), "oracle sanity: NaN weight poisons rows");
        for tile in [1, 2, 3, l, KEY_TILE] {
            let got = attention_fused_tile(&q, &k, &v, l, dk, dv, tile);
            assert!(
                got.iter().all(|x| x.is_nan()),
                "fused must poison NaN-scored rows like the oracle (tile {tile})"
            );
        }
    }

    /// Degenerate rows through the fused path: a fully `-inf` score row
    /// (fully masked) and a `+inf`-bearing row both collapse to exactly
    /// zero, matching `softmax_in_place`'s semantics bitwise.
    #[test]
    fn fused_fully_masked_and_inf_rows_are_zero() {
        let (l, dk, dv) = (9, 3, 4);
        let q = vec![1.0f32; l * dk];
        // Every key -inf => every score -inf => every row fully masked.
        let k = vec![f32::NEG_INFINITY; l * dk];
        let v: Vec<f32> = (0..l * dv).map(|i| i as f32).collect();
        for tile in [1, 2, 4, l, KEY_TILE] {
            assert_eq!(
                attention_fused_tile(&q, &k, &v, l, dk, dv, tile),
                vec![0.0; l * dv],
                "fully-masked rows must be exactly zero (tile {tile})"
            );
        }
        // One +inf key: that column's score is +inf in every row, so the
        // unfused softmax zeroes every row; fused must agree even when
        // the +inf lands mid-stream after finite tiles accumulated.
        let mut k2 = vec![1.0f32; l * dk];
        k2[5 * dk] = f32::INFINITY;
        let want = attention(&q, &k2, &v, l, dk, dv);
        assert_eq!(want, vec![0.0; l * dv], "oracle sanity");
        for tile in [1, 2, 3, l, KEY_TILE] {
            assert_eq!(
                attention_fused_tile(&q, &k2, &v, l, dk, dv, tile),
                want,
                "+inf rows must zero through the fused path (tile {tile})"
            );
        }
    }

    #[test]
    fn fused_warm_scratch_rows_are_allocation_free() {
        use crate::kernels::scratch::Scratch;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(5);
        let (l, dk, dv) = (37, 7, 5);
        let q: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..l * dv).map(|_| rng.normal() as f32).collect();
        let mut out = vec![0f32; l * dv];
        let mut scratch = Scratch::new();
        attention_rows_fused_scratch(&q, &k, &v, l, dk, dv, 0, l, &mut out, &mut scratch);
        let warm = scratch.grow_events();
        let mut again = vec![0f32; l * dv];
        attention_rows_fused_scratch(&q, &k, &v, l, dk, dv, 0, l, &mut again, &mut scratch);
        assert_eq!(scratch.grow_events(), warm, "fused hot loop allocated");
        assert_eq!(out, again, "scratch reuse changed results");
    }

    /// Per-row fused results are independent of the row-range split (the
    /// query blocking restarts at each range boundary but carries no
    /// cross-row state), so any partition reproduces the whole-matrix
    /// pass bit for bit — the invariant row-parallel execution rests on.
    #[test]
    fn fused_row_splits_are_bitwise_stable() {
        use crate::kernels::scratch::Scratch;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(29);
        let (l, dk, dv) = (29, 6, 4); // not a QUERY_BLOCK multiple
        let q: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..l * dv).map(|_| rng.normal() as f32).collect();
        let whole = attention_fused(&q, &k, &v, l, dk, dv);
        for mid in [1, 5, QUERY_BLOCK, 13, l - 1] {
            let mut split = vec![0f32; l * dv];
            let (a, b) = split.split_at_mut(mid * dv);
            let mut scratch = Scratch::new();
            attention_rows_fused_scratch(&q, &k, &v, l, dk, dv, 0, mid, a, &mut scratch);
            attention_rows_fused_scratch(&q, &k, &v, l, dk, dv, mid, l, b, &mut scratch);
            assert_eq!(whole, split, "split at {mid}");
        }
    }

    /// The query block is pure locality: every row owns its running
    /// max / denominator / accumulator, so any `query_block` (1 up to the
    /// stack cap) reproduces the default bit for bit at equal key tile.
    /// This is what lets a `TilePlan` tune `query_block` freely without
    /// ever moving outputs.
    #[test]
    fn fused_query_block_never_changes_results() {
        use crate::kernels::tiles::{Tile, MAX_QUERY_BLOCK};
        use crate::util::rng::Rng;
        let mut rng = Rng::new(83);
        let (l, dk, dv) = (43, 6, 5); // ragged vs every block size
        let q: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..l * dk).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..l * dv).map(|_| rng.normal() as f32).collect();
        for kt in [1, 7, 64, KEY_TILE] {
            let want = attention_fused_tile(&q, &k, &v, l, dk, dv, kt);
            for qb in [1, 2, 3, 5, QUERY_BLOCK, 16, MAX_QUERY_BLOCK, MAX_QUERY_BLOCK + 9] {
                let tile = Tile { key_tile: kt, query_block: qb };
                assert_eq!(
                    attention_fused_tiled(&q, &k, &v, l, dk, dv, tile),
                    want,
                    "key_tile={kt} query_block={qb} moved fused outputs"
                );
            }
        }
    }

    #[test]
    fn one_hot_scores_select_v_row() {
        // Orthogonal q/k rows with large magnitude: row r attends ~only to
        // the column sharing its axis, i.e. itself.
        let l = 2;
        let (dk, dv) = (2, 2);
        let mut q = vec![0f32; l * dk];
        for (r, chunk) in q.chunks_exact_mut(dk).enumerate() {
            chunk[r] = 30.0;
        }
        let k = q.clone();
        let v: Vec<f32> = (0..l * dv).map(|i| i as f32).collect();
        let out = attention(&q, &k, &v, l, dk, dv);
        for r in 0..l {
            assert_allclose(
                &out[r * dv..(r + 1) * dv],
                &v[r * dv..(r + 1) * dv],
                1e-3,
                1e-3,
            );
        }
    }
}
