//! Row-parallel execution of the native attention kernels with
//! `std::thread::scope` (rayon is unavailable in the hermetic build).
//!
//! Attention rows are independent end to end — scoring, mask selection,
//! SDDMM, masked softmax and SpMM — so the query dimension is split into
//! contiguous chunks, one per worker, and each worker writes a disjoint
//! slice of the output. Because every chunk performs exactly the
//! operations the single-threaded reference would, results are
//! **bit-identical** regardless of thread count (asserted by the tests).

use super::sparse::ApproxScorer;
use super::{dense, sparse};

/// Resolve a requested worker count: 0 means one worker per available
/// core.
pub fn effective_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Split `out` into per-chunk row slices and run `f(r0, r1, slice)` on
/// scoped worker threads (`threads <= 1` runs inline).
fn par_row_chunks<F>(l: usize, dv: usize, threads: usize, out: &mut [f32], f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), l * dv);
    let threads = threads.clamp(1, l.max(1));
    if threads <= 1 {
        f(0, l, out);
        return;
    }
    let chunk = l.div_ceil(threads);
    let mut slices: Vec<(usize, usize, &mut [f32])> = Vec::with_capacity(threads);
    let mut rest = out;
    let mut r0 = 0;
    while r0 < l {
        let r1 = (r0 + chunk).min(l);
        let (head, tail) = std::mem::take(&mut rest).split_at_mut((r1 - r0) * dv);
        slices.push((r0, r1, head));
        rest = tail;
        r0 = r1;
    }
    let fref = &f;
    std::thread::scope(|s| {
        for (a, b, slice) in slices {
            s.spawn(move || fref(a, b, slice));
        }
    });
}

/// Multi-threaded dense attention (`threads = 0` → one per core).
pub fn dense_attention_mt(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    threads: usize,
) -> Vec<f32> {
    assert_eq!(q.len(), l * dk, "q shape");
    assert_eq!(k.len(), l * dk, "k shape");
    assert_eq!(v.len(), l * dv, "v shape");
    let mut out = vec![0f32; l * dv];
    par_row_chunks(l, dv, effective_threads(threads), &mut out, |r0, r1, slice| {
        dense::attention_rows(q, k, v, l, dk, dv, r0, r1, slice);
    });
    out
}

/// Multi-threaded dynamic-sparse attention: Q/K are quantized once, then
/// each worker runs the full per-row DSA pipeline over its chunk.
#[allow(clippy::too_many_arguments)]
pub fn dsa_attention_mt(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    keep: usize,
    threads: usize,
) -> Vec<f32> {
    assert_eq!(v.len(), l * dv, "v shape");
    let scorer = ApproxScorer::new(q, k, l, dk);
    let mut out = vec![0f32; l * dv];
    par_row_chunks(l, dv, effective_threads(threads), &mut out, |r0, r1, slice| {
        sparse::dsa_attention_rows(q, k, v, l, dk, dv, keep, &scorer, r0, r1, slice);
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn effective_threads_resolves() {
        assert_eq!(effective_threads(3), 3);
        assert!(effective_threads(0) >= 1);
    }

    #[test]
    fn dense_mt_matches_st_bitwise() {
        let mut rng = Rng::new(21);
        let (l, dk, dv) = (67, 8, 5); // odd sizes exercise ragged chunks
        let q = randv(&mut rng, l * dk);
        let k = randv(&mut rng, l * dk);
        let v = randv(&mut rng, l * dv);
        let st = dense::attention(&q, &k, &v, l, dk, dv);
        for threads in [1, 2, 3, 8, 64, 200] {
            let mt = dense_attention_mt(&q, &k, &v, l, dk, dv, threads);
            assert_eq!(st, mt, "threads={threads}");
        }
    }

    #[test]
    fn sparse_mt_matches_st_bitwise() {
        let mut rng = Rng::new(22);
        let (l, dk, dv) = (61, 8, 7);
        let q = randv(&mut rng, l * dk);
        let k = randv(&mut rng, l * dk);
        let v = randv(&mut rng, l * dv);
        for keep in [1, 6, 61] {
            let st = sparse::dsa_attention(&q, &k, &v, l, dk, dv, keep);
            for threads in [2, 5, 16] {
                let mt = dsa_attention_mt(&q, &k, &v, l, dk, dv, keep, threads);
                assert_eq!(st, mt, "keep={keep} threads={threads}");
            }
        }
    }

    #[test]
    fn degenerate_shapes_do_not_panic() {
        let out = dense_attention_mt(&[], &[], &[], 0, 4, 4, 8);
        assert!(out.is_empty());
        let out = dsa_attention_mt(&[0.5], &[0.5], &[1.0], 1, 1, 1, 3, 4);
        assert_eq!(out, vec![1.0]);
    }
}
