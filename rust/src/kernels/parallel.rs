//! Row-parallel execution of the native attention kernels.
//!
//! Attention rows are independent end to end — scoring, mask selection,
//! SDDMM, masked softmax and SpMM — so the work is split into contiguous
//! row-block work items (chunk boundaries aligned to the fused kernels'
//! [`dense::QUERY_BLOCK`], so no query block's tile pass straddles two
//! workers), and each worker writes a disjoint slice of the output
//! through its own reusable [`Scratch`]. Per-row results never depend on
//! the chunking, thread count or execution backend, so every driver is
//! **bit-identical** to its single-threaded reference (asserted by the
//! property tests).
//!
//! The default drivers run the **fused** tiled online-softmax kernels
//! ([`dense::attention_rows_fused_scratch`],
//! [`sparse::dsa_attention_rows_fused_scratch`]); the unfused three-pass
//! forms stay available as `*_unfused_mt_exec` — the property-test oracle
//! and the fused-vs-unfused bench comparator.
//!
//! The **write-into forms** (`*_into_exec`) are the primitives: they take
//! a caller-owned output slice plus an explicit [`Tile`] geometry (the
//! dispatch layer resolves one per `(l, dk)` shape from its `TilePlan`
//! before dispatch, which is what keeps fused outputs bit-identical
//! across thread counts and backends), so a warm caller buffer makes the
//! steady-state dispatch path output-allocation-free. The Vec-returning
//! `*_mt` / `*_mt_exec` forms are thin allocate-and-fill wrappers at
//! [`Tile::DEFAULT`].
//!
//! Two execution backends share the chunking ([`Exec`]):
//!
//! * [`Exec::Pool`] — the default: chunks run as tasks on the persistent
//!   [`WorkerPool`], whose parked workers and warm per-worker scratch
//!   remove the per-dispatch spawn/join and allocation cost (the win is
//!   largest for small problems, `l <= 256`).
//! * [`Exec::Spawn`] — the legacy `std::thread::scope` path, kept as the
//!   benchmark comparator (`bench_kernels` sweeps spawn vs pool).
//!
//! Two granularities share the same chunking machinery:
//!
//! * single-head (`*_mt`): workers split the `l` query rows of one
//!   `(l, dk, dv)` problem.
//! * batched multi-head (`*_batch_mt`): one dispatch covers all
//!   `b * h` problems of a `[b, h, l, d]` batch; workers split the global
//!   `b * h * l` row space, so threads balance across `(batch, head,
//!   row-range)` work items and the per-dispatch cost is paid once for
//!   the whole batch instead of once per head.

use super::pool::{self, ScopedTask, WorkerPool};
use super::scratch::Scratch;
use super::sparse::ApproxScorer;
use super::tiles::Tile;
use super::{dense, sparse};

/// Resolve a requested worker count: 0 means one worker per available
/// core.
pub fn effective_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// How a row-parallel dispatch executes its chunks. Chunking — and
/// therefore the output bits — depends only on the `threads` count, never
/// on the backend; the two variants differ purely in dispatch overhead.
#[derive(Clone, Copy)]
pub enum Exec<'p> {
    /// Per-dispatch `std::thread::scope` spawn/join (legacy path, kept as
    /// the benchmark comparator).
    Spawn,
    /// Tasks on a persistent [`WorkerPool`] with warm per-worker scratch.
    Pool(&'p WorkerPool),
}

impl Exec<'_> {
    /// The production default: the process-wide pool.
    pub fn global_pool() -> Exec<'static> {
        Exec::Pool(WorkerPool::global())
    }
}

/// Split `out` into per-chunk row slices and run `f(r0, r1, slice,
/// scratch)` per chunk on `exec` (`threads <= 1` runs inline on the
/// calling thread's scratch). `rows` counts logical output rows of width
/// `dv` — a single problem's query rows, or the `b * h * l` global row
/// space of a batch. `query_block` is the fused kernels' query blocking
/// for this shape (the unfused drivers pass the default): chunk
/// boundaries align to it so no query block's tile pass straddles two
/// workers.
fn par_row_chunks<F>(
    rows: usize,
    dv: usize,
    threads: usize,
    exec: Exec<'_>,
    query_block: usize,
    out: &mut [f32],
    f: F,
) where
    F: Fn(usize, usize, &mut [f32], &mut Scratch) + Sync,
{
    debug_assert_eq!(out.len(), rows * dv);
    let threads = threads.clamp(1, rows.max(1));
    if threads <= 1 {
        pool::with_local_scratch(|scratch| f(0, rows, out, scratch));
        return;
    }
    // Work items are whole row-blocks: align the chunk size down to a
    // query-block multiple so a fused query block's K/V tile pass never
    // splits across two workers (a few extra sub-`threads` items at the
    // tail just queue on the pool). Outputs are chunking-independent, so
    // this is purely a locality/balance choice.
    let query_block = query_block.max(1);
    let mut chunk = rows.div_ceil(threads);
    if chunk > query_block {
        chunk -= chunk % query_block;
    }
    let mut slices: Vec<(usize, usize, &mut [f32])> = Vec::with_capacity(threads);
    let mut rest = out;
    let mut r0 = 0;
    while r0 < rows {
        let r1 = (r0 + chunk).min(rows);
        let (head, tail) = std::mem::take(&mut rest).split_at_mut((r1 - r0) * dv);
        slices.push((r0, r1, head));
        rest = tail;
        r0 = r1;
    }
    let fref = &f;
    match exec {
        Exec::Spawn => {
            std::thread::scope(|s| {
                for (a, b, slice) in slices {
                    s.spawn(move || {
                        let mut scratch = Scratch::new();
                        fref(a, b, slice, &mut scratch);
                    });
                }
            });
        }
        Exec::Pool(p) => {
            let mut tasks: Vec<ScopedTask<'_>> = Vec::with_capacity(slices.len());
            for (a, b, slice) in slices {
                tasks.push(Box::new(move |scratch: &mut Scratch| {
                    fref(a, b, slice, scratch);
                }));
            }
            p.run_scoped(tasks);
        }
    }
}

/// Multi-threaded **fused** dense attention on the global pool
/// (`threads = 0` → one chunk per core; `threads = 1` runs inline on the
/// calling thread's warm local scratch). Bit-identical to
/// [`dense::attention_fused`].
pub fn dense_attention_mt(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    threads: usize,
) -> Vec<f32> {
    dense_attention_mt_exec(q, k, v, l, dk, dv, threads, Exec::global_pool())
}

/// [`dense_attention_mt`] with an explicit execution backend.
#[allow(clippy::too_many_arguments)]
pub fn dense_attention_mt_exec(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    threads: usize,
    exec: Exec<'_>,
) -> Vec<f32> {
    let mut out = vec![0f32; l * dv];
    dense_attention_into_exec(q, k, v, l, dk, dv, threads, exec, Tile::DEFAULT, &mut out);
    out
}

/// The write-into **primitive** behind the fused dense drivers: runs the
/// fused kernel at an explicit [`Tile`] (resolved per shape by the
/// dispatch layer's `TilePlan`) and writes the `l x dv` context straight
/// into `out` — no output allocation, so a warm caller-owned buffer makes
/// the steady-state dispatch path allocation-free. `out` may hold
/// arbitrary stale data; every row is overwritten.
#[allow(clippy::too_many_arguments)]
pub fn dense_attention_into_exec(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    threads: usize,
    exec: Exec<'_>,
    tile: Tile,
    out: &mut [f32],
) {
    assert_eq!(q.len(), l * dk, "q shape");
    assert_eq!(k.len(), l * dk, "k shape");
    assert_eq!(v.len(), l * dv, "v shape");
    assert_eq!(out.len(), l * dv, "out shape");
    let threads = effective_threads(threads);
    let qb = tile.query_block;
    par_row_chunks(l, dv, threads, exec, qb, out, |r0, r1, slice, scratch| {
        dense::attention_rows_fused_tiled_scratch(q, k, v, l, dk, dv, r0, r1, slice, scratch, tile);
    });
}

/// Multi-threaded **unfused** dense attention — the three-pass reference
/// kernel under the same chunking. Retained as the property-test oracle's
/// parallel form and the fused-vs-unfused bench comparator; bit-identical
/// to [`dense::attention`].
#[allow(clippy::too_many_arguments)]
pub fn dense_attention_unfused_mt_exec(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    threads: usize,
    exec: Exec<'_>,
) -> Vec<f32> {
    assert_eq!(q.len(), l * dk, "q shape");
    assert_eq!(k.len(), l * dk, "k shape");
    assert_eq!(v.len(), l * dv, "v shape");
    let mut out = vec![0f32; l * dv];
    let threads = effective_threads(threads);
    let qb = dense::QUERY_BLOCK;
    par_row_chunks(l, dv, threads, exec, qb, &mut out, |r0, r1, slice, scratch| {
        dense::attention_rows_scratch(q, k, v, l, dk, dv, r0, r1, slice, scratch);
    });
    out
}

/// Multi-threaded **fused** dynamic-sparse attention on the global pool:
/// Q/K are quantized once, then each worker runs the fused per-row DSA
/// pipeline (predict → top-k → fused SDDMM/online-softmax/SpMM) over its
/// row blocks. Bit-identical to [`sparse::dsa_attention_fused`].
#[allow(clippy::too_many_arguments)]
pub fn dsa_attention_mt(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    keep: usize,
    threads: usize,
) -> Vec<f32> {
    dsa_attention_mt_exec(q, k, v, l, dk, dv, keep, threads, Exec::global_pool())
}

/// [`dsa_attention_mt`] with an explicit execution backend.
#[allow(clippy::too_many_arguments)]
pub fn dsa_attention_mt_exec(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    keep: usize,
    threads: usize,
    exec: Exec<'_>,
) -> Vec<f32> {
    let mut out = vec![0f32; l * dv];
    dsa_attention_into_exec(q, k, v, l, dk, dv, keep, threads, exec, Tile::DEFAULT, &mut out);
    out
}

/// The write-into **primitive** behind the fused DSA drivers: quantizes
/// Q/K once, runs the fused per-row pipeline over kept-column chunks of
/// `tile.key_tile`, and writes straight into `out` (no output
/// allocation). `tile.query_block` only shapes the work-item alignment —
/// the DSA pipeline is per-row, so results depend on `key_tile` alone.
#[allow(clippy::too_many_arguments)]
pub fn dsa_attention_into_exec(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    keep: usize,
    threads: usize,
    exec: Exec<'_>,
    tile: Tile,
    out: &mut [f32],
) {
    assert_eq!(v.len(), l * dv, "v shape");
    assert_eq!(out.len(), l * dv, "out shape");
    let scorer = ApproxScorer::new(q, k, l, dk);
    let threads = effective_threads(threads);
    let qb = tile.query_block;
    par_row_chunks(l, dv, threads, exec, qb, out, |r0, r1, slice, scratch| {
        sparse::dsa_attention_rows_fused_tile_scratch(
            q,
            k,
            v,
            l,
            dk,
            dv,
            keep,
            &scorer,
            r0,
            r1,
            slice,
            scratch,
            tile.key_tile,
        );
    });
}

/// Multi-threaded **unfused** dynamic-sparse attention — the oracle
/// pipeline under the same chunking, kept for property tests and the
/// fused-vs-unfused bench sweep; bit-identical to
/// [`sparse::dsa_attention`].
#[allow(clippy::too_many_arguments)]
pub fn dsa_attention_unfused_mt_exec(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    keep: usize,
    threads: usize,
    exec: Exec<'_>,
) -> Vec<f32> {
    assert_eq!(v.len(), l * dv, "v shape");
    let scorer = ApproxScorer::new(q, k, l, dk);
    let mut out = vec![0f32; l * dv];
    let threads = effective_threads(threads);
    let qb = dense::QUERY_BLOCK;
    par_row_chunks(l, dv, threads, exec, qb, &mut out, |r0, r1, slice, scratch| {
        sparse::dsa_attention_rows_scratch(
            q, k, v, l, dk, dv, keep, &scorer, r0, r1, slice, scratch,
        );
    });
    out
}

/// Walk the problems of a `[p, l, ...]` batch that intersect the global
/// row range `[g0, g1)`, calling `f(problem, local_r0, local_r1,
/// out_offset_rows)` per intersection in ascending order.
fn for_problem_ranges<F>(l: usize, g0: usize, g1: usize, mut f: F)
where
    F: FnMut(usize, usize, usize, usize),
{
    let mut g = g0;
    while g < g1 {
        let p = g / l;
        let r0 = g % l;
        let r1 = (r0 + (g1 - g)).min(l);
        f(p, r0, r1, g - g0);
        g += r1 - r0;
    }
}

/// Batched multi-head **fused** dense attention over `[b, h, l, d]`
/// row-major buffers: one dispatch, workers split the `b * h * l` global
/// row space. Bit-identical to running [`dense_attention_mt`] per
/// `(batch, head)` problem and concatenating (asserted by the tests).
#[allow(clippy::too_many_arguments)]
pub fn dense_attention_batch_mt(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    h: usize,
    l: usize,
    dk: usize,
    dv: usize,
    threads: usize,
) -> Vec<f32> {
    dense_attention_batch_mt_exec(q, k, v, b, h, l, dk, dv, threads, Exec::global_pool())
}

/// [`dense_attention_batch_mt`] with an explicit execution backend.
#[allow(clippy::too_many_arguments)]
pub fn dense_attention_batch_mt_exec(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    h: usize,
    l: usize,
    dk: usize,
    dv: usize,
    threads: usize,
    exec: Exec<'_>,
) -> Vec<f32> {
    let mut out = vec![0f32; b * h * l * dv];
    dense_attention_batch_into_exec(
        q,
        k,
        v,
        b,
        h,
        l,
        dk,
        dv,
        threads,
        exec,
        Tile::DEFAULT,
        &mut out,
    );
    out
}

/// The write-into **primitive** behind the fused batched dense driver:
/// one dispatch over the `b * h * l` global row space at an explicit
/// [`Tile`], written straight into `out` (no output allocation — the
/// serving backend reuses a per-bucket buffer across batches).
#[allow(clippy::too_many_arguments)]
pub fn dense_attention_batch_into_exec(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    h: usize,
    l: usize,
    dk: usize,
    dv: usize,
    threads: usize,
    exec: Exec<'_>,
    tile: Tile,
    out: &mut [f32],
) {
    let p = b * h;
    assert_eq!(q.len(), p * l * dk, "q shape");
    assert_eq!(k.len(), p * l * dk, "k shape");
    assert_eq!(v.len(), p * l * dv, "v shape");
    let rows = p * l;
    assert_eq!(out.len(), rows * dv, "out shape");
    let threads = effective_threads(threads);
    let qb = tile.query_block;
    par_row_chunks(rows, dv, threads, exec, qb, out, |g0, g1, slice, scratch| {
        for_problem_ranges(l, g0, g1, |pi, r0, r1, off| {
            dense::attention_rows_fused_tiled_scratch(
                &q[pi * l * dk..(pi + 1) * l * dk],
                &k[pi * l * dk..(pi + 1) * l * dk],
                &v[pi * l * dv..(pi + 1) * l * dv],
                l,
                dk,
                dv,
                r0,
                r1,
                &mut slice[off * dv..(off + r1 - r0) * dv],
                scratch,
                tile,
            );
        });
    });
}

/// Batched multi-head **fused** dynamic-sparse attention over
/// `[b, h, l, d]` buffers. Each `(batch, head)` problem gets its own
/// quantized scorer — exactly what a per-head dispatch would build, so
/// masks and outputs are bit-identical to [`dsa_attention_mt`] per
/// problem (asserted by the tests); workers then split the global row
/// space as in the dense path.
#[allow(clippy::too_many_arguments)]
pub fn dsa_attention_batch_mt(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    h: usize,
    l: usize,
    dk: usize,
    dv: usize,
    keep: usize,
    threads: usize,
) -> Vec<f32> {
    dsa_attention_batch_mt_exec(q, k, v, b, h, l, dk, dv, keep, threads, Exec::global_pool())
}

/// [`dsa_attention_batch_mt`] with an explicit execution backend.
#[allow(clippy::too_many_arguments)]
pub fn dsa_attention_batch_mt_exec(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    h: usize,
    l: usize,
    dk: usize,
    dv: usize,
    keep: usize,
    threads: usize,
    exec: Exec<'_>,
) -> Vec<f32> {
    let mut out = vec![0f32; b * h * l * dv];
    dsa_attention_batch_into_exec(
        q,
        k,
        v,
        b,
        h,
        l,
        dk,
        dv,
        keep,
        threads,
        exec,
        Tile::DEFAULT,
        &mut out,
    );
    out
}

/// The write-into **primitive** behind the fused batched DSA driver: one
/// dispatch over the global row space, per-problem scorers exactly as a
/// per-head dispatch would build them, written straight into `out`.
#[allow(clippy::too_many_arguments)]
pub fn dsa_attention_batch_into_exec(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    h: usize,
    l: usize,
    dk: usize,
    dv: usize,
    keep: usize,
    threads: usize,
    exec: Exec<'_>,
    tile: Tile,
    out: &mut [f32],
) {
    let p = b * h;
    assert_eq!(q.len(), p * l * dk, "q shape");
    assert_eq!(k.len(), p * l * dk, "k shape");
    assert_eq!(v.len(), p * l * dv, "v shape");
    let scorers: Vec<ApproxScorer> = (0..p)
        .map(|pi| {
            ApproxScorer::new(
                &q[pi * l * dk..(pi + 1) * l * dk],
                &k[pi * l * dk..(pi + 1) * l * dk],
                l,
                dk,
            )
        })
        .collect();
    let rows = p * l;
    assert_eq!(out.len(), rows * dv, "out shape");
    let threads = effective_threads(threads);
    let qb = tile.query_block;
    par_row_chunks(rows, dv, threads, exec, qb, out, |g0, g1, slice, scratch| {
        for_problem_ranges(l, g0, g1, |pi, r0, r1, off| {
            sparse::dsa_attention_rows_fused_tile_scratch(
                &q[pi * l * dk..(pi + 1) * l * dk],
                &k[pi * l * dk..(pi + 1) * l * dk],
                &v[pi * l * dv..(pi + 1) * l * dv],
                l,
                dk,
                dv,
                keep,
                &scorers[pi],
                r0,
                r1,
                &mut slice[off * dv..(off + r1 - r0) * dv],
                scratch,
                tile.key_tile,
            );
        });
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Config};
    use crate::util::rng::Rng;

    // Short local names for the unfused comparators (keeps the assertion
    // lines readable).
    use super::dense_attention_unfused_mt_exec as dense_unfused;
    use super::dsa_attention_unfused_mt_exec as dsa_unfused;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn effective_threads_resolves() {
        assert_eq!(effective_threads(3), 3);
        assert!(effective_threads(0) >= 1);
    }

    #[test]
    fn dense_mt_matches_st_bitwise() {
        let mut rng = Rng::new(21);
        let (l, dk, dv) = (67, 8, 5); // odd sizes exercise ragged chunks
        let q = randv(&mut rng, l * dk);
        let k = randv(&mut rng, l * dk);
        let v = randv(&mut rng, l * dv);
        let fused_st = dense::attention_fused(&q, &k, &v, l, dk, dv);
        let unfused_st = dense::attention(&q, &k, &v, l, dk, dv);
        for threads in [1, 2, 3, 8, 64, 200] {
            let mt = dense_attention_mt(&q, &k, &v, l, dk, dv, threads);
            assert_eq!(fused_st, mt, "fused threads={threads}");
            let mt = dense_unfused(&q, &k, &v, l, dk, dv, threads, Exec::global_pool());
            assert_eq!(unfused_st, mt, "unfused threads={threads}");
        }
    }

    #[test]
    fn sparse_mt_matches_st_bitwise() {
        let mut rng = Rng::new(22);
        let (l, dk, dv) = (61, 8, 7);
        let q = randv(&mut rng, l * dk);
        let k = randv(&mut rng, l * dk);
        let v = randv(&mut rng, l * dv);
        for keep in [1, 6, 61] {
            let fused_st = sparse::dsa_attention_fused(&q, &k, &v, l, dk, dv, keep);
            let unfused_st = sparse::dsa_attention(&q, &k, &v, l, dk, dv, keep);
            for threads in [2, 5, 16] {
                let mt = dsa_attention_mt(&q, &k, &v, l, dk, dv, keep, threads);
                assert_eq!(fused_st, mt, "fused keep={keep} threads={threads}");
                let mt = dsa_unfused(&q, &k, &v, l, dk, dv, keep, threads, Exec::global_pool());
                assert_eq!(unfused_st, mt, "unfused keep={keep} threads={threads}");
            }
        }
    }

    /// The tentpole invariant: for random problems, the pool-based
    /// drivers are bit-identical to both the per-dispatch spawn drivers
    /// and their single-threaded references — fused drivers against the
    /// fused references, unfused against unfused — across thread counts
    /// {1, 2, 7, num_cpus} and a pool smaller than the chunk count.
    #[test]
    fn pool_and_spawn_drivers_bit_identical_property() {
        let pool = WorkerPool::new(3); // fewer workers than chunks: tasks queue
        let ncpu = effective_threads(0);
        forall(
            &Config { cases: 16, seed: 0x9001_D5A5 },
            |rng, size| {
                let l = 2 + (rng.next_u64() as usize % (size * 4 + 3));
                let dk = 1 + (rng.next_u64() as usize % 8);
                let dv = 1 + (rng.next_u64() as usize % 8);
                let keep = 1 + (rng.next_u64() as usize % l);
                let q = randv(rng, l * dk);
                let k = randv(rng, l * dk);
                let v = randv(rng, l * dv);
                (l, dk, dv, keep, q, k, v)
            },
            |(l, dk, dv, keep, q, k, v)| {
                let (l, dk, dv, keep) = (*l, *dk, *dv, *keep);
                let dense_ref = dense::attention_fused(q, k, v, l, dk, dv);
                let dense_u = dense::attention(q, k, v, l, dk, dv);
                let dsa_ref = sparse::dsa_attention_fused(q, k, v, l, dk, dv, keep);
                let dsa_u = sparse::dsa_attention(q, k, v, l, dk, dv, keep);
                for threads in [1usize, 2, 7, ncpu] {
                    for exec in [Exec::Spawn, Exec::Pool(&pool)] {
                        let d = dense_attention_mt_exec(q, k, v, l, dk, dv, threads, exec);
                        let s = dsa_attention_mt_exec(q, k, v, l, dk, dv, keep, threads, exec);
                        let du = dense_unfused(q, k, v, l, dk, dv, threads, exec);
                        let su = dsa_unfused(q, k, v, l, dk, dv, keep, threads, exec);
                        if d != dense_ref || s != dsa_ref || du != dense_u || su != dsa_u {
                            return false;
                        }
                    }
                }
                true
            },
        );
    }

    /// The write-into primitives fully overwrite arbitrary stale output
    /// and agree bit for bit with the Vec-returning wrappers — for
    /// single-head and batched forms, at the default and a non-default
    /// tile, across thread counts and both exec backends. This is the
    /// invariant that lets the serving backend reuse one warm buffer
    /// across batches.
    #[test]
    fn into_drivers_overwrite_dirty_buffers_bitwise() {
        let mut rng = Rng::new(91);
        let (b, h, l, dk, dv) = (2, 2, 27, 5, 4);
        let p = b * h;
        let q = randv(&mut rng, p * l * dk);
        let k = randv(&mut rng, p * l * dk);
        let v = randv(&mut rng, p * l * dv);
        let keep = 6;
        let pool = WorkerPool::new(2);
        for tile in [Tile::DEFAULT, Tile { key_tile: 5, query_block: 3 }] {
            for threads in [1, 2, 7] {
                for exec in [Exec::Spawn, Exec::Pool(&pool)] {
                    // single-head (problem 0) — reference at the same tile
                    let q0 = &q[..l * dk];
                    let k0 = &k[..l * dk];
                    let v0 = &v[..l * dv];
                    let want = dense::attention_fused_tiled(q0, k0, v0, l, dk, dv, tile);
                    let mut out = vec![f32::NAN; l * dv]; // poisoned stale data
                    dense_attention_into_exec(q0, k0, v0, l, dk, dv, threads, exec, tile, &mut out);
                    assert_eq!(want, out, "dense into t{threads}");
                    let kt = tile.key_tile;
                    let want = sparse::dsa_attention_fused_tile(q0, k0, v0, l, dk, dv, keep, kt);
                    let mut out = vec![f32::NAN; l * dv];
                    dsa_attention_into_exec(
                        q0, k0, v0, l, dk, dv, keep, threads, exec, tile, &mut out,
                    );
                    assert_eq!(want, out, "dsa into t{threads}");
                    // batched forms against their per-problem loops
                    let mut want = Vec::with_capacity(p * l * dv);
                    for pi in 0..p {
                        want.extend(dense::attention_fused_tiled(
                            &q[pi * l * dk..(pi + 1) * l * dk],
                            &k[pi * l * dk..(pi + 1) * l * dk],
                            &v[pi * l * dv..(pi + 1) * l * dv],
                            l,
                            dk,
                            dv,
                            tile,
                        ));
                    }
                    let mut out = vec![f32::NAN; p * l * dv];
                    dense_attention_batch_into_exec(
                        &q, &k, &v, b, h, l, dk, dv, threads, exec, tile, &mut out,
                    );
                    assert_eq!(want, out, "dense batch into t{threads}");
                    let mut want = Vec::with_capacity(p * l * dv);
                    for pi in 0..p {
                        want.extend(sparse::dsa_attention_fused_tile(
                            &q[pi * l * dk..(pi + 1) * l * dk],
                            &k[pi * l * dk..(pi + 1) * l * dk],
                            &v[pi * l * dv..(pi + 1) * l * dv],
                            l,
                            dk,
                            dv,
                            keep,
                            tile.key_tile,
                        ));
                    }
                    let mut out = vec![f32::NAN; p * l * dv];
                    dsa_attention_batch_into_exec(
                        &q, &k, &v, b, h, l, dk, dv, keep, threads, exec, tile, &mut out,
                    );
                    assert_eq!(want, out, "dsa batch into t{threads}");
                }
            }
        }
    }

    #[test]
    fn problem_ranges_cover_batch_exactly() {
        // ragged split across 3 problems of 5 rows each
        let mut seen = Vec::new();
        for_problem_ranges(5, 3, 14, |p, r0, r1, off| seen.push((p, r0, r1, off)));
        assert_eq!(seen, vec![(0, 3, 5, 0), (1, 0, 5, 2), (2, 0, 4, 7)]);
        // empty range
        for_problem_ranges(5, 4, 4, |_, _, _, _| panic!("must not be called"));
    }

    #[test]
    fn dense_batch_matches_per_problem_bitwise() {
        let mut rng = Rng::new(23);
        let (b, h, l, dk, dv) = (2, 3, 19, 6, 5); // odd l: chunks straddle problems
        let p = b * h;
        let q = randv(&mut rng, p * l * dk);
        let k = randv(&mut rng, p * l * dk);
        let v = randv(&mut rng, p * l * dv);
        let mut looped = Vec::with_capacity(p * l * dv);
        for pi in 0..p {
            looped.extend(dense::attention_fused(
                &q[pi * l * dk..(pi + 1) * l * dk],
                &k[pi * l * dk..(pi + 1) * l * dk],
                &v[pi * l * dv..(pi + 1) * l * dv],
                l,
                dk,
                dv,
            ));
        }
        let pool = WorkerPool::new(2);
        for threads in [1, 2, 4, 7, 32] {
            for exec in [Exec::Spawn, Exec::Pool(&pool), Exec::global_pool()] {
                let batched =
                    dense_attention_batch_mt_exec(&q, &k, &v, b, h, l, dk, dv, threads, exec);
                assert_eq!(looped, batched, "threads={threads}");
            }
        }
    }

    #[test]
    fn sparse_batch_matches_per_problem_bitwise() {
        let mut rng = Rng::new(24);
        let (b, h, l, dk, dv) = (3, 2, 23, 5, 4);
        let p = b * h;
        let q = randv(&mut rng, p * l * dk);
        let k = randv(&mut rng, p * l * dk);
        let v = randv(&mut rng, p * l * dv);
        for keep in [1, 5, 23] {
            let mut looped = Vec::with_capacity(p * l * dv);
            for pi in 0..p {
                looped.extend(sparse::dsa_attention_fused(
                    &q[pi * l * dk..(pi + 1) * l * dk],
                    &k[pi * l * dk..(pi + 1) * l * dk],
                    &v[pi * l * dv..(pi + 1) * l * dv],
                    l,
                    dk,
                    dv,
                    keep,
                ));
            }
            let pool = WorkerPool::new(4);
            for threads in [1, 3, 8] {
                for exec in [Exec::Spawn, Exec::Pool(&pool)] {
                    let batched = dsa_attention_batch_mt_exec(
                        &q, &k, &v, b, h, l, dk, dv, keep, threads, exec,
                    );
                    assert_eq!(looped, batched, "keep={keep} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn degenerate_shapes_do_not_panic() {
        let out = dense_attention_mt(&[], &[], &[], 0, 4, 4, 8);
        assert!(out.is_empty());
        let out = dsa_attention_mt(&[0.5], &[0.5], &[1.0], 1, 1, 1, 3, 4);
        assert_eq!(out, vec![1.0]);
        let out = dense_attention_batch_mt(&[], &[], &[], 0, 8, 16, 4, 4, 8);
        assert!(out.is_empty());
        let out = dsa_attention_batch_mt(&[0.5], &[0.5], &[2.0], 1, 1, 1, 1, 1, 9, 3);
        assert_eq!(out, vec![2.0]);
    }
}
