//! The dynamic-sparse attention pipeline (paper Eq. 4 / Sec. 4): quantized
//! approximate scores predict a per-input row top-k mask, and only the
//! surviving entries run through SDDMM → masked softmax → SpMM.
//!
//! Three equivalent drivers are provided:
//!
//! * [`dsa_attention_rows_fused_scratch`] — the production path: per row,
//!   predict (int8 scores into the scratch row) → exact top-k → then a
//!   **fused** SDDMM + online softmax + SpMM over the kept columns in
//!   [`super::dense::KEY_TILE`]-sized chunks, accumulating straight into
//!   the output row ([`super::dense::online_rescale`] /
//!   [`super::dense::online_finish`]). No full approximate-score matrix,
//!   no intermediate `Vec` returns, no separate softmax pass — the whole
//!   per-row pipeline runs out of one [`Scratch`].
//! * [`dsa_attention_rows`] — the unfused row-range form (SDDMM row →
//!   [`softmax_in_place`] → SpMM row), retained as the oracle the fused
//!   driver is property-tested against.
//! * [`dsa_attention`] — the whole-matrix reference: full approximate-score
//!   matrix (through `Scratch::scores`, see
//!   [`ApproxScorer::full_into`]) → [`crate::sparse::topk::topk_mask_exact`]
//!   → [`crate::sparse::Csr`] → [`sddmm`] → [`masked_softmax`] → [`spmm`].
//!
//! All three select **bitwise-identical masks** (same int8 scores, same
//! exact row top-k — the int8 dot is tier-independent, see
//! [`super::simd`]); the unfused drivers agree bit for bit with each
//! other, the fused driver within a tight tolerance (reassociated
//! softmax). At `keep = l`, unfused matches unfused dense and fused
//! matches fused dense exactly.

use super::dense::{self, softmax_in_place};
use super::scratch::Scratch;
use super::simd;
use crate::sparse::{topk, Csr};

/// Symmetric int8 quantization: `x ≈ q * scale`. An all-zero (or empty)
/// tensor quantizes to scale 0.
pub fn quantize_i8(x: &[f32]) -> (Vec<i8>, f32) {
    let max = x.iter().fold(0f32, |m, &v| m.max(v.abs()));
    if max == 0.0 {
        return (vec![0; x.len()], 0.0);
    }
    let inv = 127.0 / max;
    let q = x
        .iter()
        .map(|&v| (v * inv).round().clamp(-127.0, 127.0) as i8)
        .collect();
    (q, max / 127.0)
}

/// Low-precision score predictor: Q and K quantized to int8 once, rows
/// scored on demand. These approximate scores select the mask; the kept
/// entries are then re-computed exactly by [`sddmm`] (the paper's
/// approximate-prediction / exact-execution split).
pub struct ApproxScorer {
    qq: Vec<i8>,
    kq: Vec<i8>,
    scale: f32,
    l: usize,
    dk: usize,
}

impl ApproxScorer {
    pub fn new(q: &[f32], k: &[f32], l: usize, dk: usize) -> ApproxScorer {
        assert_eq!(q.len(), l * dk, "q shape");
        assert_eq!(k.len(), l * dk, "k shape");
        let (qq, qs) = quantize_i8(q);
        let (kq, ks) = quantize_i8(k);
        ApproxScorer {
            qq,
            kq,
            scale: qs * ks / (dk as f32).sqrt(),
            l,
            dk,
        }
    }

    /// Approximate scores of query row `r` against every key. The int8
    /// dot accumulates exactly in i32 ([`simd::dot_i8`]), so the predicted
    /// scores — and therefore the selected masks — are bitwise identical
    /// across SIMD tiers.
    pub fn score_row(&self, r: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.l);
        let dk = self.dk;
        let qr = &self.qq[r * dk..(r + 1) * dk];
        for (c, o) in out.iter_mut().enumerate() {
            *o = simd::dot_i8(qr, &self.kq[c * dk..(c + 1) * dk]) as f32 * self.scale;
        }
    }

    /// The full `l x l` approximate score matrix, written into a
    /// caller-owned buffer — route it through [`Scratch::scores`] (see
    /// [`Scratch::reserve_scores`]) and repeated dispatches are
    /// allocation-free once the scratch is warm (asserted by the tests).
    pub fn full_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.l * self.l, "scores shape");
        for (r, row) in out.chunks_exact_mut(self.l).enumerate() {
            self.score_row(r, row);
        }
    }

    /// The full `l x l` approximate score matrix as a fresh `Vec` —
    /// convenience for tests/offline analysis; hot paths use
    /// [`ApproxScorer::full_into`] (or [`ApproxScorer::score_row`] per
    /// row) so no `l x l` buffer is allocated per dispatch.
    pub fn full(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.l * self.l];
        self.full_into(&mut out);
        out
    }
}

/// Full approximate score matrix for `q`/`k` (allocating convenience
/// wrapper over [`ApproxScorer::full`] — tests/offline analysis only).
pub fn approx_scores(q: &[f32], k: &[f32], l: usize, dk: usize) -> Vec<f32> {
    ApproxScorer::new(q, k, l, dk).full()
}

/// SDDMM: exact scaled scores computed only at the kept entries of
/// `pattern`, returned aligned with `pattern.col_idx`.
pub fn sddmm(q: &[f32], k: &[f32], dk: usize, pattern: &Csr) -> Vec<f32> {
    let scale = 1.0 / (dk as f32).sqrt();
    let mut vals = Vec::with_capacity(pattern.nnz());
    for r in 0..pattern.rows {
        let qr = &q[r * dk..(r + 1) * dk];
        for &c in pattern.row(r) {
            let kc = &k[c as usize * dk..(c as usize + 1) * dk];
            vals.push(simd::dot_f32(qr, kc) * scale);
        }
    }
    vals
}

/// Masked softmax over CSR values, row by row in place. Rows with no kept
/// entries are skipped; rows whose kept scores are all `-inf` renormalize
/// to zeros (see [`softmax_in_place`]) — never NaN.
pub fn masked_softmax(pattern: &Csr, vals: &mut [f32]) {
    assert_eq!(vals.len(), pattern.nnz(), "values misaligned with pattern");
    for r in 0..pattern.rows {
        let (a, b) = (pattern.row_ptr[r] as usize, pattern.row_ptr[r + 1] as usize);
        softmax_in_place(&mut vals[a..b]);
    }
}

/// SpMM: `out = A V` where sparse `A` has `pattern` structure and `vals`
/// values. Rows with no kept entries produce zero context vectors.
pub fn spmm(pattern: &Csr, vals: &[f32], v: &[f32], dv: usize) -> Vec<f32> {
    assert_eq!(vals.len(), pattern.nnz(), "values misaligned with pattern");
    assert_eq!(v.len(), pattern.cols * dv, "v shape");
    let mut out = vec![0f32; pattern.rows * dv];
    for (r, orow) in out.chunks_exact_mut(dv).enumerate() {
        let base = pattern.row_ptr[r] as usize;
        for (i, &c) in pattern.row(r).iter().enumerate() {
            let w = vals[base + i];
            if w != 0.0 {
                simd::axpy_f32(orow, w, &v[c as usize * dv..(c as usize + 1) * dv]);
            }
        }
    }
    out
}

/// Whole-matrix dynamic-sparse attention reference (single-threaded,
/// unfused). Allocates a throwaway scratch; see
/// [`dsa_attention_scratch`] for the reusable-buffer form.
pub fn dsa_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    keep: usize,
) -> Vec<f32> {
    let mut scratch = Scratch::new();
    dsa_attention_scratch(q, k, v, l, dk, dv, keep, &mut scratch)
}

/// [`dsa_attention`] over a caller-owned [`Scratch`]: the approximate
/// score matrix lives in `scratch.scores` ([`ApproxScorer::full_into`])
/// instead of a fresh `l x l` `Vec` per call, so the prediction stage of
/// a warm scratch records zero grow events (asserted by the tests). The
/// mask/CSR/value stages still allocate — this is the reference path, not
/// the hot one; serving traffic runs the fused row drivers.
#[allow(clippy::too_many_arguments)]
pub fn dsa_attention_scratch(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    keep: usize,
    scratch: &mut Scratch,
) -> Vec<f32> {
    assert_eq!(v.len(), l * dv, "v shape");
    scratch.reserve_scores(l * l);
    let scorer = ApproxScorer::new(q, k, l, dk);
    let scores = &mut scratch.scores[..l * l];
    scorer.full_into(scores);
    let mask = topk::topk_mask_exact(scores, l, l, keep);
    let pattern = Csr::from_mask(&mask);
    let mut vals = sddmm(q, k, dk, &pattern);
    masked_softmax(&pattern, &mut vals);
    spmm(&pattern, &vals, v, dv)
}

/// The full DSA pipeline for query rows `r0..r1`, writing `(r1 - r0) x dv`
/// context rows into `out`. Mask selection (exact row top-k on the shared
/// [`ApproxScorer`], via [`topk::topk_row_indices`] — the same primitive
/// `topk_mask_exact` uses), SDDMM, masked softmax and SpMM all happen per
/// row, so disjoint ranges parallelize with bit-identical results.
#[allow(clippy::too_many_arguments)]
pub fn dsa_attention_rows(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    keep: usize,
    scorer: &ApproxScorer,
    r0: usize,
    r1: usize,
    out: &mut [f32],
) {
    let mut scratch = Scratch::new();
    dsa_attention_rows_scratch(q, k, v, l, dk, dv, keep, scorer, r0, r1, out, &mut scratch);
}

/// [`dsa_attention_rows`] over a caller-owned [`Scratch`]: score row,
/// top-k selection buffer and softmax row are all reused, so the per-row
/// pipeline performs no allocations once the scratch is warm (asserted by
/// the tests via the scratch grow counter).
#[allow(clippy::too_many_arguments)]
pub fn dsa_attention_rows_scratch(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    keep: usize,
    scorer: &ApproxScorer,
    r0: usize,
    r1: usize,
    out: &mut [f32],
    scratch: &mut Scratch,
) {
    debug_assert_eq!(out.len(), (r1 - r0) * dv);
    scratch.reserve(l, keep.min(l.max(1)));
    let scale = 1.0 / (dk as f32).sqrt();
    let srow = &mut scratch.row[..l];
    let vals = &mut scratch.vals;
    let kept = &mut scratch.kept;
    for r in r0..r1 {
        scorer.score_row(r, srow);
        topk::topk_row_indices_into(srow, keep, kept);
        // SDDMM over the kept entries of this row.
        vals.clear();
        let qr = &q[r * dk..(r + 1) * dk];
        for &c in kept.iter() {
            vals.push(simd::dot_f32(qr, &k[c * dk..(c + 1) * dk]) * scale);
        }
        softmax_in_place(vals);
        // SpMM row.
        let orow = &mut out[(r - r0) * dv..(r - r0 + 1) * dv];
        orow.fill(0.0);
        for (&c, &w) in kept.iter().zip(vals.iter()) {
            if w != 0.0 {
                simd::axpy_f32(orow, w, &v[c * dv..(c + 1) * dv]);
            }
        }
    }
}

/// The **fused** per-row DSA pipeline for query rows `r0..r1` at the
/// default [`dense::KEY_TILE`]: predict → exact top-k → SDDMM + online
/// softmax + SpMM in one pass over the kept columns, accumulating
/// directly into `out`. Mask selection is bitwise identical to the
/// unfused drivers (same scorer, same [`topk::topk_row_indices_into`]);
/// the context rows match them within reassociation tolerance — and at
/// `keep = l` match [`dense::attention_rows_fused_tile_scratch`] at the
/// same tile size bit for bit (identical operations in identical order).
#[allow(clippy::too_many_arguments)]
pub fn dsa_attention_rows_fused_scratch(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    keep: usize,
    scorer: &ApproxScorer,
    r0: usize,
    r1: usize,
    out: &mut [f32],
    scratch: &mut Scratch,
) {
    dsa_attention_rows_fused_tile_scratch(
        q, k, v, l, dk, dv, keep, scorer, r0, r1, out, scratch, dense::KEY_TILE,
    );
}

/// [`dsa_attention_rows_fused_scratch`] with an explicit tile size (test
/// sweeps). The approximate score row reuses `scratch.row`, the kept
/// indices `scratch.kept` and the per-chunk exact scores `scratch.vals`,
/// so a warm scratch runs the whole loop allocation-free.
#[allow(clippy::too_many_arguments)]
// lint: hot-path
pub fn dsa_attention_rows_fused_tile_scratch(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    keep: usize,
    scorer: &ApproxScorer,
    r0: usize,
    r1: usize,
    out: &mut [f32],
    scratch: &mut Scratch,
    tile: usize,
) {
    debug_assert_eq!(out.len(), (r1 - r0) * dv);
    let tile = tile.clamp(1, l.max(1));
    scratch.reserve(l, keep.min(l.max(1)));
    let scale = 1.0 / (dk as f32).sqrt();
    for r in r0..r1 {
        scorer.score_row(r, &mut scratch.row[..l]);
        topk::topk_row_indices_into(&scratch.row[..l], keep, &mut scratch.kept);
        let qr = &q[r * dk..(r + 1) * dk];
        let orow = &mut out[(r - r0) * dv..(r - r0 + 1) * dv];
        orow.fill(0.0);
        let (mut m, mut den, mut nanp) = (f32::NEG_INFINITY, 0.0f32, false);
        for chunk in scratch.kept.chunks(tile) {
            scratch.vals.clear();
            for &c in chunk {
                scratch.vals.push(simd::dot_f32(qr, &k[c * dk..(c + 1) * dk]) * scale);
            }
            if dense::online_rescale(simd::max_f32(&scratch.vals), &mut m, &mut den, orow) {
                for (&c, &s) in chunk.iter().zip(scratch.vals.iter()) {
                    let w = (s - m).exp();
                    den += w;
                    if w != 0.0 {
                        simd::axpy_f32(orow, w, &v[c * dv..(c + 1) * dv]);
                    }
                }
            } else if m == f32::NEG_INFINITY {
                nanp = nanp || scratch.vals.iter().any(|s| s.is_nan());
            }
        }
        dense::online_finish(m, den, nanp, orow);
    }
}

/// Full fused dynamic-sparse attention at the default
/// [`dense::KEY_TILE`]: the single-threaded fused reference the
/// multi-threaded fused drivers are bit-identical to.
pub fn dsa_attention_fused(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    keep: usize,
) -> Vec<f32> {
    dsa_attention_fused_tile(q, k, v, l, dk, dv, keep, dense::KEY_TILE)
}

/// [`dsa_attention_fused`] with an explicit tile size (test sweeps).
#[allow(clippy::too_many_arguments)]
pub fn dsa_attention_fused_tile(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    keep: usize,
    tile: usize,
) -> Vec<f32> {
    assert_eq!(v.len(), l * dv, "v shape");
    let scorer = ApproxScorer::new(q, k, l, dk);
    let mut out = vec![0f32; l * dv];
    let mut scratch = Scratch::new();
    dsa_attention_rows_fused_tile_scratch(
        q, k, v, l, dk, dv, keep, &scorer, 0, l, &mut out, &mut scratch, tile,
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::DenseMask;
    use crate::util::prop::{assert_allclose, forall, Config};
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn quantize_roundtrips_within_step() {
        let x = vec![-2.0f32, -0.5, 0.0, 0.7, 1.9];
        let (q, s) = quantize_i8(&x);
        for (orig, &qi) in x.iter().zip(&q) {
            assert!((orig - qi as f32 * s).abs() <= s * 0.5 + 1e-7);
        }
        let (qz, sz) = quantize_i8(&[0.0, 0.0]);
        assert_eq!((qz, sz), (vec![0, 0], 0.0));
    }

    #[test]
    fn approx_scores_track_exact_ranking() {
        let mut rng = Rng::new(1);
        let (l, dk) = (16, 8);
        let q = randv(&mut rng, l * dk);
        let k = randv(&mut rng, l * dk);
        let approx = approx_scores(&q, &k, l, dk);
        let mut exact = vec![0f32; l];
        for r in 0..l {
            super::super::dense::score_row(&q, &k, l, dk, r, &mut exact);
            // int8 x int8 error stays well under the score spread
            assert_allclose(&approx[r * l..(r + 1) * l], &exact, 0.05, 0.25);
        }
    }

    #[test]
    fn masked_softmax_rows_sum_to_one_or_zero() {
        let mut m = DenseMask::zeros(3, 6);
        for c in [0, 2, 5] {
            m.set(0, c, true);
        }
        m.set(2, 1, true);
        // row 1 fully masked (no kept entries)
        let pattern = Csr::from_mask(&m);
        let mut vals = vec![0.3, -1.0, 2.0, 4.0];
        masked_softmax(&pattern, &mut vals);
        let row0: f32 = vals[..3].iter().sum();
        assert!((row0 - 1.0).abs() < 1e-6);
        assert!((vals[3] - 1.0).abs() < 1e-6); // single-entry row
        let out = spmm(&pattern, &vals, &[1.0f32; 12], 2);
        // fully-masked row 1 must be exactly zero, not NaN
        assert_eq!(&out[2..4], &[0.0, 0.0]);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn sparse_at_full_keep_matches_dense_prop() {
        forall(
            &Config { cases: 24, ..Default::default() },
            |rng: &mut Rng, size| {
                let l = 2 + rng.below(4 * size as u64) as usize;
                let dk = 1 + rng.below(16) as usize;
                let dv = 1 + rng.below(16) as usize;
                let q = randv(rng, l * dk);
                let k = randv(rng, l * dk);
                let v = randv(rng, l * dv);
                (q, k, v, l, dk, dv)
            },
            |(q, k, v, l, dk, dv)| {
                let dense = super::super::dense::attention(q, k, v, *l, *dk, *dv);
                let sparse = dsa_attention(q, k, v, *l, *dk, *dv, *l);
                // keep = l: identical op order => bit-for-bit equal
                dense == sparse
            },
        );
    }

    #[test]
    fn row_driver_matches_reference_prop() {
        forall(
            &Config { cases: 24, ..Default::default() },
            |rng: &mut Rng, size| {
                let l = 2 + rng.below(4 * size as u64) as usize;
                let dk = 1 + rng.below(12) as usize;
                let dv = 1 + rng.below(12) as usize;
                let keep = 1 + rng.below(l as u64) as usize;
                let q = randv(rng, l * dk);
                let k = randv(rng, l * dk);
                let v = randv(rng, l * dv);
                (q, k, v, l, dk, dv, keep)
            },
            |(q, k, v, l, dk, dv, keep)| {
                let whole = dsa_attention(q, k, v, *l, *dk, *dv, *keep);
                let scorer = ApproxScorer::new(q, k, *l, *dk);
                let mut by_rows = vec![0f32; l * dv];
                // split at an arbitrary interior row
                let mid = l / 2;
                let (a, b) = by_rows.split_at_mut(mid * dv);
                dsa_attention_rows(q, k, v, *l, *dk, *dv, *keep, &scorer, 0, mid, a);
                dsa_attention_rows(q, k, v, *l, *dk, *dv, *keep, &scorer, mid, *l, b);
                whole == by_rows
            },
        );
    }

    /// Strictly-scalar DSA row pipeline (every inner product through the
    /// `simd::scalar` oracle, same mask selection) — the reference the
    /// dispatched path is compared against without touching the global
    /// SIMD mode. Mask selection reuses the scorer's (bitwise
    /// tier-independent) int8 scores, so both sides prune identically and
    /// only float rounding can differ.
    fn scalar_dsa_attention(
        q: &[f32],
        k: &[f32],
        v: &[f32],
        l: usize,
        dk: usize,
        dv: usize,
        keep: usize,
    ) -> Vec<f32> {
        use crate::kernels::simd::scalar;
        let scorer = ApproxScorer::new(q, k, l, dk);
        let scale = 1.0 / (dk as f32).sqrt();
        let mut out = vec![0f32; l * dv];
        let mut srow = vec![0f32; l];
        for r in 0..l {
            scorer.score_row(r, &mut srow);
            let kept = topk::topk_row_indices(&srow, keep);
            let qr = &q[r * dk..(r + 1) * dk];
            let mut vals: Vec<f32> = kept
                .iter()
                .map(|&c| scalar::dot_f32(qr, &k[c * dk..(c + 1) * dk]) * scale)
                .collect();
            softmax_in_place(&mut vals);
            let orow = &mut out[r * dv..(r + 1) * dv];
            for (&c, &w) in kept.iter().zip(vals.iter()) {
                if w != 0.0 {
                    scalar::axpy_f32(orow, w, &v[c * dv..(c + 1) * dv]);
                }
            }
        }
        out
    }

    #[test]
    fn simd_dsa_matches_scalar_oracle_prop() {
        forall(
            &Config { cases: 24, ..Default::default() },
            |rng: &mut Rng, size| {
                let l = 2 + rng.below(3 * size as u64) as usize;
                let dk = 1 + rng.below(20) as usize;
                let dv = 1 + rng.below(20) as usize;
                let keep = 1 + rng.below(l as u64) as usize;
                let mut q = randv(rng, l * dk);
                let k = randv(rng, l * dk);
                let v = randv(rng, l * dv);
                if size > 16 && rng.f64() < 0.3 {
                    // NaN-bearing inputs: NaN quantizes to 0, the exact
                    // SDDMM re-scores it to NaN — both tiers must agree.
                    let i = rng.below((l * dk) as u64) as usize;
                    q[i] = f32::NAN;
                }
                (q, k, v, l, dk, dv, keep)
            },
            |(q, k, v, l, dk, dv, keep)| {
                let got = dsa_attention(q, k, v, *l, *dk, *dv, *keep);
                let want = scalar_dsa_attention(q, k, v, *l, *dk, *dv, *keep);
                got.iter().zip(&want).all(|(a, b)| {
                    (a.is_nan() && b.is_nan()) || (a - b).abs() <= 1e-5 + 1e-5 * b.abs()
                })
            },
        );
    }

    #[test]
    fn fully_masked_rows_zero_in_every_tier() {
        // A row whose kept scores are all -inf renormalizes to an exactly
        // zero context row — through the dispatched SpMM and the scalar
        // oracle alike (the w != 0 skip makes this bitwise, not allclose).
        let mut m = DenseMask::zeros(2, 4);
        for c in 0..3 {
            m.set(0, c, true);
            m.set(1, c, true);
        }
        let pattern = Csr::from_mask(&m);
        let ninf = f32::NEG_INFINITY;
        let mut vals = vec![0.5, 1.0, -0.25, ninf, ninf, ninf];
        masked_softmax(&pattern, &mut vals);
        let v: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let out = spmm(&pattern, &vals, &v, 4);
        assert!(out[..4].iter().all(|x| x.is_finite()));
        assert_eq!(&out[4..], &[0.0; 4], "fully -inf row must be exactly zero");
    }

    #[test]
    fn warm_scratch_rows_are_allocation_free() {
        let mut rng = Rng::new(11);
        let (l, dk, dv, keep) = (41, 9, 6, 7);
        let q = randv(&mut rng, l * dk);
        let k = randv(&mut rng, l * dk);
        let v = randv(&mut rng, l * dv);
        let scorer = ApproxScorer::new(&q, &k, l, dk);
        let mut out = vec![0f32; l * dv];
        let mut scratch = Scratch::new();
        dsa_attention_rows_scratch(
            &q, &k, &v, l, dk, dv, keep, &scorer, 0, l, &mut out, &mut scratch,
        );
        let warm = scratch.grow_events();
        let mut again = vec![0f32; l * dv];
        dsa_attention_rows_scratch(
            &q, &k, &v, l, dk, dv, keep, &scorer, 0, l, &mut again, &mut scratch,
        );
        assert_eq!(scratch.grow_events(), warm, "hot loop allocated");
        assert_eq!(out, again, "scratch reuse changed results");
        assert_eq!(out, dsa_attention(&q, &k, &v, l, dk, dv, keep));
    }

    /// Tentpole invariant: the fused per-row pipeline matches the unfused
    /// oracle within a tight tolerance across tile sizes (dividing and
    /// non-dividing `keep`, larger than `keep`), ragged shapes, and
    /// NaN-bearing keys (NaN quantizes to 0 for the predictor; rows that
    /// keep the NaN column get a NaN exact score, hitting the nan-pending
    /// path at small tiles) — and selects bitwise-identical masks by
    /// construction (same scorer, same top-k primitive; the output
    /// agreement below would fail on any mask divergence long before the
    /// tolerance did).
    #[test]
    fn fused_matches_unfused_across_tiles_prop() {
        forall(
            &Config { cases: 24, ..Default::default() },
            |rng: &mut Rng, size| {
                let l = 2 + rng.below(3 * size as u64) as usize;
                let dk = 1 + rng.below(12) as usize;
                let dv = 1 + rng.below(12) as usize;
                let keep = 1 + rng.below(l as u64) as usize;
                let tiles = [1, 2, 3, 5, 8, keep, keep + 3, l, super::dense::KEY_TILE];
                let tile = tiles[rng.below(tiles.len() as u64) as usize].max(1);
                let q = randv(rng, l * dk);
                let mut k = randv(rng, l * dk);
                let v = randv(rng, l * dv);
                if size > 16 && rng.f64() < 0.3 {
                    let i = rng.below((l * dk) as u64) as usize;
                    k[i] = f32::NAN;
                }
                (q, k, v, l, dk, dv, keep, tile)
            },
            |(q, k, v, l, dk, dv, keep, tile)| {
                let fused = dsa_attention_fused_tile(q, k, v, *l, *dk, *dv, *keep, *tile);
                let want = dsa_attention(q, k, v, *l, *dk, *dv, *keep);
                fused.iter().zip(&want).all(|(a, b)| {
                    (a.is_nan() && b.is_nan()) || (a - b).abs() <= 1e-5 + 1e-5 * b.abs()
                })
            },
        );
    }

    /// The nan-pending path, pinned (see the dense twin): a NaN key makes
    /// the kept column's exact score NaN; with `tile = 1` that chunk is
    /// folded in while the running max is still `-inf`, and the fused
    /// kernel must still poison exactly the rows the unfused oracle does
    /// (rows that did not keep the NaN column stay finite and close).
    #[test]
    fn fused_nan_scores_poison_rows_like_unfused() {
        let mut rng = Rng::new(78);
        let (l, dk, dv) = (9, 4, 3);
        let q = randv(&mut rng, l * dk);
        let mut k = randv(&mut rng, l * dk);
        let v = randv(&mut rng, l * dv);
        k[0] = f32::NAN; // key row 0 => exact score of column 0 is NaN everywhere
        for keep in [2, l] {
            let want = dsa_attention(&q, &k, &v, l, dk, dv, keep);
            for tile in [1, 2, 3, l, super::dense::KEY_TILE] {
                let got = dsa_attention_fused_tile(&q, &k, &v, l, dk, dv, keep, tile);
                for (a, b) in got.iter().zip(&want) {
                    assert!(
                        (a.is_nan() && b.is_nan())
                            || (a - b).abs() <= 1e-5 + 1e-5 * b.abs(),
                        "keep={keep} tile={tile}: {a} vs {b}"
                    );
                }
            }
        }
    }

    /// At `keep = l` the kept list is exactly `0..l` in ascending order,
    /// so the fused DSA pipeline performs the fused dense kernel's float
    /// operations in the same order — **bit for bit**, at every tile
    /// size. The dense-equivalent guarantee of the unfused pair, carried
    /// over to the fused pair.
    #[test]
    fn fused_at_full_keep_matches_fused_dense_bitwise_prop() {
        forall(
            &Config { cases: 16, ..Default::default() },
            |rng: &mut Rng, size| {
                let l = 2 + rng.below(4 * size as u64) as usize;
                let dk = 1 + rng.below(16) as usize;
                let dv = 1 + rng.below(16) as usize;
                let tiles = [1, 3, 8, l / 2, l, l + 5];
                let tile = tiles[rng.below(tiles.len() as u64) as usize].max(1);
                let q = randv(rng, l * dk);
                let k = randv(rng, l * dk);
                let v = randv(rng, l * dv);
                (q, k, v, l, dk, dv, tile)
            },
            |(q, k, v, l, dk, dv, tile)| {
                let dense = dense::attention_fused_tile(q, k, v, *l, *dk, *dv, *tile);
                let sparse = dsa_attention_fused_tile(q, k, v, *l, *dk, *dv, *l, *tile);
                dense == sparse
            },
        );
    }

    /// Mask selection is shared between fused and unfused drivers: the
    /// per-row `topk_row_indices_into` selection over the scorer's row
    /// equals the whole-matrix `topk_mask_exact` rows bit for bit — the
    /// int8 predictor path is untouched by the fusion.
    #[test]
    fn fused_mask_selection_is_bitwise_identical_prop() {
        forall(
            &Config { cases: 16, ..Default::default() },
            |rng: &mut Rng, size| {
                let l = 2 + rng.below(3 * size as u64) as usize;
                let dk = 1 + rng.below(10) as usize;
                let keep = 1 + rng.below(l as u64) as usize;
                let q = randv(rng, l * dk);
                let k = randv(rng, l * dk);
                (q, k, l, dk, keep)
            },
            |(q, k, l, dk, keep)| {
                let scorer = ApproxScorer::new(q, k, *l, *dk);
                let mask = topk::topk_mask_exact(&scorer.full(), *l, *l, *keep);
                let mut srow = vec![0f32; *l];
                let mut kept = Vec::new();
                (0..*l).all(|r| {
                    scorer.score_row(r, &mut srow);
                    topk::topk_row_indices_into(&srow, *keep, &mut kept);
                    kept == mask.row_cols(r)
                })
            },
        );
    }

    /// Fully-masked rows through the fused path: when every kept score is
    /// `-inf`, the unfused pipeline renormalizes the row to exact zeros —
    /// the fused online softmax must agree bitwise at every tile size.
    #[test]
    fn fused_fully_masked_rows_zero() {
        let (l, dk, dv, keep) = (7, 3, 4, 3);
        // All-ones queries against all -inf keys: every exact SDDMM score
        // is -inf, so every row is fully masked whatever the mask says.
        let q = vec![1.0f32; l * dk];
        let k = vec![f32::NEG_INFINITY; l * dk];
        let v: Vec<f32> = (0..l * dv).map(|i| i as f32).collect();
        let want = dsa_attention(&q, &k, &v, l, dk, dv, keep);
        assert_eq!(want, vec![0.0; l * dv], "oracle sanity");
        for tile in [1, 2, keep, l, super::dense::KEY_TILE] {
            assert_eq!(
                dsa_attention_fused_tile(&q, &k, &v, l, dk, dv, keep, tile),
                want,
                "fully-masked rows must be exactly zero (tile {tile})"
            );
        }
    }

    /// The predictor path is allocation-free under warm scratch: the
    /// whole-matrix reference routes its `l x l` approximate scores
    /// through `Scratch::scores`, and repeated calls record zero grow
    /// events once warm (the satellite fix for `approx_scores` /
    /// `ApproxScorer::full` returning fresh `Vec`s per dispatch).
    #[test]
    fn warm_scratch_predictor_is_allocation_free() {
        let mut rng = Rng::new(17);
        let (l, dk, dv, keep) = (23, 6, 4, 5);
        let q = randv(&mut rng, l * dk);
        let k = randv(&mut rng, l * dk);
        let v = randv(&mut rng, l * dv);
        let scorer = ApproxScorer::new(&q, &k, l, dk);
        let mut scratch = Scratch::new();
        // full_into through the scratch scores buffer
        scratch.reserve_scores(l * l);
        scorer.full_into(&mut scratch.scores[..l * l]);
        let warm = scratch.grow_events();
        scratch.reserve_scores(l * l);
        scorer.full_into(&mut scratch.scores[..l * l]);
        assert_eq!(scratch.grow_events(), warm, "warm full_into allocated");
        assert_eq!(&scratch.scores[..l * l], &scorer.full()[..], "values drifted");
        // and the whole-matrix reference driver on the same scratch
        let first = dsa_attention_scratch(&q, &k, &v, l, dk, dv, keep, &mut scratch);
        let warm = scratch.grow_events();
        let again = dsa_attention_scratch(&q, &k, &v, l, dk, dv, keep, &mut scratch);
        assert_eq!(scratch.grow_events(), warm, "warm predictor path allocated");
        assert_eq!(first, again);
        assert_eq!(first, dsa_attention(&q, &k, &v, l, dk, dv, keep));
    }

    #[test]
    fn fused_warm_scratch_rows_are_allocation_free() {
        let mut rng = Rng::new(12);
        let (l, dk, dv, keep) = (41, 9, 6, 7);
        let q = randv(&mut rng, l * dk);
        let k = randv(&mut rng, l * dk);
        let v = randv(&mut rng, l * dv);
        let scorer = ApproxScorer::new(&q, &k, l, dk);
        let mut out = vec![0f32; l * dv];
        let mut scratch = Scratch::new();
        dsa_attention_rows_fused_scratch(
            &q, &k, &v, l, dk, dv, keep, &scorer, 0, l, &mut out, &mut scratch,
        );
        let warm = scratch.grow_events();
        let mut again = vec![0f32; l * dv];
        dsa_attention_rows_fused_scratch(
            &q, &k, &v, l, dk, dv, keep, &scorer, 0, l, &mut again, &mut scratch,
        );
        assert_eq!(scratch.grow_events(), warm, "fused hot loop allocated");
        assert_eq!(out, again, "scratch reuse changed results");
        assert_eq!(out, dsa_attention_fused(&q, &k, &v, l, dk, dv, keep));
    }

    #[test]
    fn sparsity_actually_prunes() {
        let mut rng = Rng::new(9);
        let (l, dk) = (64, 8);
        let q = randv(&mut rng, l * dk);
        let k = randv(&mut rng, l * dk);
        let scores = approx_scores(&q, &k, l, dk);
        let mask = topk::topk_mask_exact(&scores, l, l, 6);
        assert_eq!(Csr::from_mask(&mask).nnz(), l * 6);
        assert!((mask.sparsity() - (1.0 - 6.0 / 64.0)).abs() < 1e-9);
    }
}
