//! The dynamic-sparse attention pipeline (paper Eq. 4 / Sec. 4): quantized
//! approximate scores predict a per-input row top-k mask, and only the
//! surviving entries run through SDDMM → masked softmax → SpMM.
//!
//! Two equivalent drivers are provided:
//!
//! * [`dsa_attention`] — the whole-matrix reference: full approximate-score
//!   matrix → [`crate::sparse::topk::topk_mask_exact`] →
//!   [`crate::sparse::Csr`] → [`sddmm`] → [`masked_softmax`] → [`spmm`].
//! * [`dsa_attention_rows`] — the row-range form the multi-threaded path
//!   ([`super::parallel`]) drives. Every stage is row-local, so both
//!   drivers perform identical float operations per row and agree bit for
//!   bit — and at `keep = l` they also match [`super::dense`] exactly.

use super::dense::softmax_in_place;
use super::scratch::Scratch;
use super::simd;
use crate::sparse::{topk, Csr};

/// Symmetric int8 quantization: `x ≈ q * scale`. An all-zero (or empty)
/// tensor quantizes to scale 0.
pub fn quantize_i8(x: &[f32]) -> (Vec<i8>, f32) {
    let max = x.iter().fold(0f32, |m, &v| m.max(v.abs()));
    if max == 0.0 {
        return (vec![0; x.len()], 0.0);
    }
    let inv = 127.0 / max;
    let q = x
        .iter()
        .map(|&v| (v * inv).round().clamp(-127.0, 127.0) as i8)
        .collect();
    (q, max / 127.0)
}

/// Low-precision score predictor: Q and K quantized to int8 once, rows
/// scored on demand. These approximate scores select the mask; the kept
/// entries are then re-computed exactly by [`sddmm`] (the paper's
/// approximate-prediction / exact-execution split).
pub struct ApproxScorer {
    qq: Vec<i8>,
    kq: Vec<i8>,
    scale: f32,
    l: usize,
    dk: usize,
}

impl ApproxScorer {
    pub fn new(q: &[f32], k: &[f32], l: usize, dk: usize) -> ApproxScorer {
        assert_eq!(q.len(), l * dk, "q shape");
        assert_eq!(k.len(), l * dk, "k shape");
        let (qq, qs) = quantize_i8(q);
        let (kq, ks) = quantize_i8(k);
        ApproxScorer {
            qq,
            kq,
            scale: qs * ks / (dk as f32).sqrt(),
            l,
            dk,
        }
    }

    /// Approximate scores of query row `r` against every key. The int8
    /// dot accumulates exactly in i32 ([`simd::dot_i8`]), so the predicted
    /// scores — and therefore the selected masks — are bitwise identical
    /// across SIMD tiers.
    pub fn score_row(&self, r: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.l);
        let dk = self.dk;
        let qr = &self.qq[r * dk..(r + 1) * dk];
        for (c, o) in out.iter_mut().enumerate() {
            *o = simd::dot_i8(qr, &self.kq[c * dk..(c + 1) * dk]) as f32 * self.scale;
        }
    }

    /// The full `l x l` approximate score matrix.
    pub fn full(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.l * self.l];
        for (r, row) in out.chunks_exact_mut(self.l).enumerate() {
            self.score_row(r, row);
        }
        out
    }
}

/// Full approximate score matrix for `q`/`k` (convenience wrapper).
pub fn approx_scores(q: &[f32], k: &[f32], l: usize, dk: usize) -> Vec<f32> {
    ApproxScorer::new(q, k, l, dk).full()
}

/// SDDMM: exact scaled scores computed only at the kept entries of
/// `pattern`, returned aligned with `pattern.col_idx`.
pub fn sddmm(q: &[f32], k: &[f32], dk: usize, pattern: &Csr) -> Vec<f32> {
    let scale = 1.0 / (dk as f32).sqrt();
    let mut vals = Vec::with_capacity(pattern.nnz());
    for r in 0..pattern.rows {
        let qr = &q[r * dk..(r + 1) * dk];
        for &c in pattern.row(r) {
            let kc = &k[c as usize * dk..(c as usize + 1) * dk];
            vals.push(simd::dot_f32(qr, kc) * scale);
        }
    }
    vals
}

/// Masked softmax over CSR values, row by row in place. Rows with no kept
/// entries are skipped; rows whose kept scores are all `-inf` renormalize
/// to zeros (see [`softmax_in_place`]) — never NaN.
pub fn masked_softmax(pattern: &Csr, vals: &mut [f32]) {
    assert_eq!(vals.len(), pattern.nnz(), "values misaligned with pattern");
    for r in 0..pattern.rows {
        let (a, b) = (pattern.row_ptr[r] as usize, pattern.row_ptr[r + 1] as usize);
        softmax_in_place(&mut vals[a..b]);
    }
}

/// SpMM: `out = A V` where sparse `A` has `pattern` structure and `vals`
/// values. Rows with no kept entries produce zero context vectors.
pub fn spmm(pattern: &Csr, vals: &[f32], v: &[f32], dv: usize) -> Vec<f32> {
    assert_eq!(vals.len(), pattern.nnz(), "values misaligned with pattern");
    assert_eq!(v.len(), pattern.cols * dv, "v shape");
    let mut out = vec![0f32; pattern.rows * dv];
    for (r, orow) in out.chunks_exact_mut(dv).enumerate() {
        let base = pattern.row_ptr[r] as usize;
        for (i, &c) in pattern.row(r).iter().enumerate() {
            let w = vals[base + i];
            if w != 0.0 {
                simd::axpy_f32(orow, w, &v[c as usize * dv..(c as usize + 1) * dv]);
            }
        }
    }
    out
}

/// Whole-matrix dynamic-sparse attention reference (single-threaded).
pub fn dsa_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    keep: usize,
) -> Vec<f32> {
    assert_eq!(v.len(), l * dv, "v shape");
    let scores = approx_scores(q, k, l, dk);
    let mask = topk::topk_mask_exact(&scores, l, l, keep);
    let pattern = Csr::from_mask(&mask);
    let mut vals = sddmm(q, k, dk, &pattern);
    masked_softmax(&pattern, &mut vals);
    spmm(&pattern, &vals, v, dv)
}

/// The full DSA pipeline for query rows `r0..r1`, writing `(r1 - r0) x dv`
/// context rows into `out`. Mask selection (exact row top-k on the shared
/// [`ApproxScorer`], via [`topk::topk_row_indices`] — the same primitive
/// `topk_mask_exact` uses), SDDMM, masked softmax and SpMM all happen per
/// row, so disjoint ranges parallelize with bit-identical results.
#[allow(clippy::too_many_arguments)]
pub fn dsa_attention_rows(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    keep: usize,
    scorer: &ApproxScorer,
    r0: usize,
    r1: usize,
    out: &mut [f32],
) {
    let mut scratch = Scratch::new();
    dsa_attention_rows_scratch(q, k, v, l, dk, dv, keep, scorer, r0, r1, out, &mut scratch);
}

/// [`dsa_attention_rows`] over a caller-owned [`Scratch`]: score row,
/// top-k selection buffer and softmax row are all reused, so the per-row
/// pipeline performs no allocations once the scratch is warm (asserted by
/// the tests via the scratch grow counter).
#[allow(clippy::too_many_arguments)]
pub fn dsa_attention_rows_scratch(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    l: usize,
    dk: usize,
    dv: usize,
    keep: usize,
    scorer: &ApproxScorer,
    r0: usize,
    r1: usize,
    out: &mut [f32],
    scratch: &mut Scratch,
) {
    debug_assert_eq!(out.len(), (r1 - r0) * dv);
    scratch.reserve(l, keep.min(l.max(1)));
    let scale = 1.0 / (dk as f32).sqrt();
    let srow = &mut scratch.row[..l];
    let vals = &mut scratch.vals;
    let kept = &mut scratch.kept;
    for r in r0..r1 {
        scorer.score_row(r, srow);
        topk::topk_row_indices_into(srow, keep, kept);
        // SDDMM over the kept entries of this row.
        vals.clear();
        let qr = &q[r * dk..(r + 1) * dk];
        for &c in kept.iter() {
            vals.push(simd::dot_f32(qr, &k[c * dk..(c + 1) * dk]) * scale);
        }
        softmax_in_place(vals);
        // SpMM row.
        let orow = &mut out[(r - r0) * dv..(r - r0 + 1) * dv];
        orow.fill(0.0);
        for (&c, &w) in kept.iter().zip(vals.iter()) {
            if w != 0.0 {
                simd::axpy_f32(orow, w, &v[c * dv..(c + 1) * dv]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::DenseMask;
    use crate::util::prop::{assert_allclose, forall, Config};
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn quantize_roundtrips_within_step() {
        let x = vec![-2.0f32, -0.5, 0.0, 0.7, 1.9];
        let (q, s) = quantize_i8(&x);
        for (orig, &qi) in x.iter().zip(&q) {
            assert!((orig - qi as f32 * s).abs() <= s * 0.5 + 1e-7);
        }
        let (qz, sz) = quantize_i8(&[0.0, 0.0]);
        assert_eq!((qz, sz), (vec![0, 0], 0.0));
    }

    #[test]
    fn approx_scores_track_exact_ranking() {
        let mut rng = Rng::new(1);
        let (l, dk) = (16, 8);
        let q = randv(&mut rng, l * dk);
        let k = randv(&mut rng, l * dk);
        let approx = approx_scores(&q, &k, l, dk);
        let mut exact = vec![0f32; l];
        for r in 0..l {
            super::super::dense::score_row(&q, &k, l, dk, r, &mut exact);
            // int8 x int8 error stays well under the score spread
            assert_allclose(&approx[r * l..(r + 1) * l], &exact, 0.05, 0.25);
        }
    }

    #[test]
    fn masked_softmax_rows_sum_to_one_or_zero() {
        let mut m = DenseMask::zeros(3, 6);
        for c in [0, 2, 5] {
            m.set(0, c, true);
        }
        m.set(2, 1, true);
        // row 1 fully masked (no kept entries)
        let pattern = Csr::from_mask(&m);
        let mut vals = vec![0.3, -1.0, 2.0, 4.0];
        masked_softmax(&pattern, &mut vals);
        let row0: f32 = vals[..3].iter().sum();
        assert!((row0 - 1.0).abs() < 1e-6);
        assert!((vals[3] - 1.0).abs() < 1e-6); // single-entry row
        let out = spmm(&pattern, &vals, &[1.0f32; 12], 2);
        // fully-masked row 1 must be exactly zero, not NaN
        assert_eq!(&out[2..4], &[0.0, 0.0]);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn sparse_at_full_keep_matches_dense_prop() {
        forall(
            &Config { cases: 24, ..Default::default() },
            |rng: &mut Rng, size| {
                let l = 2 + rng.below(4 * size as u64) as usize;
                let dk = 1 + rng.below(16) as usize;
                let dv = 1 + rng.below(16) as usize;
                let q = randv(rng, l * dk);
                let k = randv(rng, l * dk);
                let v = randv(rng, l * dv);
                (q, k, v, l, dk, dv)
            },
            |(q, k, v, l, dk, dv)| {
                let dense = super::super::dense::attention(q, k, v, *l, *dk, *dv);
                let sparse = dsa_attention(q, k, v, *l, *dk, *dv, *l);
                // keep = l: identical op order => bit-for-bit equal
                dense == sparse
            },
        );
    }

    #[test]
    fn row_driver_matches_reference_prop() {
        forall(
            &Config { cases: 24, ..Default::default() },
            |rng: &mut Rng, size| {
                let l = 2 + rng.below(4 * size as u64) as usize;
                let dk = 1 + rng.below(12) as usize;
                let dv = 1 + rng.below(12) as usize;
                let keep = 1 + rng.below(l as u64) as usize;
                let q = randv(rng, l * dk);
                let k = randv(rng, l * dk);
                let v = randv(rng, l * dv);
                (q, k, v, l, dk, dv, keep)
            },
            |(q, k, v, l, dk, dv, keep)| {
                let whole = dsa_attention(q, k, v, *l, *dk, *dv, *keep);
                let scorer = ApproxScorer::new(q, k, *l, *dk);
                let mut by_rows = vec![0f32; l * dv];
                // split at an arbitrary interior row
                let mid = l / 2;
                let (a, b) = by_rows.split_at_mut(mid * dv);
                dsa_attention_rows(q, k, v, *l, *dk, *dv, *keep, &scorer, 0, mid, a);
                dsa_attention_rows(q, k, v, *l, *dk, *dv, *keep, &scorer, mid, *l, b);
                whole == by_rows
            },
        );
    }

    /// Strictly-scalar DSA row pipeline (every inner product through the
    /// `simd::scalar` oracle, same mask selection) — the reference the
    /// dispatched path is compared against without touching the global
    /// SIMD mode. Mask selection reuses the scorer's (bitwise
    /// tier-independent) int8 scores, so both sides prune identically and
    /// only float rounding can differ.
    fn scalar_dsa_attention(
        q: &[f32],
        k: &[f32],
        v: &[f32],
        l: usize,
        dk: usize,
        dv: usize,
        keep: usize,
    ) -> Vec<f32> {
        use crate::kernels::simd::scalar;
        let scorer = ApproxScorer::new(q, k, l, dk);
        let scale = 1.0 / (dk as f32).sqrt();
        let mut out = vec![0f32; l * dv];
        let mut srow = vec![0f32; l];
        for r in 0..l {
            scorer.score_row(r, &mut srow);
            let kept = topk::topk_row_indices(&srow, keep);
            let qr = &q[r * dk..(r + 1) * dk];
            let mut vals: Vec<f32> = kept
                .iter()
                .map(|&c| scalar::dot_f32(qr, &k[c * dk..(c + 1) * dk]) * scale)
                .collect();
            softmax_in_place(&mut vals);
            let orow = &mut out[r * dv..(r + 1) * dv];
            for (&c, &w) in kept.iter().zip(vals.iter()) {
                if w != 0.0 {
                    scalar::axpy_f32(orow, w, &v[c * dv..(c + 1) * dv]);
                }
            }
        }
        out
    }

    #[test]
    fn simd_dsa_matches_scalar_oracle_prop() {
        forall(
            &Config { cases: 24, ..Default::default() },
            |rng: &mut Rng, size| {
                let l = 2 + rng.below(3 * size as u64) as usize;
                let dk = 1 + rng.below(20) as usize;
                let dv = 1 + rng.below(20) as usize;
                let keep = 1 + rng.below(l as u64) as usize;
                let mut q = randv(rng, l * dk);
                let k = randv(rng, l * dk);
                let v = randv(rng, l * dv);
                if size > 16 && rng.f64() < 0.3 {
                    // NaN-bearing inputs: NaN quantizes to 0, the exact
                    // SDDMM re-scores it to NaN — both tiers must agree.
                    let i = rng.below((l * dk) as u64) as usize;
                    q[i] = f32::NAN;
                }
                (q, k, v, l, dk, dv, keep)
            },
            |(q, k, v, l, dk, dv, keep)| {
                let got = dsa_attention(q, k, v, *l, *dk, *dv, *keep);
                let want = scalar_dsa_attention(q, k, v, *l, *dk, *dv, *keep);
                got.iter().zip(&want).all(|(a, b)| {
                    (a.is_nan() && b.is_nan()) || (a - b).abs() <= 1e-5 + 1e-5 * b.abs()
                })
            },
        );
    }

    #[test]
    fn fully_masked_rows_zero_in_every_tier() {
        // A row whose kept scores are all -inf renormalizes to an exactly
        // zero context row — through the dispatched SpMM and the scalar
        // oracle alike (the w != 0 skip makes this bitwise, not allclose).
        let mut m = DenseMask::zeros(2, 4);
        for c in 0..3 {
            m.set(0, c, true);
            m.set(1, c, true);
        }
        let pattern = Csr::from_mask(&m);
        let ninf = f32::NEG_INFINITY;
        let mut vals = vec![0.5, 1.0, -0.25, ninf, ninf, ninf];
        masked_softmax(&pattern, &mut vals);
        let v: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let out = spmm(&pattern, &vals, &v, 4);
        assert!(out[..4].iter().all(|x| x.is_finite()));
        assert_eq!(&out[4..], &[0.0; 4], "fully -inf row must be exactly zero");
    }

    #[test]
    fn warm_scratch_rows_are_allocation_free() {
        let mut rng = Rng::new(11);
        let (l, dk, dv, keep) = (41, 9, 6, 7);
        let q = randv(&mut rng, l * dk);
        let k = randv(&mut rng, l * dk);
        let v = randv(&mut rng, l * dv);
        let scorer = ApproxScorer::new(&q, &k, l, dk);
        let mut out = vec![0f32; l * dv];
        let mut scratch = Scratch::new();
        dsa_attention_rows_scratch(
            &q, &k, &v, l, dk, dv, keep, &scorer, 0, l, &mut out, &mut scratch,
        );
        let warm = scratch.grow_events();
        let mut again = vec![0f32; l * dv];
        dsa_attention_rows_scratch(
            &q, &k, &v, l, dk, dv, keep, &scorer, 0, l, &mut again, &mut scratch,
        );
        assert_eq!(scratch.grow_events(), warm, "hot loop allocated");
        assert_eq!(out, again, "scratch reuse changed results");
        assert_eq!(out, dsa_attention(&q, &k, &v, l, dk, dv, keep));
    }

    #[test]
    fn sparsity_actually_prunes() {
        let mut rng = Rng::new(9);
        let (l, dk) = (64, 8);
        let q = randv(&mut rng, l * dk);
        let k = randv(&mut rng, l * dk);
        let scores = approx_scores(&q, &k, l, dk);
        let mask = topk::topk_mask_exact(&scores, l, l, 6);
        assert_eq!(Csr::from_mask(&mask).nnz(), l * 6);
        assert!((mask.sparsity() - (1.0 - 6.0 / 64.0)).abs() < 1e-9);
    }
}
