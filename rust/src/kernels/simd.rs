//! SIMD inner products shared by every native kernel hot loop.
//!
//! All three inner products of the DSA pipeline route through this module:
//! the f32 dot behind dense scoring and SDDMM, the f32 axpy behind dense
//! accumulation and SpMM, and the int8×int8 dot behind the approximate
//! score predictor. Three tiers, selected at runtime per call:
//!
//! * [`scalar`] — strictly-ordered reference loops, the correctness oracle
//!   every other tier is property-tested against.
//! * portable lanes — manual 8-accumulator (`f32x8` / `i32x8`) unrolling
//!   on plain stable Rust. Splitting the reduction across independent
//!   lanes is what lets LLVM vectorize it at all: a single f32 accumulator
//!   forces sequential adds (float addition is not associative), so the
//!   scalar loop can never be packed.
//! * AVX2(+FMA) — the same lane kernels recompiled under
//!   `#[target_feature]` so they use 256-bit registers, selected when
//!   `is_x86_feature_detected!` says the host supports them. Because the
//!   lane code is identical, the AVX2 tier is bit-identical to the
//!   portable tier; only the scalar tier differs (by summation order,
//!   within `~1e-5` relative on attention-scale inputs).
//!
//! The int8 dot accumulates in i32, where order is irrelevant — every tier
//! is **bitwise identical**, so mask selection (and therefore the whole
//! sparse pattern) never depends on the ISA the host happens to have.
//!
//! [`set_mode`] flips every dispatched call site between [`Mode::Scalar`]
//! and [`Mode::Simd`] process-wide; the benches sweep it to measure the
//! SIMD win. Tests never touch the global — they compare tiers directly —
//! so parallel test threads cannot race on it.

use std::sync::atomic::{AtomicU8, Ordering};

/// Accumulator lanes of the manually-unrolled kernels.
pub const LANES: usize = 8;

/// Process-wide kernel tier selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Strictly-ordered scalar loops (the oracle).
    Scalar,
    /// Lane-unrolled kernels, AVX2-specialized when the host supports it.
    Simd,
}

static MODE: AtomicU8 = AtomicU8::new(1);

/// Select the tier every dispatched call uses (benches sweep this; the
/// default is [`Mode::Simd`]).
pub fn set_mode(m: Mode) {
    MODE.store(
        match m {
            Mode::Scalar => 0,
            Mode::Simd => 1,
        },
        Ordering::Relaxed,
    );
}

/// The currently selected tier.
pub fn mode() -> Mode {
    if MODE.load(Ordering::Relaxed) == 0 {
        Mode::Scalar
    } else {
        Mode::Simd
    }
}

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
#[inline]
fn avx2_fma() -> bool {
    // std caches the cpuid probe; this is an atomic load after first use.
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

/// Human-readable name of the instruction set the dispatched calls run on
/// (shows up in bench output and engine startup logs).
pub fn active_isa() -> &'static str {
    match mode() {
        Mode::Scalar => "scalar",
        Mode::Simd => {
            #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
            {
                if avx2_fma() {
                    return "avx2+fma";
                }
            }
            "portable-lanes"
        }
    }
}

/// `a . b` over f32, runtime-dispatched. Slices must have equal length.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match mode() {
        Mode::Scalar => scalar::dot_f32(a, b),
        Mode::Simd => {
            #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
            {
                if avx2_fma() {
                    // SAFETY: guarded by the runtime feature probe above.
                    return unsafe { x86::dot_f32_avx2(a, b) };
                }
            }
            lanes::dot_f32(a, b)
        }
    }
}

/// `out[i] += w * x[i]`, runtime-dispatched. Elementwise (no reduction),
/// so every tier is bit-identical. Slices must have equal length.
#[inline]
pub fn axpy_f32(out: &mut [f32], w: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    match mode() {
        Mode::Scalar => scalar::axpy_f32(out, w, x),
        Mode::Simd => {
            #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
            {
                if avx2_fma() {
                    // SAFETY: guarded by the runtime feature probe above.
                    unsafe { x86::axpy_f32_avx2(out, w, x) };
                    return;
                }
            }
            lanes::axpy_f32(out, w, x)
        }
    }
}

/// `a . b` over int8 accumulating in i32, runtime-dispatched. Integer
/// accumulation commutes, so every tier is bitwise identical — the score
/// predictor's masks never depend on the host ISA. Slices must have equal
/// length. Overflow-safe by construction: `len * 127 * 127 < i32::MAX`
/// for every sequence length this crate can represent.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    match mode() {
        Mode::Scalar => scalar::dot_i8(a, b),
        Mode::Simd => {
            #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
            {
                if avx2_fma() {
                    // SAFETY: guarded by the runtime feature probe above.
                    return unsafe { x86::dot_i8_avx2(a, b) };
                }
            }
            lanes::dot_i8(a, b)
        }
    }
}

/// Strictly-ordered scalar reference loops — the oracle the lane kernels
/// are property-tested against, and the `Mode::Scalar` tier the benches
/// compare SIMD numbers to.
pub mod scalar {
    /// Sequential-order f32 dot product.
    #[inline]
    pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        let mut acc = 0.0f32;
        for (x, y) in a.iter().zip(b) {
            acc += x * y;
        }
        acc
    }

    /// Elementwise `out[i] += w * x[i]`.
    #[inline]
    pub fn axpy_f32(out: &mut [f32], w: f32, x: &[f32]) {
        for (o, &v) in out.iter_mut().zip(x) {
            *o += w * v;
        }
    }

    /// Sequential-order int8 dot accumulating in i32.
    #[inline]
    pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
        let mut acc = 0i32;
        for (&x, &y) in a.iter().zip(b) {
            acc += x as i32 * y as i32;
        }
        acc
    }
}

/// Manually lane-unrolled kernels on plain stable Rust. Eight independent
/// accumulators expose the data parallelism LLVM needs to emit packed
/// instructions; the fixed reduction tree at the end keeps results
/// identical whether the body compiles to SSE2, AVX2, or stays scalar.
mod lanes {
    use super::LANES;

    #[inline(always)]
    pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        let mut acc = [0.0f32; LANES];
        let mut ca = a.chunks_exact(LANES);
        let mut cb = b.chunks_exact(LANES);
        for (xa, xb) in (&mut ca).zip(&mut cb) {
            for ((s, &x), &y) in acc.iter_mut().zip(xa).zip(xb) {
                *s += x * y;
            }
        }
        let mut tail = 0.0f32;
        for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
            tail += x * y;
        }
        // Fixed pairwise reduction: the same order on every ISA.
        let s0 = (acc[0] + acc[4]) + (acc[1] + acc[5]);
        let s1 = (acc[2] + acc[6]) + (acc[3] + acc[7]);
        (s0 + s1) + tail
    }

    #[inline(always)]
    pub fn axpy_f32(out: &mut [f32], w: f32, x: &[f32]) {
        // Elementwise: the plain zip already vectorizes (no reduction),
        // the unrolled form just helps the AVX2 recompile use full-width
        // stores on the exact-chunk body.
        let mut co = out.chunks_exact_mut(LANES);
        let mut cx = x.chunks_exact(LANES);
        for (xo, xx) in (&mut co).zip(&mut cx) {
            for (o, &v) in xo.iter_mut().zip(xx) {
                *o += w * v;
            }
        }
        for (o, &v) in co.into_remainder().iter_mut().zip(cx.remainder()) {
            *o += w * v;
        }
    }

    #[inline(always)]
    pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
        let mut acc = [0i32; LANES];
        let mut ca = a.chunks_exact(LANES);
        let mut cb = b.chunks_exact(LANES);
        for (xa, xb) in (&mut ca).zip(&mut cb) {
            for ((s, &x), &y) in acc.iter_mut().zip(xa).zip(xb) {
                *s += x as i32 * y as i32;
            }
        }
        let mut tail = 0i32;
        for (&x, &y) in ca.remainder().iter().zip(cb.remainder()) {
            tail += x as i32 * y as i32;
        }
        acc.iter().sum::<i32>() + tail
    }
}

/// The lane kernels recompiled for AVX2(+FMA) via `#[target_feature]`:
/// `#[inline(always)]` on the lane bodies lets them inline here and pick
/// up 256-bit codegen. Callers must verify support first (see the
/// dispatchers above).
#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
mod x86 {
    /// # Safety
    /// The host CPU must support AVX2 and FMA.
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub unsafe fn dot_f32_avx2(a: &[f32], b: &[f32]) -> f32 {
        super::lanes::dot_f32(a, b)
    }

    /// # Safety
    /// The host CPU must support AVX2 and FMA.
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub unsafe fn axpy_f32_avx2(out: &mut [f32], w: f32, x: &[f32]) {
        super::lanes::axpy_f32(out, w, x)
    }

    /// # Safety
    /// The host CPU must support AVX2 and FMA.
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub unsafe fn dot_i8_avx2(a: &[i8], b: &[i8]) -> i32 {
        super::lanes::dot_i8(a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, forall, Config};
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn default_mode_is_simd() {
        // Tests never mutate the global mode (it would race with the
        // bitwise tests on other threads); benches own it.
        assert_eq!(mode(), Mode::Simd);
        assert!(!active_isa().is_empty());
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert_eq!(dot_f32(&[], &[]), 0.0);
        assert_eq!(scalar::dot_f32(&[], &[]), 0.0);
        assert_eq!(dot_i8(&[], &[]), 0);
        assert_eq!(dot_f32(&[2.0], &[3.5]), 7.0);
        assert_eq!(dot_i8(&[-4], &[5]), -20);
        let mut out = [1.0f32];
        axpy_f32(&mut out, 2.0, &[3.0]);
        assert_eq!(out, [7.0]);
    }

    /// Dispatched f32 dot matches the scalar oracle within reassociation
    /// tolerance across every remainder-lane residue (lengths 0..=67
    /// cover 0..8 tail elements several times) and NaN-bearing inputs.
    #[test]
    fn dot_f32_matches_scalar_prop() {
        forall(
            &Config { cases: 96, ..Default::default() },
            |rng: &mut Rng, size| {
                let n = rng.below(2 + 2 * size as u64) as usize;
                let mut a = randv(rng, n);
                let b = randv(rng, n);
                if size > 16 && n > 0 && rng.f64() < 0.3 {
                    // NaN-bearing rows: both tiers must agree on NaN-ness.
                    let i = rng.below(n as u64) as usize;
                    a[i] = f32::NAN;
                }
                (a, b)
            },
            |(a, b)| {
                let simd = dot_f32(a, b);
                let oracle = scalar::dot_f32(a, b);
                if oracle.is_nan() {
                    return simd.is_nan();
                }
                let tol = 1e-5f32 * oracle.abs().max(a.len() as f32);
                (simd - oracle).abs() <= tol
            },
        );
    }

    /// int8 dot is bitwise identical to the oracle in every tier — integer
    /// accumulation commutes — across all remainder residues and extreme
    /// (±127) values.
    #[test]
    fn dot_i8_matches_scalar_bitwise_prop() {
        forall(
            &Config { cases: 96, ..Default::default() },
            |rng: &mut Rng, size| {
                let n = rng.below(2 + 2 * size as u64) as usize;
                let a: Vec<i8> =
                    (0..n).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
                let b: Vec<i8> =
                    (0..n).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
                (a, b)
            },
            |(a, b)| dot_i8(a, b) == scalar::dot_i8(a, b),
        );
    }

    /// axpy is elementwise, so the dispatched tier is bitwise equal to the
    /// oracle (no reduction to reassociate).
    #[test]
    fn axpy_matches_scalar_bitwise_prop() {
        forall(
            &Config { cases: 64, ..Default::default() },
            |rng: &mut Rng, size| {
                let n = rng.below(2 + 2 * size as u64) as usize;
                let out = randv(rng, n);
                let x = randv(rng, n);
                let w = rng.normal() as f32;
                (out, x, w)
            },
            |(out, x, w)| {
                let mut a = out.clone();
                let mut b = out.clone();
                axpy_f32(&mut a, *w, x);
                scalar::axpy_f32(&mut b, *w, x);
                a == b
            },
        );
    }

    #[test]
    fn long_dot_accumulates_accurately() {
        // 1024-element dot (the bench shape): lane reduction must stay
        // within float tolerance of the f64 ground truth.
        let mut rng = Rng::new(7);
        let a = randv(&mut rng, 1024);
        let b = randv(&mut rng, 1024);
        let exact: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
        assert_allclose(&[dot_f32(&a, &b)], &[exact as f32], 1e-4, 1e-3);
        assert_allclose(&[scalar::dot_f32(&a, &b)], &[exact as f32], 1e-4, 1e-3);
    }

    #[test]
    fn infinities_do_not_diverge_between_tiers() {
        let mut a = vec![1.0f32; 24];
        let b = vec![1.0f32; 24];
        a[3] = f32::INFINITY;
        let s = scalar::dot_f32(&a, &b);
        let v = dot_f32(&a, &b);
        assert_eq!(s.is_finite(), v.is_finite());
        assert_eq!(s.is_nan(), v.is_nan());
    }
}
