//! SIMD lane primitives shared by every native kernel hot loop.
//!
//! All three inner products of the DSA pipeline route through this module
//! — the f32 dot behind dense scoring and SDDMM, the f32 axpy behind
//! dense accumulation and SpMM, and the int8×int8 dot behind the
//! approximate score predictor — plus the tile-wide primitives of the
//! fused online-softmax kernels: [`max_f32`] (running-max update over a
//! score tile) and [`scale_f32`] (accumulator/denominator rescale when
//! the running max moves). Four tiers, selected at runtime per call:
//!
//! * [`scalar`] — strictly-ordered reference loops, the correctness oracle
//!   every other tier is property-tested against.
//! * portable lanes — manual 8-accumulator (`f32x8` / `i32x8`) unrolling
//!   on plain stable Rust. Splitting the reduction across independent
//!   lanes is what lets LLVM vectorize it at all: a single f32 accumulator
//!   forces sequential adds (float addition is not associative), so the
//!   scalar loop can never be packed.
//! * AVX2(+FMA) — the same 8-lane kernels recompiled under
//!   `#[target_feature]` so they use 256-bit registers, selected when
//!   `is_x86_feature_detected!` says the host supports them. Because the
//!   lane code is identical, the AVX2 tier is bit-identical to the
//!   portable tier; only the scalar tier differs (by summation order,
//!   within `~1e-5` relative on attention-scale inputs).
//! * AVX-512 — 16-lane versions of the same kernels (`lanes16`)
//!   recompiled for `avx512f`(+`avx512bw` for the int8 dot); target
//!   features stable since Rust 1.89, probed at runtime like AVX2. The
//!   wider reduction tree reassociates the f32 dot differently from the
//!   8-lane tiers (same `~1e-5` envelope vs the oracle); max / scale /
//!   axpy are exact elementwise ops and stay bit-identical everywhere.
//!
//! The int8 dot accumulates in i32, where order is irrelevant — every tier
//! (scalar, 8-lane, 16-lane) is **bitwise identical**, so mask selection
//! (and therefore the whole sparse pattern) never depends on the ISA the
//! host happens to have.
//!
//! [`set_mode`] flips every dispatched call site between [`Mode::Scalar`]
//! and [`Mode::Simd`] process-wide; the benches sweep it to measure the
//! SIMD win. Tests never touch the global — they compare tiers directly —
//! so parallel test threads cannot race on it.

use std::sync::atomic::{AtomicU8, Ordering};

/// Accumulator lanes of the manually-unrolled kernels.
pub const LANES: usize = 8;

/// Process-wide kernel tier selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Strictly-ordered scalar loops (the oracle).
    Scalar,
    /// Lane-unrolled kernels, AVX2-specialized when the host supports it.
    Simd,
}

static MODE: AtomicU8 = AtomicU8::new(1);

/// Select the tier every dispatched call uses (benches sweep this; the
/// default is [`Mode::Simd`]).
pub fn set_mode(m: Mode) {
    MODE.store(
        match m {
            Mode::Scalar => 0,
            Mode::Simd => 1,
        },
        Ordering::Relaxed,
    );
}

/// The currently selected tier.
pub fn mode() -> Mode {
    if MODE.load(Ordering::Relaxed) == 0 {
        Mode::Scalar
    } else {
        Mode::Simd
    }
}

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
#[inline]
fn avx2_fma() -> bool {
    // std caches the cpuid probe; this is an atomic load after first use.
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
#[inline]
fn avx512() -> bool {
    // AVX-512 target features are stable since Rust 1.89; avx512bw is
    // required by the widened int8 dot, avx512f by everything else.
    is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx512bw")
}

/// Human-readable name of the instruction set the dispatched calls run on
/// (shows up in bench output and engine startup logs).
pub fn active_isa() -> &'static str {
    match mode() {
        Mode::Scalar => "scalar",
        Mode::Simd => {
            #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
            {
                if avx512() {
                    return "avx512";
                }
                if avx2_fma() {
                    return "avx2+fma";
                }
            }
            "portable-lanes"
        }
    }
}

/// `a . b` over f32, runtime-dispatched. Slices must have equal length.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match mode() {
        Mode::Scalar => scalar::dot_f32(a, b),
        Mode::Simd => {
            #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
            {
                if avx512() {
                    // SAFETY: guarded by the runtime feature probe above.
                    return unsafe { x86_512::dot_f32_avx512(a, b) };
                }
                if avx2_fma() {
                    // SAFETY: guarded by the runtime feature probe above.
                    return unsafe { x86::dot_f32_avx2(a, b) };
                }
            }
            lanes::dot_f32(a, b)
        }
    }
}

/// `out[i] += w * x[i]`, runtime-dispatched. Elementwise (no reduction),
/// so every tier is bit-identical. Slices must have equal length.
#[inline]
pub fn axpy_f32(out: &mut [f32], w: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    match mode() {
        Mode::Scalar => scalar::axpy_f32(out, w, x),
        Mode::Simd => {
            #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
            {
                if avx512() {
                    // SAFETY: guarded by the runtime feature probe above.
                    unsafe { x86_512::axpy_f32_avx512(out, w, x) };
                    return;
                }
                if avx2_fma() {
                    // SAFETY: guarded by the runtime feature probe above.
                    unsafe { x86::axpy_f32_avx2(out, w, x) };
                    return;
                }
            }
            lanes::axpy_f32(out, w, x)
        }
    }
}

/// Maximum over `x` with NaN entries skipped (`f32::NEG_INFINITY` for an
/// empty or all-NaN slice) — the running-max update of the fused
/// online-softmax kernels. The maximum is an exact (order-independent)
/// reduction, so every tier returns the same value; NaN handling matches
/// the unfused `softmax_in_place` max loop (`x > m` is false for NaN).
#[inline]
pub fn max_f32(x: &[f32]) -> f32 {
    match mode() {
        Mode::Scalar => scalar::max_f32(x),
        Mode::Simd => {
            #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
            {
                if avx512() {
                    // SAFETY: guarded by the runtime feature probe above.
                    return unsafe { x86_512::max_f32_avx512(x) };
                }
                if avx2_fma() {
                    // SAFETY: guarded by the runtime feature probe above.
                    return unsafe { x86::max_f32_avx2(x) };
                }
            }
            lanes::max_f32(x)
        }
    }
}

/// `x[i] *= s` — the accumulator/denominator rescale of the fused
/// online-softmax kernels (and their final `1/denominator`
/// normalization). Elementwise, so every tier is bit-identical.
#[inline]
pub fn scale_f32(x: &mut [f32], s: f32) {
    match mode() {
        Mode::Scalar => scalar::scale_f32(x, s),
        Mode::Simd => {
            #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
            {
                if avx512() {
                    // SAFETY: guarded by the runtime feature probe above.
                    unsafe { x86_512::scale_f32_avx512(x, s) };
                    return;
                }
                if avx2_fma() {
                    // SAFETY: guarded by the runtime feature probe above.
                    unsafe { x86::scale_f32_avx2(x, s) };
                    return;
                }
            }
            lanes::scale_f32(x, s)
        }
    }
}

/// `a . b` over int8 accumulating in i32, runtime-dispatched. Integer
/// accumulation commutes, so every tier is bitwise identical — the score
/// predictor's masks never depend on the host ISA. Slices must have equal
/// length. Overflow-safe by construction: `len * 127 * 127 < i32::MAX`
/// for every sequence length this crate can represent.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    match mode() {
        Mode::Scalar => scalar::dot_i8(a, b),
        Mode::Simd => {
            #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
            {
                if avx512() {
                    // SAFETY: guarded by the runtime feature probe above.
                    return unsafe { x86_512::dot_i8_avx512(a, b) };
                }
                if avx2_fma() {
                    // SAFETY: guarded by the runtime feature probe above.
                    return unsafe { x86::dot_i8_avx2(a, b) };
                }
            }
            lanes::dot_i8(a, b)
        }
    }
}

/// Strictly-ordered scalar reference loops — the oracle the lane kernels
/// are property-tested against, and the `Mode::Scalar` tier the benches
/// compare SIMD numbers to.
pub mod scalar {
    /// Sequential-order f32 dot product.
    #[inline]
    pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        let mut acc = 0.0f32;
        for (x, y) in a.iter().zip(b) {
            acc += x * y;
        }
        acc
    }

    /// Elementwise `out[i] += w * x[i]`.
    #[inline]
    pub fn axpy_f32(out: &mut [f32], w: f32, x: &[f32]) {
        for (o, &v) in out.iter_mut().zip(x) {
            *o += w * v;
        }
    }

    /// Sequential-order int8 dot accumulating in i32.
    #[inline]
    pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
        let mut acc = 0i32;
        for (&x, &y) in a.iter().zip(b) {
            acc += x as i32 * y as i32;
        }
        acc
    }

    /// Sequential max with NaN skipped (`-inf` for empty / all-NaN).
    #[inline]
    pub fn max_f32(x: &[f32]) -> f32 {
        let mut m = f32::NEG_INFINITY;
        for &v in x {
            if v > m {
                m = v;
            }
        }
        m
    }

    /// Elementwise `x[i] *= s`.
    #[inline]
    pub fn scale_f32(x: &mut [f32], s: f32) {
        for o in x {
            *o *= s;
        }
    }
}

/// Width-generic lane-kernel bodies shared by every lane count. Only the
/// f32 dot's final reduction is genuinely width-specific (its fixed
/// pairwise tree decides the summation order, so each width hand-writes
/// its own in [`lanes`] / [`lanes16`]); axpy, int8 dot, max and scale
/// are order-insensitive, so one generic body keeps the 8- and 16-lane
/// tiers from drifting apart.
mod wide {
    /// Lane accumulators + sequential tail of the f32 dot. The caller
    /// applies its width's fixed pairwise reduction tree.
    #[inline(always)]
    pub fn dot_f32_acc<const N: usize>(a: &[f32], b: &[f32]) -> ([f32; N], f32) {
        let mut acc = [0.0f32; N];
        let mut ca = a.chunks_exact(N);
        let mut cb = b.chunks_exact(N);
        for (xa, xb) in (&mut ca).zip(&mut cb) {
            for ((s, &x), &y) in acc.iter_mut().zip(xa).zip(xb) {
                *s += x * y;
            }
        }
        let mut tail = 0.0f32;
        for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
            tail += x * y;
        }
        (acc, tail)
    }

    #[inline(always)]
    pub fn axpy_f32<const N: usize>(out: &mut [f32], w: f32, x: &[f32]) {
        // Elementwise: the plain zip already vectorizes (no reduction),
        // the unrolled form just helps the target_feature recompiles use
        // full-width stores on the exact-chunk body.
        let mut co = out.chunks_exact_mut(N);
        let mut cx = x.chunks_exact(N);
        for (xo, xx) in (&mut co).zip(&mut cx) {
            for (o, &v) in xo.iter_mut().zip(xx) {
                *o += w * v;
            }
        }
        for (o, &v) in co.into_remainder().iter_mut().zip(cx.remainder()) {
            *o += w * v;
        }
    }

    #[inline(always)]
    pub fn dot_i8<const N: usize>(a: &[i8], b: &[i8]) -> i32 {
        let mut acc = [0i32; N];
        let mut ca = a.chunks_exact(N);
        let mut cb = b.chunks_exact(N);
        for (xa, xb) in (&mut ca).zip(&mut cb) {
            for ((s, &x), &y) in acc.iter_mut().zip(xa).zip(xb) {
                *s += x as i32 * y as i32;
            }
        }
        let mut tail = 0i32;
        for (&x, &y) in ca.remainder().iter().zip(cb.remainder()) {
            tail += x as i32 * y as i32;
        }
        acc.iter().sum::<i32>() + tail
    }

    #[inline(always)]
    pub fn max_f32<const N: usize>(x: &[f32]) -> f32 {
        let mut acc = [f32::NEG_INFINITY; N];
        let mut cx = x.chunks_exact(N);
        for xa in &mut cx {
            for (m, &v) in acc.iter_mut().zip(xa) {
                if v > *m {
                    *m = v;
                }
            }
        }
        // The maximum is exact, so merging lanes and remainder in any
        // order gives the same result as the scalar loop.
        let mut m = f32::NEG_INFINITY;
        for &v in cx.remainder() {
            if v > m {
                m = v;
            }
        }
        for &lane in &acc {
            if lane > m {
                m = lane;
            }
        }
        m
    }

    #[inline(always)]
    pub fn scale_f32<const N: usize>(x: &mut [f32], s: f32) {
        let mut cx = x.chunks_exact_mut(N);
        for xa in &mut cx {
            for o in xa {
                *o *= s;
            }
        }
        for o in cx.into_remainder() {
            *o *= s;
        }
    }
}

/// The 8-lane kernels ([`wide`] at `N = 8`) on plain stable Rust. Eight
/// independent accumulators expose the data parallelism LLVM needs to
/// emit packed instructions; the fixed reduction tree of the f32 dot
/// keeps results identical whether the body compiles to SSE2, AVX2, or
/// stays scalar.
mod lanes {
    use super::{wide, LANES};

    #[inline(always)]
    pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        let (acc, tail) = wide::dot_f32_acc::<LANES>(a, b);
        // Fixed pairwise reduction: the same order on every ISA.
        let s0 = (acc[0] + acc[4]) + (acc[1] + acc[5]);
        let s1 = (acc[2] + acc[6]) + (acc[3] + acc[7]);
        (s0 + s1) + tail
    }

    #[inline(always)]
    pub fn axpy_f32(out: &mut [f32], w: f32, x: &[f32]) {
        wide::axpy_f32::<LANES>(out, w, x)
    }

    #[inline(always)]
    pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
        wide::dot_i8::<LANES>(a, b)
    }

    #[inline(always)]
    pub fn max_f32(x: &[f32]) -> f32 {
        wide::max_f32::<LANES>(x)
    }

    #[inline(always)]
    pub fn scale_f32(x: &mut [f32], s: f32) {
        wide::scale_f32::<LANES>(x, s)
    }
}

/// The 16-lane kernels ([`wide`] at `N = 16`) for the AVX-512 recompile.
/// The f32 dot's wider fixed reduction tree reassociates differently
/// from the 8-lane tiers (within the oracle tolerance); the int8 dot,
/// max, scale and axpy share [`wide`]'s order-insensitive bodies and
/// stay bitwise tier-independent.
// Reached only through the AVX-512 wrappers (and the tests), so on
// non-x86 targets the bodies are intentionally unreferenced.
#[cfg_attr(not(any(target_arch = "x86", target_arch = "x86_64")), allow(dead_code))]
mod lanes16 {
    use super::wide;

    const LANES16: usize = 16;

    #[inline(always)]
    pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        let (acc, tail) = wide::dot_f32_acc::<LANES16>(a, b);
        // Fixed pairwise reduction: the same order on every ISA.
        let s0 = (acc[0] + acc[8]) + (acc[1] + acc[9]);
        let s1 = (acc[2] + acc[10]) + (acc[3] + acc[11]);
        let s2 = (acc[4] + acc[12]) + (acc[5] + acc[13]);
        let s3 = (acc[6] + acc[14]) + (acc[7] + acc[15]);
        ((s0 + s1) + (s2 + s3)) + tail
    }

    #[inline(always)]
    pub fn axpy_f32(out: &mut [f32], w: f32, x: &[f32]) {
        wide::axpy_f32::<LANES16>(out, w, x)
    }

    #[inline(always)]
    pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
        wide::dot_i8::<LANES16>(a, b)
    }

    #[inline(always)]
    pub fn max_f32(x: &[f32]) -> f32 {
        wide::max_f32::<LANES16>(x)
    }

    #[inline(always)]
    pub fn scale_f32(x: &mut [f32], s: f32) {
        wide::scale_f32::<LANES16>(x, s)
    }
}

/// The lane kernels recompiled for AVX2(+FMA) via `#[target_feature]`:
/// `#[inline(always)]` on the lane bodies lets them inline here and pick
/// up 256-bit codegen. Callers must verify support first (see the
/// dispatchers above).
#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
mod x86 {
    /// # Safety
    /// The host CPU must support AVX2 and FMA.
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    // SAFETY: delegated to callers — only reachable through the
    // `avx2_fma()`-guarded dispatch arms above.
    pub unsafe fn dot_f32_avx2(a: &[f32], b: &[f32]) -> f32 {
        super::lanes::dot_f32(a, b)
    }

    /// # Safety
    /// The host CPU must support AVX2 and FMA.
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    // SAFETY: delegated to callers — only reachable through the
    // `avx2_fma()`-guarded dispatch arms above.
    pub unsafe fn axpy_f32_avx2(out: &mut [f32], w: f32, x: &[f32]) {
        super::lanes::axpy_f32(out, w, x)
    }

    /// # Safety
    /// The host CPU must support AVX2 and FMA.
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    // SAFETY: delegated to callers — only reachable through the
    // `avx2_fma()`-guarded dispatch arms above.
    pub unsafe fn dot_i8_avx2(a: &[i8], b: &[i8]) -> i32 {
        super::lanes::dot_i8(a, b)
    }

    /// # Safety
    /// The host CPU must support AVX2 and FMA.
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    // SAFETY: delegated to callers — only reachable through the
    // `avx2_fma()`-guarded dispatch arms above.
    pub unsafe fn max_f32_avx2(x: &[f32]) -> f32 {
        super::lanes::max_f32(x)
    }

    /// # Safety
    /// The host CPU must support AVX2 and FMA.
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    // SAFETY: delegated to callers — only reachable through the
    // `avx2_fma()`-guarded dispatch arms above.
    pub unsafe fn scale_f32_avx2(x: &mut [f32], s: f32) {
        super::lanes::scale_f32(x, s)
    }
}

/// The 16-lane kernels recompiled for AVX-512 via `#[target_feature]`
/// (stable since Rust 1.89): `#[inline(always)]` on the lane bodies lets
/// them inline here and pick up 512-bit codegen. Callers must verify
/// support first (see the dispatchers above).
#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
mod x86_512 {
    /// # Safety
    /// The host CPU must support AVX-512F.
    #[target_feature(enable = "avx512f")]
    // SAFETY: delegated to callers — only reachable through the
    // `avx512()`-guarded dispatch arms above.
    pub unsafe fn dot_f32_avx512(a: &[f32], b: &[f32]) -> f32 {
        super::lanes16::dot_f32(a, b)
    }

    /// # Safety
    /// The host CPU must support AVX-512F.
    #[target_feature(enable = "avx512f")]
    // SAFETY: delegated to callers — only reachable through the
    // `avx512()`-guarded dispatch arms above.
    pub unsafe fn axpy_f32_avx512(out: &mut [f32], w: f32, x: &[f32]) {
        super::lanes16::axpy_f32(out, w, x)
    }

    /// # Safety
    /// The host CPU must support AVX-512F and AVX-512BW (the widened
    /// int8 -> i32 body needs the byte/word instructions).
    #[target_feature(enable = "avx512f,avx512bw")]
    // SAFETY: delegated to callers — only reachable through the
    // `avx512()`-guarded dispatch arms above.
    pub unsafe fn dot_i8_avx512(a: &[i8], b: &[i8]) -> i32 {
        super::lanes16::dot_i8(a, b)
    }

    /// # Safety
    /// The host CPU must support AVX-512F.
    #[target_feature(enable = "avx512f")]
    // SAFETY: delegated to callers — only reachable through the
    // `avx512()`-guarded dispatch arms above.
    pub unsafe fn max_f32_avx512(x: &[f32]) -> f32 {
        super::lanes16::max_f32(x)
    }

    /// # Safety
    /// The host CPU must support AVX-512F.
    #[target_feature(enable = "avx512f")]
    // SAFETY: delegated to callers — only reachable through the
    // `avx512()`-guarded dispatch arms above.
    pub unsafe fn scale_f32_avx512(x: &mut [f32], s: f32) {
        super::lanes16::scale_f32(x, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, forall, Config};
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn default_mode_is_simd() {
        // Tests never mutate the global mode (it would race with the
        // bitwise tests on other threads); benches own it.
        assert_eq!(mode(), Mode::Simd);
        assert!(!active_isa().is_empty());
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert_eq!(dot_f32(&[], &[]), 0.0);
        assert_eq!(scalar::dot_f32(&[], &[]), 0.0);
        assert_eq!(dot_i8(&[], &[]), 0);
        assert_eq!(dot_f32(&[2.0], &[3.5]), 7.0);
        assert_eq!(dot_i8(&[-4], &[5]), -20);
        let mut out = [1.0f32];
        axpy_f32(&mut out, 2.0, &[3.0]);
        assert_eq!(out, [7.0]);
    }

    /// Dispatched f32 dot matches the scalar oracle within reassociation
    /// tolerance across every remainder-lane residue (lengths 0..=67
    /// cover 0..8 tail elements several times) and NaN-bearing inputs.
    #[test]
    fn dot_f32_matches_scalar_prop() {
        forall(
            &Config { cases: 96, ..Default::default() },
            |rng: &mut Rng, size| {
                let n = rng.below(2 + 2 * size as u64) as usize;
                let mut a = randv(rng, n);
                let b = randv(rng, n);
                if size > 16 && n > 0 && rng.f64() < 0.3 {
                    // NaN-bearing rows: both tiers must agree on NaN-ness.
                    let i = rng.below(n as u64) as usize;
                    a[i] = f32::NAN;
                }
                (a, b)
            },
            |(a, b)| {
                let simd = dot_f32(a, b);
                let oracle = scalar::dot_f32(a, b);
                if oracle.is_nan() {
                    return simd.is_nan();
                }
                let tol = 1e-5f32 * oracle.abs().max(a.len() as f32);
                (simd - oracle).abs() <= tol
            },
        );
    }

    /// int8 dot is bitwise identical to the oracle in every tier — integer
    /// accumulation commutes — across all remainder residues and extreme
    /// (±127) values.
    #[test]
    fn dot_i8_matches_scalar_bitwise_prop() {
        forall(
            &Config { cases: 96, ..Default::default() },
            |rng: &mut Rng, size| {
                let n = rng.below(2 + 2 * size as u64) as usize;
                let a: Vec<i8> =
                    (0..n).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
                let b: Vec<i8> =
                    (0..n).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
                (a, b)
            },
            |(a, b)| dot_i8(a, b) == scalar::dot_i8(a, b),
        );
    }

    /// axpy is elementwise, so the dispatched tier is bitwise equal to the
    /// oracle (no reduction to reassociate).
    #[test]
    fn axpy_matches_scalar_bitwise_prop() {
        forall(
            &Config { cases: 64, ..Default::default() },
            |rng: &mut Rng, size| {
                let n = rng.below(2 + 2 * size as u64) as usize;
                let out = randv(rng, n);
                let x = randv(rng, n);
                let w = rng.normal() as f32;
                (out, x, w)
            },
            |(out, x, w)| {
                let mut a = out.clone();
                let mut b = out.clone();
                axpy_f32(&mut a, *w, x);
                scalar::axpy_f32(&mut b, *w, x);
                a == b
            },
        );
    }

    #[test]
    fn long_dot_accumulates_accurately() {
        // 1024-element dot (the bench shape): lane reduction must stay
        // within float tolerance of the f64 ground truth.
        let mut rng = Rng::new(7);
        let a = randv(&mut rng, 1024);
        let b = randv(&mut rng, 1024);
        let exact: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
        assert_allclose(&[dot_f32(&a, &b)], &[exact as f32], 1e-4, 1e-3);
        assert_allclose(&[scalar::dot_f32(&a, &b)], &[exact as f32], 1e-4, 1e-3);
    }

    #[test]
    fn infinities_do_not_diverge_between_tiers() {
        let mut a = vec![1.0f32; 24];
        let b = vec![1.0f32; 24];
        a[3] = f32::INFINITY;
        let s = scalar::dot_f32(&a, &b);
        let v = dot_f32(&a, &b);
        assert_eq!(s.is_finite(), v.is_finite());
        assert_eq!(s.is_nan(), v.is_nan());
    }

    /// The 16-lane (AVX-512) kernel bodies are plain stable Rust, so they
    /// are testable on any host: f32 dot within reassociation tolerance
    /// of the oracle, int8 dot / axpy / max / scale bitwise — across all
    /// 0..16 remainder residues.
    #[test]
    fn lanes16_matches_scalar_prop() {
        forall(
            &Config { cases: 96, ..Default::default() },
            |rng: &mut Rng, size| {
                let n = rng.below(2 + 2 * size as u64) as usize;
                let a = randv(rng, n);
                let b = randv(rng, n);
                let ai: Vec<i8> =
                    (0..n).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
                let bi: Vec<i8> =
                    (0..n).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
                let w = rng.normal() as f32;
                (a, b, ai, bi, w)
            },
            |(a, b, ai, bi, w)| {
                let oracle = scalar::dot_f32(a, b);
                let tol = 1e-5f32 * oracle.abs().max(a.len() as f32);
                if (lanes16::dot_f32(a, b) - oracle).abs() > tol {
                    return false;
                }
                if lanes16::dot_i8(ai, bi) != scalar::dot_i8(ai, bi) {
                    return false;
                }
                if lanes16::max_f32(a) != scalar::max_f32(a) {
                    return false;
                }
                let mut x = a.clone();
                let mut y = a.clone();
                lanes16::axpy_f32(&mut x, *w, b);
                scalar::axpy_f32(&mut y, *w, b);
                if x != y {
                    return false;
                }
                let mut x = a.clone();
                let mut y = a.clone();
                lanes16::scale_f32(&mut x, *w);
                scalar::scale_f32(&mut y, *w);
                x == y
            },
        );
    }

    /// When the host actually has AVX-512, the recompiled wrappers must
    /// agree with their plain 16-lane bodies bit for bit (identical lane
    /// code, only the codegen target differs). Skipped silently elsewhere.
    #[test]
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    fn avx512_wrappers_match_lanes16_when_supported() {
        if !super::avx512() {
            return;
        }
        let mut rng = Rng::new(13);
        for n in [0usize, 1, 7, 16, 17, 63, 256] {
            let a = randv(&mut rng, n);
            let b = randv(&mut rng, n);
            // SAFETY: probe checked above.
            unsafe {
                assert_eq!(x86_512::dot_f32_avx512(&a, &b), lanes16::dot_f32(&a, &b));
                assert_eq!(x86_512::max_f32_avx512(&a), lanes16::max_f32(&a));
                let mut x = a.clone();
                let mut y = a.clone();
                x86_512::axpy_f32_avx512(&mut x, 1.5, &b);
                lanes16::axpy_f32(&mut y, 1.5, &b);
                assert_eq!(x, y);
                let ai: Vec<i8> = a.iter().map(|&v| (v * 30.0) as i8).collect();
                let bi: Vec<i8> = b.iter().map(|&v| (v * 30.0) as i8).collect();
                assert_eq!(x86_512::dot_i8_avx512(&ai, &bi), lanes16::dot_i8(&ai, &bi));
                let mut x = a.clone();
                let mut y = a;
                x86_512::scale_f32_avx512(&mut x, 0.25);
                lanes16::scale_f32(&mut y, 0.25);
                assert_eq!(x, y);
            }
        }
    }

    /// The dispatched max is bitwise equal to the scalar loop (the
    /// maximum is exact) across remainder residues, and NaN entries are
    /// skipped exactly like `softmax_in_place`'s `x > m` scan.
    #[test]
    fn max_f32_matches_scalar_bitwise_prop() {
        forall(
            &Config { cases: 64, ..Default::default() },
            |rng: &mut Rng, size| {
                let n = rng.below(2 + 2 * size as u64) as usize;
                let mut a = randv(rng, n);
                if size > 8 && n > 0 && rng.f64() < 0.4 {
                    let i = rng.below(n as u64) as usize;
                    a[i] = f32::NAN;
                }
                a
            },
            |a| {
                let got = max_f32(a);
                let want = scalar::max_f32(a);
                got == want || (got.is_nan() && want.is_nan())
            },
        );
    }

    #[test]
    fn max_f32_edge_cases() {
        assert_eq!(max_f32(&[]), f32::NEG_INFINITY);
        assert_eq!(max_f32(&[f32::NAN, f32::NAN]), f32::NEG_INFINITY);
        assert_eq!(max_f32(&[f32::NEG_INFINITY; 20]), f32::NEG_INFINITY);
        assert_eq!(max_f32(&[1.0, f32::NAN, 3.0, 2.0]), 3.0);
        assert_eq!(max_f32(&[-2.0, f32::INFINITY, 1.0]), f32::INFINITY);
    }

    /// scale is elementwise, so the dispatched tier is bitwise equal to
    /// the oracle in every tier.
    #[test]
    fn scale_f32_matches_scalar_bitwise_prop() {
        forall(
            &Config { cases: 64, ..Default::default() },
            |rng: &mut Rng, size| {
                let n = rng.below(2 + 2 * size as u64) as usize;
                (randv(rng, n), rng.normal() as f32)
            },
            |(x, s)| {
                let mut a = x.clone();
                let mut b = x.clone();
                scale_f32(&mut a, *s);
                scalar::scale_f32(&mut b, *s);
                a == b
            },
        );
    }
}
