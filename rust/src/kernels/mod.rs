//! Native CPU implementations of the DSA kernel pipeline — the hermetic
//! hot path the serving stack runs when no AOT artifacts (and no PJRT)
//! are present, and the measured counterpart the analytical cost models
//! (`costmodel`) are validated against.
//!
//! * [`dense`] — dense attention baseline (per-row, single-threaded
//!   reference).
//! * [`sparse`] — the dynamic pipeline of Eq. (4): int8 approximate-score
//!   prediction → exact row top-k mask (`sparse::topk`) → SDDMM → masked
//!   softmax → SpMM over [`crate::sparse::Csr`].
//! * [`simd`] — the shared inner products (f32 dot/axpy, int8×int8 dot):
//!   manual 8-lane unrolling, AVX2-specialized at runtime, with a scalar
//!   oracle every tier is property-tested against.
//! * [`scratch`] — reusable per-worker buffers so the row hot loops are
//!   allocation-free (observable via a grow counter).
//! * [`pool`] — the persistent, channel-fed worker pool (parked workers,
//!   warm per-worker scratch, panic-safe join) every multi-threaded
//!   driver dispatches through; one process-wide pool serves the engine,
//!   benches and tests.
//! * [`parallel`] — row-parallel multi-threaded drivers with bit-identical
//!   results (rows are independent end to end), for single-head problems
//!   and batched multi-head `[b, h, l, d]` dispatches alike; each driver
//!   runs on the pool by default or per-dispatch scoped spawns
//!   ([`parallel::Exec`], the benchmarked comparison).
//! * [`dispatch`] — the [`KernelDispatch`] trait mapping serving variant
//!   names ("dense", "dsa90", …) to kernel implementations, over one
//!   [`AttnInput`] problem or one [`AttnBatch`] per engine batch.
//! * [`model`] — a hand-constructed, training-free needle-counting
//!   classifier over these kernels; the model behind
//!   `coordinator::backend::NativeBackend`.

pub mod dense;
pub mod dispatch;
pub mod model;
pub mod parallel;
pub mod pool;
pub mod scratch;
pub mod simd;
pub mod sparse;

pub use dispatch::{for_variant, AttnBatch, AttnInput, DenseKernel, KernelDispatch, SparseKernel};
pub use model::NativeClassifier;
pub use parallel::Exec;
pub use pool::{PoolStats, WorkerPool};
