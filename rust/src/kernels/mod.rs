//! Native CPU implementations of the DSA kernel pipeline — the hermetic
//! hot path the serving stack runs when no AOT artifacts (and no PJRT)
//! are present, and the measured counterpart the analytical cost models
//! (`costmodel`) are validated against.
//!
//! * [`dense`] — dense attention two ways: the production **fused,
//!   cache-tiled kernel with online softmax** (query blocks × K/V tiles,
//!   running max/denominator per row — one pass over the data) and the
//!   unfused three-pass reference it is property-tested against.
//! * [`sparse`] — the dynamic pipeline of Eq. (4): int8 approximate-score
//!   prediction → exact row top-k mask (`sparse::topk`) → SDDMM → masked
//!   softmax → SpMM; production runs the **fused** per-row form (one pass
//!   over the kept columns, no materialized score matrix), with the
//!   unfused per-row and whole-matrix [`crate::sparse::Csr`] references
//!   retained as oracles. Mask selection is bitwise identical across all
//!   of them.
//! * [`simd`] — the shared lane primitives (f32 dot/axpy, int8×int8 dot,
//!   tile max, rescale): manual 8-lane unrolling, AVX2- and
//!   AVX-512-specialized at runtime, with a scalar oracle every tier is
//!   property-tested against.
//! * [`scratch`] — reusable per-worker buffers so the row hot loops
//!   (fused tiles included) are allocation-free (observable via a grow
//!   counter); also hosts the whole-matrix predictor's score buffer.
//! * [`pool`] — the persistent, channel-fed worker pool (parked workers,
//!   warm per-worker scratch, panic-safe join) every multi-threaded
//!   driver dispatches through; one process-wide pool serves the engine,
//!   benches and tests.
//! * [`parallel`] — row-parallel multi-threaded drivers with bit-identical
//!   results (rows are independent end to end), for single-head problems
//!   and batched multi-head `[b, h, l, d]` dispatches alike; work items
//!   are query-block-aligned row blocks, fused by default with
//!   `*_unfused_mt_exec` comparators, on the pool or per-dispatch scoped
//!   spawns ([`parallel::Exec`], the benchmarked comparison).
//! * [`dispatch`] — the [`KernelDispatch`] trait mapping serving variant
//!   names ("dense", "dsa90", …) to kernel implementations (fused paths
//!   throughout), over one [`AttnInput`] problem or one [`AttnBatch`] per
//!   engine batch.
//! * [`model`] — a hand-constructed, training-free needle-counting
//!   classifier over these kernels; the model behind
//!   `coordinator::backend::NativeBackend`.

pub mod dense;
pub mod dispatch;
pub mod model;
pub mod parallel;
pub mod pool;
pub mod scratch;
pub mod simd;
pub mod sparse;

pub use dispatch::{for_variant, AttnBatch, AttnInput, DenseKernel, KernelDispatch, SparseKernel};
pub use model::NativeClassifier;
pub use parallel::Exec;
pub use pool::{PoolStats, WorkerPool};
