//! Native CPU implementations of the DSA kernel pipeline — the hermetic
//! hot path the serving stack runs when no AOT artifacts (and no PJRT)
//! are present, and the measured counterpart the analytical cost models
//! (`costmodel`) are validated against.
//!
//! * [`dense`] — dense attention baseline (per-row, single-threaded
//!   reference).
//! * [`sparse`] — the dynamic pipeline of Eq. (4): int8 approximate-score
//!   prediction → exact row top-k mask (`sparse::topk`) → SDDMM → masked
//!   softmax → SpMM over [`crate::sparse::Csr`].
//! * [`parallel`] — row-parallel multi-threaded drivers with bit-identical
//!   results (rows are independent end to end).
//! * [`dispatch`] — the [`KernelDispatch`] trait mapping serving variant
//!   names ("dense", "dsa90", …) to kernel implementations.
//! * [`model`] — a hand-constructed, training-free needle-counting
//!   classifier over these kernels; the model behind
//!   `coordinator::backend::NativeBackend`.

pub mod dense;
pub mod dispatch;
pub mod model;
pub mod parallel;
pub mod sparse;

pub use dispatch::{for_variant, AttnInput, DenseKernel, KernelDispatch, SparseKernel};
pub use model::NativeClassifier;
