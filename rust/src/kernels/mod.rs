//! Native CPU implementations of the DSA kernel pipeline — the hermetic
//! hot path the serving stack runs when no AOT artifacts (and no PJRT)
//! are present, and the measured counterpart the analytical cost models
//! (`costmodel`) are validated against.
//!
//! * [`dense`] — dense attention two ways: the production **fused,
//!   cache-tiled kernel with online softmax** (query blocks × K/V tiles,
//!   running max/denominator per row — one pass over the data) and the
//!   unfused three-pass reference it is property-tested against.
//! * [`sparse`] — the dynamic pipeline of Eq. (4): int8 approximate-score
//!   prediction → exact row top-k mask (`sparse::topk`) → SDDMM → masked
//!   softmax → SpMM; production runs the **fused** per-row form (one pass
//!   over the kept columns, no materialized score matrix), with the
//!   unfused per-row and whole-matrix [`crate::sparse::Csr`] references
//!   retained as oracles. Mask selection is bitwise identical across all
//!   of them.
//! * [`simd`] — the shared lane primitives (f32 dot/axpy, int8×int8 dot,
//!   tile max, rescale): manual 8-lane unrolling, AVX2- and
//!   AVX-512-specialized at runtime, with a scalar oracle every tier is
//!   property-tested against.
//! * [`scratch`] — reusable per-worker buffers so the row hot loops
//!   (fused tiles included) are allocation-free (observable via a grow
//!   counter); also hosts the whole-matrix predictor's score buffer.
//! * [`pool`] — the persistent, channel-fed worker pool (parked workers,
//!   warm per-worker scratch, panic-safe join) every multi-threaded
//!   driver dispatches through; one process-wide pool serves the engine,
//!   benches and tests.
//! * [`parallel`] — row-parallel multi-threaded drivers with bit-identical
//!   results (rows are independent end to end), for single-head problems
//!   and batched multi-head `[b, h, l, d]` dispatches alike; the
//!   write-into `*_into_exec` forms (caller-owned output, explicit
//!   [`Tile`]) are the primitives, Vec-returning `*_mt` forms are thin
//!   wrappers; work items are query-block-aligned row blocks, fused by
//!   default with `*_unfused_mt_exec` comparators, on the pool or
//!   per-dispatch scoped spawns ([`parallel::Exec`], the benchmarked
//!   comparison).
//! * [`tiles`] — per-shape fused-kernel tile geometry: [`Tile`]
//!   (`key_tile` × `query_block`), the immutable `(l, dk)`-keyed
//!   [`TilePlan`] resolved once per dispatch (fallback = today's
//!   `KEY_TILE = 256` / `QUERY_BLOCK = 8` constants), and the committed
//!   offline-tuned table (`dsa-serve tile-plan` keeps the derived
//!   artifact in sync; the `bench_kernels` tile sweep is the tuner).
//! * [`dispatch`] — the typed dispatch surface: the [`Variant`] enum (the
//!   single source of truth for variant names, `FromStr`/`Display`), the
//!   [`KernelSpec`] execution parameters (`threads` + [`ExecPolicy`] +
//!   [`TilePlan`]), the [`KernelDispatch`] trait whose allocation-free
//!   `forward_into` / `forward_batch_into` primitives the serving hot
//!   path runs (Vec forms are default wrappers), and the pluggable
//!   [`KernelRegistry`] where variant families register builders
//!   ([`for_variant`] survives as a parse-then-build shim).
//! * [`kvcache`] — the ragged, bucket-pooled per-session K/V cache for
//!   autoregressive decode: [`KvCache`] (f32 K/V rows + an int8 key
//!   mirror maintained bitwise-equal to a whole-prefix quantization,
//!   grown in [`kvcache::BUCKET_ROWS`] buckets under a grow counter) and
//!   [`KvCachePool`] (free-list recycling in the `ModelScratch` style,
//!   so steady-state decode is allocation-free).
//! * [`decode`] — fused single-query decode kernels over a [`KvCache`]:
//!   dense (the fused tiled kernel at one query row — bitwise equal to
//!   its row of the full fused forward) and DSA (the int8 predictor
//!   scores only the new row against the cached key mirror, top-k
//!   selects cached columns, fused online-softmax execution), plus the
//!   unfused decode oracle; dispatched via
//!   [`KernelDispatch::decode_into`].
//! * [`model`] — a hand-constructed, training-free needle-counting
//!   classifier over these kernels; the model behind
//!   `coordinator::backend::NativeBackend`. Hosts the session-oriented
//!   decode surface (`open_session` / `decode_step` over a [`KvCache`]).

pub mod decode;
pub mod dense;
pub mod dispatch;
pub mod kvcache;
pub mod model;
pub mod parallel;
pub mod pool;
pub mod scratch;
pub mod simd;
pub mod sparse;
pub mod tiles;

pub use dispatch::{
    for_variant, AttnBatch, AttnInput, DenseKernel, ExecPolicy, KernelDispatch, KernelRegistry,
    KernelSpec, SparseKernel, Variant,
};
pub use kvcache::{KvCache, KvCachePool, KvPoolStats};
pub use model::{DecodeSession, NativeClassifier};
pub use parallel::Exec;
pub use pool::{PoolStats, WorkerPool};
pub use tiles::{Tile, TilePlan};
