//! Ragged, bucket-pooled per-session K/V cache for autoregressive decode.
//!
//! A [`KvCache`] holds one session's cached key/value rows plus an int8
//! mirror of the keys for the DSA score predictor. Capacity grows in
//! [`BUCKET_ROWS`]-row buckets (observable via [`KvCache::grow_events`],
//! in the `Scratch` grow-counter style) and is retained across
//! [`KvCache::reset`], so a cache recycled through [`KvCachePool`] serves
//! its next session — and every steady-state decode step — with **zero**
//! allocations until the session outgrows the previously seen capacity
//! (asserted by the tests here and end-to-end in `tests/native_engine.rs`).
//!
//! The int8 key mirror is maintained **incrementally but bitwise-equal to
//! a whole-prefix [`quantize_i8`](crate::kernels::sparse::quantize_i8)**:
//! the cache tracks the running max-|K| (the same NaN-skipping
//! `fold(0f32, max)` the one-shot quantizer uses — max is order-free, so
//! the running value equals the whole-prefix fold exactly). A new row
//! within the current max quantizes only itself; a row that raises the
//! max re-quantizes every cached row at the new scale. Either way
//! `ki8`/`k_scale` are bit-identical to quantizing the full prefix at
//! once, which is what pins DSA decode to the one-shot fused forward
//! (see `kernels::decode`).

use super::sparse;

/// Cache capacity grows in buckets of this many rows (matching the
/// engine's batch-bucket spirit: a handful of grows per session, then
/// allocation-free steady state).
pub const BUCKET_ROWS: usize = 64;

#[inline]
fn quant(x: f32, inv: f32) -> i8 {
    // Exactly `quantize_i8`'s per-entry expression (NaN casts to 0, as
    // there).
    (x * inv).round().clamp(-127.0, 127.0) as i8
}

/// One session's cached K/V rows (`len x dk` keys, `len x dv` values)
/// plus the int8 key mirror the DSA predictor scores against.
#[derive(Debug)]
pub struct KvCache {
    k: Vec<f32>,
    v: Vec<f32>,
    ki8: Vec<i8>,
    /// Running max-|K| over every cached key entry — equals the
    /// whole-prefix `quantize_i8` fold bitwise.
    kmax: f32,
    len: usize,
    cap_rows: usize,
    dk: usize,
    dv: usize,
    grows: u64,
}

impl KvCache {
    pub fn new(dk: usize, dv: usize) -> KvCache {
        assert!(dk > 0 && dv > 0, "KvCache dims must be positive");
        KvCache {
            k: Vec::new(),
            v: Vec::new(),
            ki8: Vec::new(),
            kmax: 0.0,
            len: 0,
            cap_rows: 0,
            dk,
            dv,
            grows: 0,
        }
    }

    /// Cached rows (tokens resident).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn dk(&self) -> usize {
        self.dk
    }

    pub fn dv(&self) -> usize {
        self.dv
    }

    /// Cached keys, row-major `len x dk`.
    pub fn k(&self) -> &[f32] {
        &self.k
    }

    /// Cached values, row-major `len x dv`.
    pub fn v(&self) -> &[f32] {
        &self.v
    }

    /// Int8 key mirror, bitwise-equal to `quantize_i8(self.k()).0`.
    pub fn ki8(&self) -> &[i8] {
        &self.ki8
    }

    /// Dequantization scale of [`KvCache::ki8`], bitwise-equal to
    /// `quantize_i8(self.k()).1`.
    pub fn k_scale(&self) -> f32 {
        if self.kmax == 0.0 {
            0.0
        } else {
            self.kmax / 127.0
        }
    }

    /// Bucket-capacity grow events on this cache (monotone; survives
    /// [`KvCache::reset`] so pooled reuse is observable as *zero* new
    /// events).
    pub fn grow_events(&self) -> u64 {
        self.grows
    }

    /// Row capacity currently reserved (a multiple of [`BUCKET_ROWS`]).
    pub fn capacity_rows(&self) -> usize {
        self.cap_rows
    }

    /// Empty the cache for a new session, keeping every reserved bucket
    /// (and the grow counter) so the next session reuses the capacity.
    pub fn reset(&mut self) {
        self.len = 0;
        self.kmax = 0.0;
        self.k.clear();
        self.v.clear();
        self.ki8.clear();
    }

    /// Pre-reserve capacity for `rows` total rows (rounded up to a
    /// bucket multiple) as **one** grow event, so a journal replay of a
    /// known length pays a single allocation instead of one per bucket.
    /// No-op when the cache already holds enough capacity — recycled
    /// pool caches replay entirely allocation-free.
    pub fn reserve_rows(&mut self, rows: usize) {
        let want = rows.div_ceil(BUCKET_ROWS) * BUCKET_ROWS;
        if want <= self.cap_rows {
            return;
        }
        self.cap_rows = want;
        self.k.reserve_exact(self.cap_rows * self.dk - self.k.len());
        self.v.reserve_exact(self.cap_rows * self.dv - self.v.len());
        self.ki8.reserve_exact(self.cap_rows * self.dk - self.ki8.len());
        self.grows += 1;
    }

    /// Append one token's key/value row, maintaining the int8 mirror.
    pub fn append(&mut self, krow: &[f32], vrow: &[f32]) {
        assert_eq!(krow.len(), self.dk, "k row shape");
        assert_eq!(vrow.len(), self.dv, "v row shape");
        if self.len == self.cap_rows {
            self.cap_rows += BUCKET_ROWS;
            self.k.reserve_exact(self.cap_rows * self.dk - self.k.len());
            self.v.reserve_exact(self.cap_rows * self.dv - self.v.len());
            self.ki8.reserve_exact(self.cap_rows * self.dk - self.ki8.len());
            self.grows += 1;
        }
        self.k.extend_from_slice(krow);
        self.v.extend_from_slice(vrow);
        // Same NaN-skipping fold as `quantize_i8` (f32::max ignores NaN).
        let rmax = krow.iter().fold(0f32, |m, &x| m.max(x.abs()));
        if rmax > self.kmax {
            // The new row raises the global max: every cached row was
            // quantized at a stale scale — redo the whole prefix at the
            // new one (rare; amortized over the rows that did not move
            // the max). `clear` keeps capacity, so no allocation.
            self.kmax = rmax;
            let inv = 127.0 / self.kmax;
            self.ki8.clear();
            self.ki8.extend(self.k.iter().map(|&x| quant(x, inv)));
        } else if self.kmax == 0.0 {
            // All-zero (or all-NaN) prefix: quantize_i8 maps it to zeros.
            self.ki8.extend(std::iter::repeat(0i8).take(self.dk));
        } else {
            let inv = 127.0 / self.kmax;
            self.ki8.extend(krow.iter().map(|&x| quant(x, inv)));
        }
        self.len += 1;
    }
}

/// Free-list recycler for [`KvCache`]s of one model shape, so closing a
/// session returns its buckets to the next `open` instead of the
/// allocator (the `ModelScratch` discipline applied to session state).
#[derive(Debug)]
pub struct KvCachePool {
    free: Vec<KvCache>,
    dk: usize,
    dv: usize,
    created: u64,
    recycled: u64,
}

/// Counters for [`KvCachePool`] (serving metrics surface these).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPoolStats {
    /// Caches newly allocated because the free list was empty.
    pub created: u64,
    /// Takes served from the free list (capacity reused).
    pub recycled: u64,
    /// Caches currently parked on the free list.
    pub free: usize,
}

impl KvCachePool {
    pub fn new(dk: usize, dv: usize) -> KvCachePool {
        KvCachePool {
            free: Vec::new(),
            dk,
            dv,
            created: 0,
            recycled: 0,
        }
    }

    /// A reset cache: recycled (warm buckets) when one is free, fresh
    /// otherwise.
    pub fn take(&mut self) -> KvCache {
        match self.free.pop() {
            Some(mut c) => {
                c.reset();
                self.recycled += 1;
                c
            }
            None => {
                self.created += 1;
                KvCache::new(self.dk, self.dv)
            }
        }
    }

    /// Park a cache for reuse. Panics on a shape mismatch — one pool
    /// serves one model shape.
    pub fn put(&mut self, cache: KvCache) {
        assert_eq!((cache.dk, cache.dv), (self.dk, self.dv), "pool shape");
        self.free.push(cache);
    }

    pub fn stats(&self) -> KvPoolStats {
        KvPoolStats {
            created: self.created,
            recycled: self.recycled,
            free: self.free.len(),
        }
    }

    /// Total grow events across the parked caches (live sessions carry
    /// their own counters; the serving metrics sum both).
    pub fn grow_events(&self) -> u64 {
        self.free.iter().map(|c| c.grow_events()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn grows_in_buckets_and_counts() {
        let mut c = KvCache::new(4, 3);
        assert_eq!(c.grow_events(), 0);
        let (k, v) = ([1.0f32; 4], [2.0f32; 3]);
        c.append(&k, &v);
        assert_eq!((c.len(), c.grow_events()), (1, 1));
        for _ in 1..BUCKET_ROWS {
            c.append(&k, &v);
        }
        assert_eq!((c.len(), c.grow_events()), (BUCKET_ROWS, 1));
        c.append(&k, &v);
        assert_eq!(c.grow_events(), 2, "bucket boundary must grow once");
        assert_eq!(c.capacity_rows(), 2 * BUCKET_ROWS);
        assert_eq!(c.k().len(), (BUCKET_ROWS + 1) * 4);
        assert_eq!(c.v().len(), (BUCKET_ROWS + 1) * 3);
    }

    /// The incrementally maintained int8 mirror is bitwise-equal to
    /// quantizing the whole key prefix at once, at every length —
    /// including a leading all-zero row (zero scale) and magnitudes that
    /// keep raising the running max (forcing re-quantization).
    #[test]
    fn incremental_quantization_matches_whole_prefix() {
        let (dk, dv) = (8usize, 2usize);
        let mut rng = Rng::new(3);
        let mut c = KvCache::new(dk, dv);
        let mut all: Vec<f32> = Vec::new();
        let vrow = [0.5f32; 2];
        for i in 0..100 {
            let krow: Vec<f32> = if i == 0 {
                vec![0.0; dk]
            } else {
                // Drift the magnitude up so later rows raise the max.
                (0..dk)
                    .map(|_| (rng.normal() * (1.0 + i as f64 / 8.0)) as f32)
                    .collect()
            };
            all.extend_from_slice(&krow);
            c.append(&krow, &vrow);
            let (qref, sref) = sparse::quantize_i8(&all);
            assert_eq!(c.ki8(), &qref[..], "mirror diverged at len {}", i + 1);
            assert_eq!(
                c.k_scale().to_bits(),
                sref.to_bits(),
                "scale diverged at len {}",
                i + 1
            );
        }
    }

    /// A replay-sized reservation is one grow event (not one per
    /// bucket), rounds up to the bucket multiple, and is a no-op on a
    /// cache that already has the capacity — so a recycled pool cache
    /// replays a journal with zero new grow events.
    #[test]
    fn reserve_rows_is_one_grow_event() {
        let mut c = KvCache::new(4, 3);
        c.reserve_rows(BUCKET_ROWS + 1);
        assert_eq!(c.grow_events(), 1);
        assert_eq!(c.capacity_rows(), 2 * BUCKET_ROWS);
        let (k, v) = ([1.0f32; 4], [2.0f32; 3]);
        for _ in 0..(2 * BUCKET_ROWS) {
            c.append(&k, &v);
        }
        assert_eq!(c.grow_events(), 1, "appends within the reservation grew");
        c.reset();
        c.reserve_rows(BUCKET_ROWS);
        assert_eq!(c.grow_events(), 1, "no-op reservation counted a grow");
        c.reserve_rows(0);
        assert_eq!(c.capacity_rows(), 2 * BUCKET_ROWS);
    }

    #[test]
    fn pool_recycles_capacity_without_regrowth() {
        let mut pool = KvCachePool::new(4, 3);
        let (k, v) = ([1.5f32; 4], [0.0f32; 3]);
        let mut c = pool.take();
        for _ in 0..(BUCKET_ROWS + 1) {
            c.append(&k, &v);
        }
        let grown = c.grow_events();
        assert_eq!(grown, 2);
        pool.put(c);
        assert_eq!(pool.grow_events(), 2);

        let mut c = pool.take();
        assert_eq!(c.len(), 0, "recycled cache must come back empty");
        assert_eq!(c.k_scale(), 0.0);
        for _ in 0..(BUCKET_ROWS + 1) {
            c.append(&k, &v);
        }
        assert_eq!(c.grow_events(), grown, "recycled cache re-grew");
        pool.put(c);

        let s = pool.stats();
        assert_eq!((s.created, s.recycled, s.free), (1, 1, 1));
    }
}
