//! Reader for the `.tns` tensor interchange format.
//!
//! Python writes these (python/compile/tensorio.py — keep in sync):
//!
//! ```text
//! magic  4B  b"TNS1"
//! dtype  u8  0=f32 1=i32 2=u8 3=f64 4=i64
//! ndim   u8
//! dims   ndim x u32 (LE)
//! data   row-major payload (LE)
//! ```

use std::fs;
use std::path::Path;

use crate::util::error::{bail, Context, Result};

/// Element type of a tensor file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U8,
    F64,
    I64,
}

impl DType {
    fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::U8,
            3 => DType::F64,
            4 => DType::I64,
            _ => bail!("unknown dtype code {c}"),
        })
    }

    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::U8 => 1,
            DType::F64 | DType::I64 => 8,
        }
    }
}

/// In-memory tensor with untyped payload + typed views.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub dtype: DType,
    pub dims: Vec<usize>,
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn load(path: impl AsRef<Path>) -> Result<Tensor> {
        let path = path.as_ref();
        let bytes =
            fs::read(path).with_context(|| format!("reading tensor {}", path.display()))?;
        Self::from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Tensor> {
        if bytes.len() < 6 || &bytes[..4] != b"TNS1" {
            bail!("bad magic");
        }
        let dtype = DType::from_code(bytes[4])?;
        let ndim = bytes[5] as usize;
        let hdr = 6 + 4 * ndim;
        if bytes.len() < hdr {
            bail!("truncated header");
        }
        let mut dims = Vec::with_capacity(ndim);
        for i in 0..ndim {
            let off = 6 + 4 * i;
            dims.push(u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize);
        }
        let count: usize = dims.iter().product();
        let expect = count * dtype.size();
        let data = bytes[hdr..].to_vec();
        if data.len() != expect {
            bail!("payload size {} != expected {expect}", data.len());
        }
        Ok(Tensor { dtype, dims, data })
    }

    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("tensor is {:?}, not f32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("tensor is {:?}, not i32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        if self.dtype != DType::U8 {
            bail!("tensor is {:?}, not u8", self.dtype);
        }
        Ok(&self.data)
    }

    /// Write back out (round-trip tests and Rust-generated fixtures).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut out = Vec::with_capacity(6 + 4 * self.dims.len() + self.data.len());
        out.extend_from_slice(b"TNS1");
        out.push(match self.dtype {
            DType::F32 => 0,
            DType::I32 => 1,
            DType::U8 => 2,
            DType::F64 => 3,
            DType::I64 => 4,
        });
        out.push(self.dims.len() as u8);
        for d in &self.dims {
            out.extend_from_slice(&(*d as u32).to_le_bytes());
        }
        out.extend_from_slice(&self.data);
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, out)?;
        Ok(())
    }

    pub fn from_f32(dims: Vec<usize>, vals: &[f32]) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), vals.len());
        Tensor {
            dtype: DType::F32,
            dims,
            data: vals.iter().flat_map(|v| v.to_le_bytes()).collect(),
        }
    }

    pub fn from_i32(dims: Vec<usize>, vals: &[i32]) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), vals.len());
        Tensor {
            dtype: DType::I32,
            dims,
            data: vals.iter().flat_map(|v| v.to_le_bytes()).collect(),
        }
    }

    pub fn from_u8(dims: Vec<usize>, vals: &[u8]) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), vals.len());
        Tensor {
            dtype: DType::U8,
            dims,
            data: vals.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = Tensor::from_f32(vec![2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.5]);
        let dir = std::env::temp_dir().join("dsa_tns_test");
        let p = dir.join("t.tns");
        t.save(&p).unwrap();
        let u = Tensor::load(&p).unwrap();
        assert_eq!(u.dims, vec![2, 3]);
        assert_eq!(u.as_f32().unwrap()[5], 6.5);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(Tensor::from_bytes(b"NOPE\x00\x00").is_err());
    }

    #[test]
    fn rejects_short_payload() {
        let mut bytes = b"TNS1".to_vec();
        bytes.push(0); // f32
        bytes.push(1); // ndim 1
        bytes.extend_from_slice(&4u32.to_le_bytes()); // dims [4]
        bytes.extend_from_slice(&[0u8; 8]); // only 2 floats
        assert!(Tensor::from_bytes(&bytes).is_err());
    }

    #[test]
    fn u8_view() {
        let t = Tensor::from_u8(vec![4], &[1, 0, 1, 1]);
        assert_eq!(t.as_u8().unwrap(), &[1, 0, 1, 1]);
        assert!(t.as_f32().is_err());
    }
}
