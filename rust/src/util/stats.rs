//! Latency / throughput statistics: online summaries and percentile sketches.

/// Accumulates samples (e.g. per-request latencies in seconds) and reports
/// mean / percentiles / histogram. Stores raw samples — fine at the scale
/// of this testbed (<1e7 samples per run).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64)
            .sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
    }

    /// Linear-interpolated percentile, p in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let n = self.samples.len();
        let rank = (p / 100.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.samples[lo]
        } else {
            let w = rank - lo as f64;
            self.samples[lo] * (1.0 - w) + self.samples[hi] * w
        }
    }

    /// One-line human report (times assumed in seconds, shown in ms).
    pub fn report_ms(&mut self, label: &str) -> String {
        format!(
            "{label}: n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms p99={:.3}ms max={:.3}ms",
            self.len(),
            self.mean() * 1e3,
            self.percentile(50.0) * 1e3,
            self.percentile(95.0) * 1e3,
            self.percentile(99.0) * 1e3,
            self.max() * 1e3,
        )
    }
}

/// Fixed-bucket histogram for shapes/frequency reporting.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, buckets: usize) -> Self {
        assert!(hi > lo && buckets > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0; buckets],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let b = ((x - self.lo) / (self.hi - self.lo) * self.counts.len() as f64)
                as usize;
            let last = self.counts.len() - 1;
            self.counts[b.min(last)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.underflow + self.overflow
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(x);
        }
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.percentile(50.0) - 3.0).abs() < 1e-12);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((s.percentile(100.0) - 5.0).abs() < 1e-12);
        assert!((s.stddev() - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Summary::new();
        s.add(0.0);
        s.add(10.0);
        assert!((s.percentile(25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(42.0);
        assert_eq!(h.counts, vec![1; 10]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }
}
