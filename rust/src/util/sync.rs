//! Poison-tolerant lock primitives for the serving path.
//!
//! A thread that panics while holding a `std::sync::Mutex` poisons it;
//! every later `.lock().unwrap()` then panics too, turning one contained
//! failure into a crash of whatever unlucky thread touches the lock next
//! — the replica supervisor, the dispatcher, or a metrics reader. The
//! serving stack already contains panics behind blast shields
//! (`catch_unwind` in the engine worker and the kernel pool), so the
//! state under these locks is counters, route tables and join handles
//! whose invariants hold between individual mutations: recovering the
//! guard is strictly better than dying.
//!
//! [`lock_recover`] and [`wait_recover`] are therefore the **only**
//! sanctioned way to take a serving-path lock: they return the guard
//! whether or not the mutex is poisoned. The repo linter
//! (`dsa-serve lint`, rules `panic` and `lock-order` — see LINTS.md)
//! enforces the pattern by flagging raw `.lock().unwrap()` in
//! `coordinator/` and `server/`.

use std::sync::{Condvar, Mutex, MutexGuard};

/// Lock `m`, recovering the guard from a poisoned mutex instead of
/// panicking. Poison only records that *some* holder panicked mid-hold;
/// for the serving stack's lock-protected state (metrics counters,
/// session route tables, worker handles, pool queues) every individual
/// mutation is atomic with respect to its invariants, so the data is
/// still usable and refusing it would just spread the crash.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// [`Condvar::wait`] with the same poison recovery as [`lock_recover`]:
/// re-acquires the guard whether or not a holder panicked while we were
/// parked.
pub fn wait_recover<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    match cv.wait(guard) {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::{Arc, Mutex};
    use std::time::Duration;

    #[test]
    fn lock_recover_on_healthy_mutex_behaves_like_lock() {
        let m = Mutex::new(41);
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 42);
    }

    #[test]
    fn lock_recover_survives_poison() {
        let m = Arc::new(Mutex::new(vec![1, 2, 3]));
        let m2 = m.clone();
        // Poison the mutex: panic while holding the guard.
        let _ = catch_unwind(AssertUnwindSafe(move || {
            let _g = m2.lock().unwrap();
            panic!("holder dies mid-hold");
        }));
        assert!(m.is_poisoned(), "the panic above must have poisoned it");
        // A raw unwrap would crash here; recovery hands back the data.
        let g = lock_recover(&m);
        assert_eq!(*g, vec![1, 2, 3]);
    }

    #[test]
    fn wait_recover_wakes_through_poison() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = pair.clone();
        let waiter = std::thread::spawn(move || {
            let (m, cv) = &*pair2;
            let mut done = lock_recover(m);
            while !*done {
                done = wait_recover(cv, done);
            }
        });
        std::thread::sleep(Duration::from_millis(10));
        {
            let (m, cv) = &*pair;
            // Poison first, then flip the flag through recovery and wake.
            let _ = catch_unwind(AssertUnwindSafe(|| {
                let _g = m.lock().unwrap();
                panic!("poison the wait mutex");
            }));
            *lock_recover(m) = true;
            cv.notify_all();
        }
        waiter.join().expect("waiter must wake despite the poison");
    }
}
