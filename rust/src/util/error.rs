//! Minimal error type with context chaining (anyhow is unavailable in the
//! hermetic build environment; this re-implements the small surface the
//! crate uses: `Result`, `Context::{context,with_context}`, `bail!` and
//! `err!` as the `anyhow!` analogue).
//!
//! Like `anyhow::Error`, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that is what makes the blanket
//! `From<E: std::error::Error>` impl (and therefore `?` on io/parse errors)
//! coherent.

use std::fmt;

/// An error message plus a chain of outer context frames.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// Crate-wide result type defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Wrap `self` with an outer context frame (mirrors
    /// `anyhow::Error::context`).
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error {
            msg: c.to_string(),
            source: Some(Box::new(self)),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            write!(f, ": {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Attach context to the error arm of a `Result` or the `None` arm of an
/// `Option`, converting into [`Error`].
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Return early with a formatted [`Error`] (the `anyhow::bail!` analogue).
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($t)*)))
    };
}

/// Build a formatted [`Error`] value (the `anyhow::anyhow!` analogue).
#[macro_export]
macro_rules! err {
    ($($t:tt)*) => {
        $crate::util::error::Error::msg(format!($($t)*))
    };
}

// Make the crate-root macros importable alongside the types:
// `use crate::util::error::{bail, err, Context, Result};`
pub use crate::{bail, err};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn bail_formats() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "inner 42");
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 42");
        let e = fails()
            .with_context(|| format!("outer {}", 1))
            .unwrap_err();
        assert_eq!(e.to_string(), "outer 1: inner 42");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(7u32).context("missing").unwrap(), 7);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(io().is_err());
    }

    #[test]
    fn err_macro_builds_values() {
        let e = err!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }
}
