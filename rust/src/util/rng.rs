//! Deterministic PRNG (SplitMix64 + xoshiro256**), no external crates.
//!
//! The offline build environment only vendors the `xla` crate closure, so
//! the usual `rand` stack is unavailable; this module provides the small
//! surface the workload generators, property tests and simulators need.

/// xoshiro256** seeded via SplitMix64 — fast, high-quality, reproducible.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) (n > 0), Lemire-style without bias for
    /// practical purposes (rejection on the multiply-high method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // 128-bit multiply-high; rejection loop bounds bias to zero.
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (inter-arrival times of a Poisson
    /// process — used by the workload generator).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Partial Fisher–Yates over an index vector.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let m = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((m - 0.25).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(11);
        let s = r.sample_indices(100, 40);
        assert_eq!(s.len(), 40);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 40);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
