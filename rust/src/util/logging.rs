//! Stderr logging macros (the `log` crate is unavailable in the hermetic
//! build). `log_error!` always prints; `log_debug!` is gated on the
//! `DSA_LOG` environment variable so serving hot paths stay quiet by
//! default.

/// True when `DSA_LOG` is set (to any value). Checked per call site — the
/// cost of one env lookup only lands on cold/error paths.
pub fn verbose() -> bool {
    std::env::var_os("DSA_LOG").is_some()
}

/// Debug-level line, printed only when `DSA_LOG` is set.
#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => {
        if $crate::util::logging::verbose() {
            eprintln!($($t)*);
        }
    };
}

/// Error-level line, always printed to stderr.
#[macro_export]
macro_rules! log_error {
    ($($t:tt)*) => {
        eprintln!($($t)*)
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_expand() {
        // Smoke check that both macros compile and run.
        crate::log_error!("log_error smoke ({})", 1);
        crate::log_debug!("log_debug smoke ({})", 2);
    }
}
