//! Tiny declarative CLI argument parser (clap is not available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments,
//! with typed accessors, defaults, and generated `--help` text.

use std::collections::BTreeMap;

/// Declared option.
#[derive(Debug, Clone)]
struct Opt {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Builder + parsed result in one struct.
#[derive(Debug, Clone, Default)]
pub struct Args {
    program: String,
    about: String,
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positionals: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Args {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// Declare a `--key value` option with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare a required `--key value` option (no default).
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--flag`.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let left = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <v>", o.name)
            };
            let def = match &o.default {
                Some(d) if !o.is_flag => format!(" [default: {d}]"),
                _ => String::new(),
            };
            s.push_str(&format!("{left:<26}{}{def}\n", o.help));
        }
        s
    }

    /// Parse a token list (no program name). Returns Err(message) on bad
    /// input or when `--help` is requested (message = usage).
    pub fn parse(mut self, tokens: &[String]) -> Result<Self, String> {
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if t == "--help" || t == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = t.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let decl = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.usage()))?
                    .clone();
                if decl.is_flag {
                    if inline.is_some() {
                        return Err(format!("--{name} takes no value"));
                    }
                    self.flags.insert(name, true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            tokens
                                .get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} needs a value"))?
                        }
                    };
                    self.values.insert(name, v);
                }
            } else {
                self.positionals.push(t.clone());
            }
            i += 1;
        }
        // Check required options.
        for o in &self.opts {
            if !o.is_flag && o.default.is_none() && !self.values.contains_key(&o.name) {
                return Err(format!("missing required --{}\n\n{}", o.name, self.usage()));
            }
        }
        Ok(self)
    }

    pub fn get(&self, name: &str) -> String {
        if let Some(v) = self.values.get(name) {
            return v.clone();
        }
        self.opts
            .iter()
            .find(|o| o.name == name)
            .and_then(|o| o.default.clone())
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be a number"))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_values_and_flags() {
        let a = Args::new("t", "test")
            .opt("count", "4", "how many")
            .flag("verbose", "chatty")
            .parse(&toks(&["--count", "9", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(a.get_usize("count"), 9);
        assert!(a.get_flag("verbose"));
        assert_eq!(a.positionals(), &["pos1".to_string()]);
    }

    #[test]
    fn inline_equals() {
        let a = Args::new("t", "")
            .opt("rate", "1.0", "")
            .parse(&toks(&["--rate=2.5"]))
            .unwrap();
        assert!((a.get_f64("rate") - 2.5).abs() < 1e-12);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::new("t", "").opt("n", "7", "").parse(&[]).unwrap();
        assert_eq!(a.get_usize("n"), 7);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(Args::new("t", "").parse(&toks(&["--nope"])).is_err());
    }

    #[test]
    fn required_enforced() {
        assert!(Args::new("t", "").req("must", "").parse(&[]).is_err());
        let a = Args::new("t", "")
            .req("must", "")
            .parse(&toks(&["--must", "x"]))
            .unwrap();
        assert_eq!(a.get("must"), "x");
    }
}
