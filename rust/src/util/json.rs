//! Minimal JSON value model, parser and serializer.
//!
//! serde is not available in the offline build environment, so the artifact
//! manifest, server protocol and experiment reports use this hand-rolled
//! implementation. It supports the full JSON grammar (RFC 8259) minus
//! `\u` surrogate-pair edge cases beyond the BMP-pair rule.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a BTreeMap so serialization is stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- constructors ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Convenience: `obj.path(&["modules", "0", "name"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = match cur {
                Json::Obj(m) => m.get(*k)?,
                Json::Arr(a) => a.get(k.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    // -- serialization (via `Display`, so `.to_string()` keeps working) ----

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                let code = 0x10000
                                    + ((hi - 0xD800) << 10)
                                    + (lo.wrapping_sub(0xDC00));
                                char::from_u32(code).ok_or_else(|| self.err("bad surrogate"))?
                            } else {
                                char::from_u32(hi).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            out.push(c);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("short \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.path(&["a", "2", "b"]).unwrap().as_str(), Some("c"));
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s\"x"],"obj":{"k":-7}}"#;
        let v = parse(src).unwrap();
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::num(8.0).to_string(), "8");
        assert_eq!(Json::num(8.25).to_string(), "8.25");
    }
}
