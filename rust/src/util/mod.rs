//! Dependency-free substrates: JSON, CLI parsing, PRNG, statistics, a
//! micro-bench harness, a property-test helper, seeded fault injection
//! for chaos tests, poison-tolerant lock helpers, error/logging plumbing
//! and the `.tns` tensor reader.
//!
//! The default build is fully hermetic (zero external crates), so the
//! conventional crates (serde, clap, rand, criterion, proptest, anyhow,
//! log) are re-implemented here at the scale this project needs.

pub mod bench;
pub mod cli;
pub mod error;
pub mod faults;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod tensorio;
