//! Dependency-free substrates: JSON, CLI parsing, PRNG, statistics, a
//! micro-bench harness, a property-test helper and the `.tns` tensor reader.
//!
//! The offline build environment only vendors the `xla` crate's dependency
//! closure, so the conventional crates (serde, clap, rand, criterion,
//! proptest) are re-implemented here at the scale this project needs.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod tensorio;
