//! Property-based testing helper (proptest is not available offline).
//!
//! `forall` runs a property over `n` random cases; on failure it performs a
//! simple halving shrink over the generator's size parameter and reports the
//! failing seed so the case can be replayed deterministically.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 64,
            seed: 0xD5A_5EED,
        }
    }
}

/// Run `prop` over `cases` random inputs produced by `gen`.
///
/// `gen` receives (rng, size) where size grows from small to large across
/// the run — early iterations exercise degenerate cases. Panics with the
/// failing seed + case index on the first violation.
pub fn forall<T: std::fmt::Debug>(
    cfg: &Config,
    mut gen: impl FnMut(&mut Rng, usize) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        // size ramps 1..=32 across the run
        let size = 1 + (case * 32) / cfg.cases.max(1);
        let input = gen(&mut rng, size);
        if !prop(&input) {
            panic!(
                "property failed at case {case} (seed {seed:#x}, size {size}):\n{input:#?}"
            );
        }
    }
}

/// Assert two f32 slices are elementwise close.
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol || (x.is_nan() && y.is_nan()),
            "mismatch at {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        forall(
            &Config::default(),
            |rng, size| {
                (0..size).map(|_| rng.f64()).collect::<Vec<_>>()
            },
            |xs| xs.iter().all(|x| (0.0..1.0).contains(x)),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_false_property() {
        forall(
            &Config { cases: 8, seed: 1 },
            |rng, _| rng.below(10),
            |x| *x < 5,
        );
    }

    #[test]
    fn allclose_tolerates() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-7, 2.0 - 1e-7], 1e-5, 1e-6);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn allclose_catches() {
        assert_allclose(&[1.0], &[1.1], 1e-5, 1e-6);
    }
}
