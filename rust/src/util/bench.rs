//! Micro-benchmark harness (criterion is not available offline).
//!
//! `cargo bench` targets use `harness = false` and drive this: warmup,
//! timed iterations until a wall budget or iteration cap, mean/p50/p95
//! reporting, and a machine-readable JSON line per benchmark appended to
//! `results/bench.jsonl` so EXPERIMENTS.md tables can be regenerated.

use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("mean_s", Json::num(self.mean_s)),
            ("p50_s", Json::num(self.p50_s)),
            ("p95_s", Json::num(self.p95_s)),
            ("min_s", Json::num(self.min_s)),
        ])
    }
}

/// Benchmark runner with a global time budget per case.
pub struct Bench {
    pub warmup_iters: usize,
    pub max_iters: usize,
    pub budget: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 3,
            max_iters: 200,
            budget: Duration::from_secs(5),
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_budget(mut self, d: Duration) -> Self {
        self.budget = d;
        self
    }

    /// Time `f` repeatedly; `f` should perform one complete operation.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut s = Summary::new();
        let start = Instant::now();
        let mut iters = 0;
        while iters < self.max_iters && start.elapsed() < self.budget {
            let t = Instant::now();
            f();
            s.add(t.elapsed().as_secs_f64());
            iters += 1;
        }
        let r = BenchResult {
            name: name.to_string(),
            iters,
            mean_s: s.mean(),
            p50_s: s.percentile(50.0),
            p95_s: s.percentile(95.0),
            min_s: s.min(),
        };
        println!(
            "{:<48} {:>7} iters  mean {:>10.3}us  p50 {:>10.3}us  p95 {:>10.3}us",
            r.name,
            r.iters,
            r.mean_s * 1e6,
            r.p50_s * 1e6,
            r.p95_s * 1e6
        );
        self.results.push(r.clone());
        r
    }

    /// Append all results as JSON lines to `results/bench.jsonl`.
    pub fn flush_jsonl(&self, suite: &str) {
        use std::io::Write;
        let _ = std::fs::create_dir_all("results");
        if let Ok(mut f) = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open("results/bench.jsonl")
        {
            for r in &self.results {
                let mut j = r.to_json();
                if let Json::Obj(m) = &mut j {
                    m.insert("suite".into(), Json::str(suite));
                }
                let _ = writeln!(f, "{j}");
            }
        }
    }

    /// Write one JSON document summarizing every recorded result to `path`
    /// (e.g. `results/BENCH_kernels.json`) — the machine-readable artifact
    /// a bench run leaves behind for perf-trajectory tracking.
    pub fn write_summary(
        &self,
        path: impl AsRef<std::path::Path>,
        suite: &str,
    ) -> std::io::Result<()> {
        let host_threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let doc = Json::obj(vec![
            ("suite", Json::str(suite)),
            ("host_threads", Json::num(host_threads as f64)),
            (
                "results",
                Json::Arr(self.results.iter().map(|r| r.to_json()).collect()),
            ),
        ]);
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, doc.to_string())
    }

    /// Mean seconds of a recorded result by exact name, if present.
    pub fn mean_of(&self, name: &str) -> Option<f64> {
        self.results.iter().find(|r| r.name == name).map(|r| r.mean_s)
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let mut b = Bench {
            warmup_iters: 1,
            max_iters: 10,
            budget: Duration::from_millis(200),
            results: Vec::new(),
        };
        let mut x = 0u64;
        let r = b.run("noop", || {
            x = x.wrapping_add(1);
        });
        assert!(r.iters >= 1);
        assert!(r.mean_s >= 0.0);
        assert_eq!(b.results().len(), 1);
        assert!(b.mean_of("noop").is_some());
        assert!(b.mean_of("nope").is_none());
    }

    #[test]
    fn writes_summary_json() {
        let mut b = Bench {
            warmup_iters: 0,
            max_iters: 2,
            budget: Duration::from_millis(50),
            results: Vec::new(),
        };
        b.run("a", || {});
        let path = std::env::temp_dir().join("dsa_bench_test").join("s.json");
        b.write_summary(&path, "unit").unwrap();
        let doc = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("suite").and_then(|s| s.as_str()), Some("unit"));
        assert_eq!(
            doc.get("results").and_then(|r| r.as_arr()).map(|a| a.len()),
            Some(1)
        );
    }
}
