//! Micro-benchmark harness (criterion is not available offline).
//!
//! `cargo bench` targets use `harness = false` and drive this: warmup,
//! timed iterations until a wall budget or iteration cap, mean/p50/p95
//! reporting, and a machine-readable JSON line per benchmark appended to
//! `results/bench.jsonl` so EXPERIMENTS.md tables can be regenerated.
//!
//! Perf-trajectory tracking: [`Bench::write_summary`] leaves one JSON
//! document per suite (e.g. `results/BENCH_kernels.json`), and
//! [`diff_baseline`] compares a fresh run against the committed copy of
//! that document, reporting per-kernel speedup ratios. `make
//! bench-compare` drives this as a local perf gate (nonzero exit past a
//! regression threshold); `bench_kernels` prints the same diff after
//! every run.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Path of a bench artifact inside the repo-root `results/` directory.
///
/// Cargo runs bench/test binaries with cwd = the *package* root
/// (`rust/`), but `cargo run` keeps the invoker's cwd — so a bare
/// `"results/…"` would land in `rust/results/` for benches while the
/// `bench-compare` gate and CI artifact upload read `results/` at the
/// repo root. Cargo exports `CARGO_MANIFEST_DIR` to both kinds of
/// process; anchoring on it makes every writer and reader agree. Outside
/// cargo (a directly-executed binary) this falls back to cwd-relative
/// `results/`.
pub fn results_path(file: &str) -> std::path::PathBuf {
    match std::env::var_os("CARGO_MANIFEST_DIR") {
        Some(m) => std::path::Path::new(&m).join("..").join("results").join(file),
        None => std::path::Path::new("results").join(file),
    }
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("mean_s", Json::num(self.mean_s)),
            ("p50_s", Json::num(self.p50_s)),
            ("p95_s", Json::num(self.p95_s)),
            ("min_s", Json::num(self.min_s)),
        ])
    }
}

/// Benchmark runner with a global time budget per case.
pub struct Bench {
    pub warmup_iters: usize,
    pub max_iters: usize,
    pub budget: Duration,
    results: Vec<BenchResult>,
    /// Named scalars derived from the raw timings (e.g. spawn-vs-pool
    /// overhead ratios); serialized under `"derived"` in the summary so
    /// headline numbers travel with the artifact.
    derived: BTreeMap<String, f64>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 3,
            max_iters: 200,
            budget: Duration::from_secs(5),
            results: Vec::new(),
            derived: BTreeMap::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_budget(mut self, d: Duration) -> Self {
        self.budget = d;
        self
    }

    /// Time `f` repeatedly; `f` should perform one complete operation.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut s = Summary::new();
        let start = Instant::now();
        let mut iters = 0;
        while iters < self.max_iters && start.elapsed() < self.budget {
            let t = Instant::now();
            f();
            s.add(t.elapsed().as_secs_f64());
            iters += 1;
        }
        let r = BenchResult {
            name: name.to_string(),
            iters,
            mean_s: s.mean(),
            p50_s: s.percentile(50.0),
            p95_s: s.percentile(95.0),
            min_s: s.min(),
        };
        println!(
            "{:<48} {:>7} iters  mean {:>10.3}us  p50 {:>10.3}us  p95 {:>10.3}us",
            r.name,
            r.iters,
            r.mean_s * 1e6,
            r.p50_s * 1e6,
            r.p95_s * 1e6
        );
        self.results.push(r.clone());
        r
    }

    /// Append all results as JSON lines to `results/bench.jsonl` at the
    /// repo root (see [`results_path`]).
    pub fn flush_jsonl(&self, suite: &str) {
        use std::io::Write;
        let path = results_path("bench.jsonl");
        if let Some(parent) = path.parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        if let Ok(mut f) = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
        {
            for r in &self.results {
                let mut j = r.to_json();
                if let Json::Obj(m) = &mut j {
                    m.insert("suite".into(), Json::str(suite));
                }
                let _ = writeln!(f, "{j}");
            }
        }
    }

    /// The summary document [`Bench::write_summary`] serializes.
    pub fn summary_json(&self, suite: &str) -> Json {
        let host_threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let mut obj = vec![
            ("suite", Json::str(suite)),
            ("host_threads", Json::num(host_threads as f64)),
            (
                "results",
                Json::Arr(self.results.iter().map(|r| r.to_json()).collect()),
            ),
        ];
        if !self.derived.is_empty() {
            let derived: Vec<(&str, Json)> = self
                .derived
                .iter()
                .map(|(k, &v)| (k.as_str(), Json::num(v)))
                .collect();
            obj.push(("derived", Json::obj(derived)));
        }
        Json::obj(obj)
    }

    /// Write one JSON document summarizing every recorded result to `path`
    /// (e.g. `results/BENCH_kernels.json`) — the machine-readable artifact
    /// a bench run leaves behind for perf-trajectory tracking.
    pub fn write_summary(
        &self,
        path: impl AsRef<std::path::Path>,
        suite: &str,
    ) -> std::io::Result<()> {
        let doc = self.summary_json(suite);
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, doc.to_string())
    }

    /// Record a derived scalar (ratio, counter, …) for the summary
    /// document. Non-finite values are dropped — a missing input must not
    /// poison the summary JSON.
    pub fn note(&mut self, name: &str, value: f64) {
        if value.is_finite() {
            self.derived.insert(name.to_string(), value);
        }
    }

    /// Mean seconds of a recorded result by exact name, if present.
    pub fn mean_of(&self, name: &str) -> Option<f64> {
        self.results.iter().find(|r| r.name == name).map(|r| r.mean_s)
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// `name -> mean seconds` of a summary document (as produced by
/// [`Bench::write_summary`]). Entries without a finite positive mean are
/// skipped — a committed placeholder baseline therefore compares as "no
/// baseline" rather than as an infinite regression.
pub fn summary_means(doc: &Json) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    let results = match doc.get("results").and_then(|r| r.as_arr()) {
        Some(r) => r,
        None => return out,
    };
    for r in results {
        let name = r.get("name").and_then(|n| n.as_str());
        let mean = r.get("mean_s").and_then(|m| m.as_f64());
        if let (Some(name), Some(mean)) = (name, mean) {
            if mean.is_finite() && mean > 0.0 {
                out.insert(name.to_string(), mean);
            }
        }
    }
    out
}

/// One benchmark present in both the baseline and the fresh summary.
#[derive(Debug, Clone)]
pub struct Comparison {
    pub name: String,
    pub base_s: f64,
    pub new_s: f64,
}

impl Comparison {
    /// `baseline / fresh`: > 1 is a speedup, < 1 a slowdown.
    pub fn speedup(&self) -> f64 {
        self.base_s / self.new_s
    }
}

/// Diff between a committed baseline summary and a fresh run.
#[derive(Debug, Clone, Default)]
pub struct BaselineDiff {
    /// Benchmarks present on both sides, in fresh-run order.
    pub rows: Vec<Comparison>,
    /// Benchmarks only in the fresh run (no baseline yet).
    pub added: Vec<String>,
    /// Benchmarks only in the baseline (dropped from the sweep).
    pub removed: Vec<String>,
}

/// Compare two summary documents (see [`Bench::summary_json`]) by
/// benchmark name.
pub fn diff_baseline(baseline: &Json, fresh: &Json) -> BaselineDiff {
    let base = summary_means(baseline);
    let new = summary_means(fresh);
    let mut diff = BaselineDiff::default();
    for (name, &new_s) in &new {
        match base.get(name) {
            Some(&base_s) => diff.rows.push(Comparison {
                name: name.clone(),
                base_s,
                new_s,
            }),
            None => diff.added.push(name.clone()),
        }
    }
    for name in base.keys() {
        if !new.contains_key(name) {
            diff.removed.push(name.clone());
        }
    }
    diff
}

impl BaselineDiff {
    /// Print per-kernel speedup ratios vs the baseline.
    pub fn print(&self) {
        if self.rows.is_empty() && self.added.is_empty() && self.removed.is_empty() {
            println!("(no baseline data to compare)");
            return;
        }
        for c in &self.rows {
            let flag = if c.speedup() < 0.8 {
                "  << REGRESSION"
            } else if c.speedup() > 1.25 {
                "  >> improved"
            } else {
                ""
            };
            println!(
                "{:<52} {:>10.3}us -> {:>10.3}us  {:>6.2}x{}",
                c.name,
                c.base_s * 1e6,
                c.new_s * 1e6,
                c.speedup(),
                flag
            );
        }
        for name in &self.added {
            println!("{name:<52} (new — no baseline timing)");
        }
        for name in &self.removed {
            println!("{name:<52} (removed from sweep)");
        }
    }

    /// Comparisons slower than `1 + max_slowdown` vs baseline (e.g.
    /// `max_slowdown = 0.25` flags >25% regressions).
    pub fn regressions(&self, max_slowdown: f64) -> Vec<&Comparison> {
        self.rows
            .iter()
            .filter(|c| c.new_s > c.base_s * (1.0 + max_slowdown))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let mut b = Bench {
            warmup_iters: 1,
            max_iters: 10,
            budget: Duration::from_millis(200),
            ..Default::default()
        };
        let mut x = 0u64;
        let r = b.run("noop", || {
            x = x.wrapping_add(1);
        });
        assert!(r.iters >= 1);
        assert!(r.mean_s >= 0.0);
        assert_eq!(b.results().len(), 1);
        assert!(b.mean_of("noop").is_some());
        assert!(b.mean_of("nope").is_none());
    }

    fn summary_with(results: Vec<(&str, f64)>) -> Json {
        Json::obj(vec![(
            "results",
            Json::Arr(
                results
                    .into_iter()
                    .map(|(n, m)| Json::obj(vec![("name", Json::str(n)), ("mean_s", Json::num(m))]))
                    .collect(),
            ),
        )])
    }

    #[test]
    fn baseline_diff_flags_regressions_and_membership() {
        let base = summary_with(vec![("a", 1.0e-3), ("b", 2.0e-3), ("gone", 1.0e-3)]);
        let fresh = summary_with(vec![("a", 0.5e-3), ("b", 3.0e-3), ("new", 1.0e-3)]);
        let diff = diff_baseline(&base, &fresh);
        assert_eq!(diff.rows.len(), 2);
        assert_eq!(diff.added, vec!["new".to_string()]);
        assert_eq!(diff.removed, vec!["gone".to_string()]);
        let reg = diff.regressions(0.25);
        assert_eq!(reg.len(), 1);
        assert_eq!(reg[0].name, "b");
        assert!((reg[0].speedup() - 2.0 / 3.0).abs() < 1e-12);
        // a sped up 2x, not a regression
        assert!(diff.regressions(0.25).iter().all(|c| c.name != "a"));
        diff.print(); // smoke: must not panic
    }

    #[test]
    fn placeholder_baseline_means_are_skipped() {
        let base = summary_with(vec![("a", 0.0), ("b", f64::NAN)]);
        let fresh = summary_with(vec![("a", 1.0e-3), ("b", 1.0e-3)]);
        let diff = diff_baseline(&base, &fresh);
        assert!(diff.rows.is_empty());
        assert_eq!(diff.added.len(), 2);
        assert!(diff.regressions(0.25).is_empty());
        assert!(summary_means(&Json::Null).is_empty());
    }

    #[test]
    fn writes_summary_json() {
        let mut b = Bench {
            warmup_iters: 0,
            max_iters: 2,
            budget: Duration::from_millis(50),
            ..Default::default()
        };
        b.run("a", || {});
        let path = std::env::temp_dir().join("dsa_bench_test").join("s.json");
        b.write_summary(&path, "unit").unwrap();
        let doc = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("suite").and_then(|s| s.as_str()), Some("unit"));
        assert_eq!(
            doc.get("results").and_then(|r| r.as_arr()).map(|a| a.len()),
            Some(1)
        );
    }

    #[test]
    fn derived_notes_round_trip_and_drop_nonfinite() {
        let mut b = Bench::default();
        assert!(b.summary_json("unit").get("derived").is_none());
        b.note("pool_vs_spawn/dense/l64", 1.75);
        b.note("bogus", f64::NAN);
        b.note("bogus2", f64::INFINITY);
        let doc = b.summary_json("unit");
        let derived = doc.get("derived").expect("derived section");
        assert_eq!(
            derived.get("pool_vs_spawn/dense/l64").and_then(|v| v.as_f64()),
            Some(1.75)
        );
        assert!(derived.get("bogus").is_none());
        assert!(derived.get("bogus2").is_none());
        // derived entries never leak into the per-kernel regression diff
        assert!(summary_means(&doc).is_empty());
    }
}
