//! Seeded fault injection for chaos testing the serving stack.
//!
//! A [`FaultInjector`] is an *instance* (no process-global registry — test
//! binaries run in one process, and a global would leak faults into
//! unrelated tests) that hook sites poll before doing real work:
//!
//! * `NativeBackend` polls at `backend.run` / `backend.open` /
//!   `backend.decode` before executing a batch, prefill or decode step.
//! * `WorkerPool::with_faults` polls at `pool.task` inside each worker's
//!   panic shield, so pool-level panics are exercised too.
//! * `ReplicaSet` polls at `replica.crash` / `replica.wedge` once per
//!   dispatch: **any** injected fault at `replica.crash` kills the replica
//!   the round-robin cursor points at (its worker exits without draining,
//!   as if a panic escaped the pool shield), and any injected fault at
//!   `replica.wedge` wedges it (the worker stops heartbeating until the
//!   supervisor's watchdog tears it down) — so chaos tests kill replicas
//!   deterministically by seed.
//!
//! Rolls are seed-keyed and per-site counted: the k-th roll at a given
//! site always yields the same [`Fault`] for a given seed, regardless of
//! thread interleaving — so a chaos failure reproduces from its seed.
//! Injectors start **armed**; `set_armed(false)` disarms every hook at
//! once so a test can prove post-chaos liveness on a clean engine.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::error::{bail, Result};
use crate::util::rng::Rng;
use crate::util::sync::lock_recover;

/// Per-site fault rates (each in [0, 1]; they are tried in the order
/// panic → error → delay against one uniform draw, so their sum should
/// stay ≤ 1).
#[derive(Debug, Clone)]
pub struct FaultConfig {
    /// Seed keying every roll; same seed → same fault schedule per site.
    pub seed: u64,
    /// Probability a roll panics (exercises the engine's blast shield).
    pub panic_rate: f64,
    /// Probability a roll returns an injected backend error.
    pub error_rate: f64,
    /// Probability a roll sleeps for `delay` (exercises deadlines).
    pub delay_rate: f64,
    /// Sleep length for injected delays.
    pub delay: Duration,
}

impl FaultConfig {
    /// A config that injects nothing (rates all zero) — handy as a base
    /// for struct-update syntax in tests.
    pub fn quiet(seed: u64) -> Self {
        FaultConfig {
            seed,
            panic_rate: 0.0,
            error_rate: 0.0,
            delay_rate: 0.0,
            delay: Duration::from_millis(2),
        }
    }
}

/// Outcome of one roll at a hook site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    None,
    Delay(Duration),
    Error,
    Panic,
}

/// Counts kept per hook site, readable after a chaos run to assert the
/// harness actually injected something.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SiteStats {
    pub rolls: u64,
    pub panics: u64,
    pub errors: u64,
    pub delays: u64,
}

impl SiteStats {
    pub fn injected(&self) -> u64 {
        self.panics + self.errors + self.delays
    }
}

/// Deterministic, seed-keyed fault source. See module docs.
#[derive(Debug)]
pub struct FaultInjector {
    cfg: FaultConfig,
    armed: AtomicBool,
    sites: Mutex<BTreeMap<&'static str, SiteStats>>,
}

/// FNV-1a, used to give each site an independent seed stream.
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl FaultInjector {
    pub fn new(cfg: FaultConfig) -> Self {
        FaultInjector {
            cfg,
            armed: AtomicBool::new(true),
            sites: Mutex::new(BTreeMap::new()),
        }
    }

    /// Arm or disarm every hook at once (disarm before post-chaos
    /// liveness checks).
    pub fn set_armed(&self, armed: bool) {
        self.armed.store(armed, Ordering::SeqCst);
    }

    pub fn armed(&self) -> bool {
        self.armed.load(Ordering::SeqCst)
    }

    /// Roll for a fault at `site`. The k-th roll at a site is a pure
    /// function of (seed, site, k).
    pub fn roll(&self, site: &'static str) -> Fault {
        if !self.armed() {
            return Fault::None;
        }
        let mut sites = lock_recover(&self.sites);
        let stats = sites.entry(site).or_default();
        stats.rolls += 1;
        let k = stats.rolls;
        let mut rng = Rng::new(
            self.cfg
                .seed
                .wrapping_add(fnv1a(site))
                .wrapping_add(k.wrapping_mul(0x9E37_79B9_7F4A_7C15)),
        );
        let x = rng.f64();
        let mut acc = self.cfg.panic_rate;
        if x < acc {
            stats.panics += 1;
            return Fault::Panic;
        }
        acc += self.cfg.error_rate;
        if x < acc {
            stats.errors += 1;
            return Fault::Error;
        }
        acc += self.cfg.delay_rate;
        if x < acc {
            stats.delays += 1;
            return Fault::Delay(self.cfg.delay);
        }
        Fault::None
    }

    /// Roll and *act*: sleep on Delay, bail on Error, panic on Panic.
    /// Hook sites call this as their first statement.
    pub fn fire(&self, site: &'static str) -> Result<()> {
        match self.roll(site) {
            Fault::None => Ok(()),
            Fault::Delay(d) => {
                std::thread::sleep(d);
                Ok(())
            }
            Fault::Error => bail!("injected backend error at {site}"),
            Fault::Panic => panic!("injected panic at {site}"),
        }
    }

    /// Stats for one site (zeroes if the site never rolled).
    pub fn site(&self, site: &str) -> SiteStats {
        lock_recover(&self.sites)
            .get(site)
            .copied()
            .unwrap_or_default()
    }

    /// Total faults injected across all sites.
    pub fn injected_total(&self) -> u64 {
        lock_recover(&self.sites)
            .values()
            .map(|s| s.injected())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chaotic(seed: u64) -> FaultInjector {
        FaultInjector::new(FaultConfig {
            panic_rate: 0.2,
            error_rate: 0.2,
            delay_rate: 0.2,
            ..FaultConfig::quiet(seed)
        })
    }

    #[test]
    fn same_seed_same_schedule() {
        let a = chaotic(7);
        let b = chaotic(7);
        let sa: Vec<Fault> = (0..200).map(|_| a.roll("backend.run")).collect();
        let sb: Vec<Fault> = (0..200).map(|_| b.roll("backend.run")).collect();
        assert_eq!(sa, sb);
        assert!(a.injected_total() > 0, "rates 0.6 over 200 rolls must inject");
    }

    #[test]
    fn different_sites_different_streams() {
        let f = chaotic(7);
        let sa: Vec<Fault> = (0..200).map(|_| f.roll("backend.run")).collect();
        let sb: Vec<Fault> = (0..200).map(|_| f.roll("backend.decode")).collect();
        assert_ne!(sa, sb);
    }

    /// The replica kill/wedge sites are ordinary seed-keyed sites: same
    /// seed → same schedule, and the two sites draw independent streams
    /// (a kill schedule never aliases a wedge schedule).
    #[test]
    fn replica_sites_are_deterministic_and_independent() {
        let a = chaotic(42);
        let b = chaotic(42);
        let crash_a: Vec<Fault> = (0..200).map(|_| a.roll("replica.crash")).collect();
        let crash_b: Vec<Fault> = (0..200).map(|_| b.roll("replica.crash")).collect();
        assert_eq!(crash_a, crash_b);
        let wedge_a: Vec<Fault> = (0..200).map(|_| a.roll("replica.wedge")).collect();
        assert_ne!(crash_a, wedge_a);
    }

    #[test]
    fn disarmed_injects_nothing() {
        let f = chaotic(7);
        f.set_armed(false);
        for _ in 0..100 {
            assert_eq!(f.roll("backend.run"), Fault::None);
        }
        assert_eq!(f.injected_total(), 0);
        assert_eq!(f.site("backend.run").rolls, 0, "disarmed rolls don't count");
    }

    #[test]
    fn quiet_config_never_fires() {
        let f = FaultInjector::new(FaultConfig::quiet(3));
        for _ in 0..500 {
            assert!(f.fire("backend.run").is_ok());
        }
        assert_eq!(f.injected_total(), 0);
        assert_eq!(f.site("backend.run").rolls, 500);
    }

    #[test]
    fn stats_partition_rolls() {
        let f = chaotic(11);
        for _ in 0..300 {
            let _ = f.roll("pool.task");
        }
        let s = f.site("pool.task");
        assert_eq!(s.rolls, 300);
        assert!(s.panics > 0 && s.errors > 0 && s.delays > 0);
        assert!(s.injected() < s.rolls, "rates sum to 0.6 < 1");
    }
}
