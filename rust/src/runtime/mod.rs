//! PJRT runtime: client wrapper ([`client`]) and artifact registry
//! ([`registry`]). This is the only module that touches the `xla` crate;
//! everything above it (coordinator, server) works with plain vectors.

pub mod client;
pub mod registry;

pub use client::{Arg, Client, Executable};
pub use registry::{ModuleInfo, Registry};
