//! PJRT runtime: client wrapper ([`client`]) and artifact registry
//! ([`registry`]).
//!
//! Everything that touches the `xla` crate is gated behind the `xla`
//! feature so the default build is hermetic: [`registry::Manifest`]
//! (artifact metadata parsing, no PJRT state) is always available, while
//! [`client`] and [`registry::Registry`] (compiled-executable cache) only
//! exist with `--features xla` and a vendored `xla` crate (see
//! Cargo.toml). Everything above this module (coordinator, server) works
//! with plain vectors and the backend abstraction in
//! `coordinator::backend`.

#[cfg(feature = "xla")]
pub mod client;
pub mod registry;

#[cfg(feature = "xla")]
pub use client::{Arg, Client, Executable};
pub use registry::{Manifest, ModuleInfo};
#[cfg(feature = "xla")]
pub use registry::Registry;
