//! Artifact registry: parses `artifacts/manifest.json`, lazily compiles
//! modules, and exposes variant/batch lookup for the coordinator.

#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};
#[cfg(feature = "xla")]
use std::sync::Mutex;

#[cfg(feature = "xla")]
use crate::runtime::client::{Client, Executable};
use crate::util::error::{bail, Context, Result};
use crate::util::json::{self, Json};
use crate::util::sync::lock_recover;
use crate::util::tensorio::Tensor;

/// Metadata of one HLO module from the manifest.
#[derive(Debug, Clone)]
pub struct ModuleInfo {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub variant: Option<String>,
    pub batch: Option<usize>,
    pub seq_len: Option<usize>,
    pub input_shapes: Vec<Vec<usize>>,
    pub input_dtypes: Vec<String>,
    pub output_shapes: Vec<Vec<usize>>,
}

/// Parsed manifest: metadata only, no PJRT state — `Send + Sync`, so it can
/// be shared with server threads and examples while the executables stay on
/// the engine worker thread (the `xla` crate's handles are thread-local).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub task_seq_len: usize,
    pub task_classes: usize,
    pub batch_buckets: Vec<usize>,
    pub variants: Vec<String>,
    modules: Vec<ModuleInfo>,
}

/// Manifest + PJRT client + compiled-executable cache. **Not `Send`**: the
/// `xla` crate wraps thread-local Rc handles, so a `Registry` must be
/// created and used on one thread (the engine worker does exactly that).
#[cfg(feature = "xla")]
pub struct Registry {
    pub manifest: Manifest,
    client: Client,
    cache: Mutex<HashMap<String, Executable>>,
}

fn shapes_of(entry: &Json, key: &str) -> (Vec<Vec<usize>>, Vec<String>) {
    let mut shapes = Vec::new();
    let mut dtypes = Vec::new();
    if let Some(arr) = entry.get(key).and_then(|v| v.as_arr()) {
        for io in arr {
            let shape = io
                .get("shape")
                .and_then(|s| s.as_arr())
                .map(|dims| dims.iter().filter_map(|d| d.as_usize()).collect())
                .unwrap_or_default();
            shapes.push(shape);
            dtypes.push(
                io.get("dtype")
                    .and_then(|d| d.as_str())
                    .unwrap_or("f32")
                    .to_string(),
            );
        }
    }
    (shapes, dtypes)
}

impl Manifest {
    /// Parse `root/manifest.json` (no PJRT involved).
    pub fn open(root: impl AsRef<Path>) -> Result<Manifest> {
        let root = root.as_ref().to_path_buf();
        let manifest_path = root.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let doc = json::parse(&text).context("parsing manifest.json")?;

        let mut modules = Vec::new();
        for entry in doc.get("modules").and_then(|m| m.as_arr()).unwrap_or(&[]) {
            let (input_shapes, input_dtypes) = shapes_of(entry, "inputs");
            let (output_shapes, _) = shapes_of(entry, "outputs");
            modules.push(ModuleInfo {
                name: entry
                    .get("name")
                    .and_then(|v| v.as_str())
                    .unwrap_or_default()
                    .to_string(),
                file: entry
                    .get("file")
                    .and_then(|v| v.as_str())
                    .unwrap_or_default()
                    .to_string(),
                kind: entry
                    .get("kind")
                    .and_then(|v| v.as_str())
                    .unwrap_or_default()
                    .to_string(),
                variant: entry
                    .get("variant")
                    .and_then(|v| v.as_str())
                    .map(str::to_string),
                batch: entry.get("batch").and_then(|v| v.as_usize()),
                seq_len: entry.get("seq_len").and_then(|v| v.as_usize()),
                input_shapes,
                input_dtypes,
                output_shapes,
            });
        }
        if modules.is_empty() {
            bail!("manifest has no modules — run `make artifacts` first");
        }

        let batch_buckets = doc
            .get("batch_buckets")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_else(|| vec![1]);
        let variants = doc
            .get("variants")
            .and_then(|v| v.as_arr())
            .map(|a| {
                a.iter()
                    .filter_map(|x| x.as_str().map(str::to_string))
                    .collect()
            })
            .unwrap_or_default();

        Ok(Manifest {
            task_seq_len: doc
                .path(&["task", "seq_len"])
                .and_then(|v| v.as_usize())
                .unwrap_or(0),
            task_classes: doc
                .path(&["task", "n_classes"])
                .and_then(|v| v.as_usize())
                .unwrap_or(2),
            batch_buckets,
            variants,
            modules,
            root,
        })
    }

    pub fn modules(&self) -> &[ModuleInfo] {
        &self.modules
    }

    pub fn module(&self, name: &str) -> Option<&ModuleInfo> {
        self.modules.iter().find(|m| m.name == name)
    }

    /// Classifier module for (variant, batch).
    pub fn classifier(&self, variant: &str, batch: usize) -> Option<&ModuleInfo> {
        self.modules.iter().find(|m| {
            m.kind == "classifier"
                && m.variant.as_deref() == Some(variant)
                && m.batch == Some(batch)
        })
    }

    /// Smallest compiled batch bucket >= n (or the largest bucket).
    pub fn bucket_for(&self, n: usize) -> usize {
        let mut buckets = self.batch_buckets.clone();
        buckets.sort_unstable();
        for &b in &buckets {
            if b >= n {
                return b;
            }
        }
        buckets.last().copied().unwrap_or(1)
    }

    /// Load a `.tns` tensor referenced by the manifest's tensors section.
    pub fn tensor(&self, name: &str) -> Result<Tensor> {
        // Conventional layout: tensors/<name>.tns
        let p = self.root.join("tensors").join(format!("{name}.tns"));
        Tensor::load(p)
    }
}

#[cfg(feature = "xla")]
impl Registry {
    /// Open `root/manifest.json` and create the PJRT client **on this
    /// thread** (see the `Send` note on the type).
    pub fn open(root: impl AsRef<Path>) -> Result<Registry> {
        Ok(Registry {
            manifest: Manifest::open(root)?,
            client: Client::cpu()?,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn from_manifest(manifest: Manifest) -> Result<Registry> {
        Ok(Registry {
            manifest,
            client: Client::cpu()?,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn client(&self) -> &Client {
        &self.client
    }

    /// Compile (or fetch cached) executable by module name.
    pub fn load(&self, name: &str) -> Result<Executable> {
        if let Some(e) = lock_recover(&self.cache).get(name) {
            return Ok(e.clone());
        }
        let info = self
            .manifest
            .module(name)
            .with_context(|| format!("module {name} not in manifest"))?;
        let exe = self
            .client
            .compile_hlo_file(self.manifest.root.join(&info.file))?;
        lock_recover(&self.cache).insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile every classifier executable (serving warm-up).
    pub fn preload_classifiers(&self, variant: &str) -> Result<usize> {
        let names: Vec<String> = self
            .manifest
            .modules
            .iter()
            .filter(|m| m.kind == "classifier" && m.variant.as_deref() == Some(variant))
            .map(|m| m.name.clone())
            .collect();
        for n in &names {
            self.load(n)?;
        }
        Ok(names.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Registry tests that need real artifacts live in rust/tests/; here we
    /// only exercise manifest parsing against a synthetic manifest.
    #[test]
    fn parses_synthetic_manifest() {
        let dir = std::env::temp_dir().join("dsa_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "task": {"name": "text", "seq_len": 64, "n_classes": 2, "vocab": 256},
              "batch_buckets": [1, 2, 4],
              "variants": ["dense", "dsa90"],
              "modules": [
                {"name": "classifier_dense_b1", "file": "x.hlo.txt",
                 "kind": "classifier", "variant": "dense", "batch": 1,
                 "seq_len": 64,
                 "inputs": [{"shape": [1, 64], "dtype": "int32"}],
                 "outputs": [{"shape": [1, 2], "dtype": "float32"}]}
              ],
              "tensors": []
            }"#,
        )
        .unwrap();
        let man = Manifest::open(&dir).unwrap();
        assert_eq!(man.task_seq_len, 64);
        assert_eq!(man.bucket_for(3), 4);
        assert_eq!(man.bucket_for(9), 4); // clamps to largest
        let m = man.classifier("dense", 1).unwrap();
        assert_eq!(m.input_shapes[0], vec![1, 64]);
    }
}
