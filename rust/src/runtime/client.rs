//! PJRT client + compiled-executable wrapper.
//!
//! Loads HLO **text** modules produced by `python/compile/aot.py` and
//! executes them on the CPU PJRT backend. Text (not serialized proto) is
//! the interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
//! instruction ids which xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md).

use std::path::Path;
use std::sync::Arc;

use crate::util::error::{bail, Context, Result};

/// Shared PJRT client handle.
#[derive(Clone)]
pub struct Client {
    inner: Arc<xla::PjRtClient>,
}

impl Client {
    /// Construct the host CPU client.
    pub fn cpu() -> Result<Client> {
        let inner = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Client {
            inner: Arc::new(inner),
        })
    }

    pub fn platform(&self) -> String {
        self.inner.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.inner.device_count()
    }

    /// Load an HLO-text module from disk and compile it.
    pub fn compile_hlo_file(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .inner
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe: Arc::new(exe),
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

/// Typed host-side tensor argument for execution.
#[derive(Debug, Clone)]
pub enum Arg {
    F32(Vec<f32>, Vec<i64>),
    I32(Vec<i32>, Vec<i64>),
}

impl Arg {
    pub fn f32(data: Vec<f32>, dims: &[usize]) -> Arg {
        Arg::F32(data, dims.iter().map(|&d| d as i64).collect())
    }

    pub fn i32(data: Vec<i32>, dims: &[usize]) -> Arg {
        Arg::I32(data, dims.iter().map(|&d| d as i64).collect())
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Arg::F32(data, dims) => {
                let n: i64 = dims.iter().product();
                if n as usize != data.len() {
                    bail!("f32 arg: {} elements but dims {:?}", data.len(), dims);
                }
                xla::Literal::vec1(data).reshape(dims)?
            }
            Arg::I32(data, dims) => {
                let n: i64 = dims.iter().product();
                if n as usize != data.len() {
                    bail!("i32 arg: {} elements but dims {:?}", data.len(), dims);
                }
                xla::Literal::vec1(data).reshape(dims)?
            }
        };
        Ok(lit)
    }
}

/// A compiled PJRT executable. Cheap to clone; `execute` is `&self` and
/// thread-safe at the PJRT level (the CPU client serializes internally).
#[derive(Clone)]
pub struct Executable {
    exe: Arc<xla::PjRtLoadedExecutable>,
    pub name: String,
}

impl Executable {
    /// Execute with host args; returns the elements of the output tuple as
    /// f32 vectors (aot.py lowers everything with return_tuple=True).
    pub fn run_f32(&self, args: &[Arg]) -> Result<Vec<Vec<f32>>> {
        let lits = args
            .iter()
            .map(|a| a.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing {}", self.name))?;
        let mut first = result
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .context("empty execution result")?
            .to_literal_sync()?;
        let parts = first.decompose_tuple()?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(Into::into))
            .collect()
    }
}
