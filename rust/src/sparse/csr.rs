//! CSR encoding of a binary mask — the layout the SDDMM/SpMM kernels and
//! the PE-array simulator index by.

use super::mask::DenseMask;

/// Compressed sparse row pattern (pattern only; values live elsewhere).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
}

impl Csr {
    pub fn from_mask(m: &DenseMask) -> Csr {
        let mut row_ptr = Vec::with_capacity(m.rows + 1);
        let mut col_idx = Vec::with_capacity(m.nnz());
        row_ptr.push(0);
        for r in 0..m.rows {
            for c in m.row_cols(r) {
                col_idx.push(c as u32);
            }
            row_ptr.push(col_idx.len() as u32);
        }
        Csr {
            rows: m.rows,
            cols: m.cols,
            row_ptr,
            col_idx,
        }
    }

    pub fn to_mask(&self) -> DenseMask {
        let mut m = DenseMask::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for &c in self.row(r) {
                m.set(r, c as usize, true);
            }
        }
        m
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[u32] {
        &self.col_idx[self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize]
    }

    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    pub fn row_nnz(&self, r: usize) -> usize {
        (self.row_ptr[r + 1] - self.row_ptr[r]) as usize
    }

    /// Load-imbalance factor: max row nnz / mean row nnz (>= 1). The paper's
    /// Sec. 5.2 discusses PE under-utilization from irregular rows; the
    /// row-wise top-k constraint drives this to ~1.
    pub fn load_imbalance(&self) -> f64 {
        if self.rows == 0 || self.nnz() == 0 {
            return 1.0;
        }
        let max = (0..self.rows).map(|r| self.row_nnz(r)).max().unwrap_or(0);
        let mean = self.nnz() as f64 / self.rows as f64;
        max as f64 / mean
    }

    /// Invariants used by property tests.
    pub fn check(&self) -> Result<(), String> {
        if self.row_ptr.len() != self.rows + 1 {
            return Err("row_ptr length".into());
        }
        if *self.row_ptr.last().unwrap() as usize != self.col_idx.len() {
            return Err("row_ptr tail".into());
        }
        for r in 0..self.rows {
            if self.row_ptr[r] > self.row_ptr[r + 1] {
                return Err(format!("row_ptr not monotone at {r}"));
            }
            let row = self.row(r);
            for w in row.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("row {r} columns not strictly ascending"));
                }
            }
            if row.iter().any(|&c| c as usize >= self.cols) {
                return Err(format!("row {r} column out of bounds"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Config};
    use crate::util::rng::Rng;

    fn random_mask(rng: &mut Rng, size: usize) -> DenseMask {
        let rows = 1 + rng.below(3 * size as u64) as usize;
        let cols = 1 + rng.below(6 * size as u64) as usize;
        let mut m = DenseMask::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                if rng.f64() < 0.25 {
                    m.set(r, c, true);
                }
            }
        }
        m
    }

    #[test]
    fn roundtrip_prop() {
        forall(
            &Config { cases: 48, ..Default::default() },
            random_mask,
            |m| {
                let csr = Csr::from_mask(m);
                csr.check().unwrap();
                csr.to_mask() == *m
            },
        );
    }

    #[test]
    fn imbalance_uniform_rows() {
        let mut m = DenseMask::zeros(4, 8);
        for r in 0..4 {
            m.set(r, r, true);
            m.set(r, r + 4, true);
        }
        let csr = Csr::from_mask(&m);
        assert!((csr.load_imbalance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_skewed() {
        let mut m = DenseMask::zeros(2, 8);
        for c in 0..8 {
            m.set(0, c, true);
        }
        m.set(1, 0, true);
        let csr = Csr::from_mask(&m);
        // max 8 / mean 4.5
        assert!((csr.load_imbalance() - 8.0 / 4.5).abs() < 1e-12);
    }
}
