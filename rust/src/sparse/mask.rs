//! Dense binary attention mask (bitset-backed).
//!
//! The canonical in-memory form of a predicted sparsity pattern `M` from
//! Eq. (4): `rows x cols` bits, row-major, one u64 word per 64 columns.

use crate::util::error::{bail, Result};
use crate::util::tensorio::{DType, Tensor};

/// Bitset mask over an attention matrix.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DenseMask {
    pub rows: usize,
    pub cols: usize,
    words_per_row: usize,
    bits: Vec<u64>,
}

impl DenseMask {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        let wpr = cols.div_ceil(64);
        DenseMask {
            rows,
            cols,
            words_per_row: wpr,
            bits: vec![0; wpr * rows],
        }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> bool {
        debug_assert!(r < self.rows && c < self.cols);
        let w = self.bits[r * self.words_per_row + c / 64];
        (w >> (c % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        debug_assert!(r < self.rows && c < self.cols);
        let idx = r * self.words_per_row + c / 64;
        let bit = 1u64 << (c % 64);
        if v {
            self.bits[idx] |= bit;
        } else {
            self.bits[idx] &= !bit;
        }
    }

    /// Number of kept entries in row `r` (popcount over the row's words).
    pub fn row_nnz(&self, r: usize) -> usize {
        let start = r * self.words_per_row;
        self.bits[start..start + self.words_per_row]
            .iter()
            .map(|w| w.count_ones() as usize)
            .sum()
    }

    pub fn nnz(&self) -> usize {
        self.bits.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Fraction of entries masked out (the paper's sparsity ratio).
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Column indices kept in row `r`, ascending.
    pub fn row_cols(&self, r: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.row_nnz(r));
        let start = r * self.words_per_row;
        for (wi, &w) in self.bits[start..start + self.words_per_row].iter().enumerate() {
            let mut w = w;
            while w != 0 {
                let b = w.trailing_zeros() as usize;
                let c = wi * 64 + b;
                if c < self.cols {
                    out.push(c);
                }
                w &= w - 1;
            }
        }
        out
    }

    /// Build from a u8 tensor of shape [rows, cols] (nonzero = kept).
    pub fn from_tensor(t: &Tensor) -> Result<Self> {
        if t.dtype != DType::U8 || t.dims.len() != 2 {
            bail!("mask tensor must be u8 rank-2, got {:?} {:?}", t.dtype, t.dims);
        }
        let (rows, cols) = (t.dims[0], t.dims[1]);
        let mut m = DenseMask::zeros(rows, cols);
        let data = t.as_u8()?;
        for r in 0..rows {
            for c in 0..cols {
                if data[r * cols + c] != 0 {
                    m.set(r, c, true);
                }
            }
        }
        Ok(m)
    }

    /// Slice a [.., rows, cols] u8 tensor at flat outer index `idx`.
    pub fn from_tensor_slice(t: &Tensor, idx: usize) -> Result<Self> {
        if t.dtype != DType::U8 || t.dims.len() < 2 {
            bail!("mask tensor must be u8 rank>=2");
        }
        let cols = t.dims[t.dims.len() - 1];
        let rows = t.dims[t.dims.len() - 2];
        let outer: usize = t.dims[..t.dims.len() - 2].iter().product();
        if idx >= outer.max(1) {
            bail!("slice index {idx} out of range {outer}");
        }
        let data = t.as_u8()?;
        let base = idx * rows * cols;
        let mut m = DenseMask::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                if data[base + r * cols + c] != 0 {
                    m.set(r, c, true);
                }
            }
        }
        Ok(m)
    }

    /// Export to a u8 tensor (for round-trips / fixtures).
    pub fn to_tensor(&self) -> Tensor {
        let mut v = vec![0u8; self.rows * self.cols];
        for r in 0..self.rows {
            for c in self.row_cols(r) {
                v[r * self.cols + c] = 1;
            }
        }
        Tensor::from_u8(vec![self.rows, self.cols], &v)
    }

    /// Fraction of rows whose nnz equals `k` (row-uniformity check used by
    /// the sparsity-aware execution constraint in Sec. 5.2).
    pub fn row_uniformity(&self, k: usize) -> f64 {
        if self.rows == 0 {
            return 1.0;
        }
        let even = (0..self.rows).filter(|&r| self.row_nnz(r) == k).count();
        even as f64 / self.rows as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Config};
    use crate::util::rng::Rng;

    #[test]
    fn set_get_roundtrip() {
        let mut m = DenseMask::zeros(4, 100);
        m.set(2, 63, true);
        m.set(2, 64, true);
        m.set(3, 99, true);
        assert!(m.get(2, 63) && m.get(2, 64) && m.get(3, 99));
        assert!(!m.get(2, 65));
        assert_eq!(m.nnz(), 3);
        m.set(2, 63, false);
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn row_cols_sorted_and_correct() {
        let mut m = DenseMask::zeros(1, 130);
        for c in [0, 5, 64, 127, 129] {
            m.set(0, c, true);
        }
        assert_eq!(m.row_cols(0), vec![0, 5, 64, 127, 129]);
        assert_eq!(m.row_nnz(0), 5);
    }

    #[test]
    fn sparsity_fraction() {
        let mut m = DenseMask::zeros(10, 10);
        for i in 0..10 {
            m.set(i, i, true);
        }
        assert!((m.sparsity() - 0.9).abs() < 1e-12);
        assert!((m.row_uniformity(1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn tensor_roundtrip_prop() {
        forall(
            &Config { cases: 32, ..Default::default() },
            |rng: &mut Rng, size| {
                let rows = 1 + rng.below(4 * size as u64) as usize;
                let cols = 1 + rng.below(8 * size as u64) as usize;
                let mut m = DenseMask::zeros(rows, cols);
                for r in 0..rows {
                    for c in 0..cols {
                        if rng.f64() < 0.2 {
                            m.set(r, c, true);
                        }
                    }
                }
                m
            },
            |m| DenseMask::from_tensor(&m.to_tensor()).unwrap() == *m,
        );
    }
}
