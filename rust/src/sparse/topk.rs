//! Row-wise top-k selection over score matrices.
//!
//! Mirrors the semantics of the JAX side (attention.topk_mask_from_scores /
//! kernels.ref.topk_mask): the k-th largest value per row is the threshold
//! and ties at the threshold are kept (so nnz per row can exceed k when
//! scores tie — relevant for quantized scores, where ties are common).
//!
//! NaN scores are ordered below every finite value and `-inf` (a NaN can
//! never displace a real score from a top-k set; an all-NaN row keeps
//! everything under the inclusive-tie rule and exactly `k` low-column
//! entries under the exact rule). The previous implementation fed NaNs
//! through `partial_cmp`, making `select_nth_unstable_by`'s ordering
//! non-total and the `>= thresh` filter silently drop rows.

use std::cmp::Ordering;

use super::mask::DenseMask;

/// Map NaN to `-inf` so `total_cmp` gives the ordering documented above.
#[inline]
fn sanitize(x: f32) -> f32 {
    if x.is_nan() {
        f32::NEG_INFINITY
    } else {
        x
    }
}

/// Total order: higher score first, ties broken by lower column index.
#[inline]
fn desc_score_then_col(row: &[f32], a: usize, b: usize) -> Ordering {
    sanitize(row[b])
        .total_cmp(&sanitize(row[a]))
        .then(a.cmp(&b))
}

/// Exact top-k column indices of one score row (ties broken by lower
/// column), returned in ascending column order. This is the per-row
/// primitive shared by [`topk_mask_exact`] and the native kernels'
/// row-parallel path, so both always select identical masks.
pub fn topk_row_indices(row: &[f32], k: usize) -> Vec<usize> {
    let mut out = Vec::new();
    topk_row_indices_into(row, k, &mut out);
    out
}

/// Allocation-free form of [`topk_row_indices`]: writes the selection into
/// `out`, which doubles as the selection buffer (its capacity grows to
/// `row.len()` once and is reused across rows — see `kernels::scratch`).
/// Identical selection semantics, asserted by the tests.
pub fn topk_row_indices_into(row: &[f32], k: usize, out: &mut Vec<usize>) {
    let cols = row.len();
    out.clear();
    if cols == 0 {
        return;
    }
    let k = k.clamp(1, cols);
    out.extend(0..cols);
    if k < cols {
        // Partial selection instead of a full per-row sort: O(cols) to
        // place the top-k prefix (§Perf: see EXPERIMENTS.md for the
        // measured delta at 256x256, k=26).
        out.select_nth_unstable_by(k, |&a, &b| desc_score_then_col(row, a, b));
        out.truncate(k);
    }
    out.sort_unstable();
}

/// Row top-k mask over a row-major `rows x cols` score matrix, keeping
/// ties at the threshold (nnz per row >= k).
pub fn topk_mask(scores: &[f32], rows: usize, cols: usize, k: usize) -> DenseMask {
    assert_eq!(scores.len(), rows * cols);
    let mut m = DenseMask::zeros(rows, cols);
    if cols == 0 {
        return m;
    }
    let k = k.clamp(1, cols);
    let mut buf: Vec<f32> = Vec::with_capacity(cols);
    for r in 0..rows {
        let row = &scores[r * cols..(r + 1) * cols];
        buf.clear();
        buf.extend(row.iter().map(|&v| sanitize(v)));
        // kth largest via partial selection under a total order
        let idx = cols - k;
        buf.select_nth_unstable_by(idx, |a, b| a.total_cmp(b));
        let thresh = buf[idx];
        for (c, &v) in row.iter().enumerate() {
            if sanitize(v) >= thresh {
                m.set(r, c, true);
            }
        }
    }
    m
}

/// Row top-k keeping *exactly* k entries per row (ties broken by column
/// order) — the row-uniform constraint of Sec. 5.2 that balances PE load.
pub fn topk_mask_exact(scores: &[f32], rows: usize, cols: usize, k: usize) -> DenseMask {
    assert_eq!(scores.len(), rows * cols);
    let mut m = DenseMask::zeros(rows, cols);
    if cols == 0 {
        return m;
    }
    for r in 0..rows {
        let row = &scores[r * cols..(r + 1) * cols];
        for c in topk_row_indices(row, k) {
            m.set(r, c, true);
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Config};
    use crate::util::rng::Rng;

    #[test]
    fn picks_largest() {
        let scores = vec![0.1, 0.9, 0.5, 0.3];
        let m = topk_mask(&scores, 1, 4, 2);
        assert!(m.get(0, 1) && m.get(0, 2));
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn ties_kept_inclusive() {
        let scores = vec![1.0, 1.0, 1.0, 0.0];
        let m = topk_mask(&scores, 1, 4, 2);
        assert_eq!(m.row_nnz(0), 3); // all tied at threshold kept
        let e = topk_mask_exact(&scores, 1, 4, 2);
        assert_eq!(e.row_nnz(0), 2); // exact variant trims
    }

    #[test]
    fn row_indices_ascending_and_exact() {
        let row = [0.3f32, 0.9, 0.1, 0.9, 0.5];
        assert_eq!(topk_row_indices(&row, 3), vec![1, 3, 4]);
        assert_eq!(topk_row_indices(&row, 99), vec![0, 1, 2, 3, 4]);
        assert_eq!(topk_row_indices(&[], 3), Vec::<usize>::new());
    }

    #[test]
    fn into_variant_reuses_buffer_and_matches() {
        let mut rng = Rng::new(5);
        let mut buf = Vec::new();
        for _ in 0..50 {
            let cols = 1 + rng.below(40) as usize;
            let k = 1 + rng.below(cols as u64) as usize;
            let row: Vec<f32> = (0..cols)
                .map(|_| if rng.f64() < 0.1 { f32::NAN } else { rng.f32() })
                .collect();
            topk_row_indices_into(&row, k, &mut buf);
            assert_eq!(buf, topk_row_indices(&row, k));
            assert!(buf.capacity() <= 80, "buffer should stay bounded by ~cols");
        }
        // Stale contents from a prior (larger) row never leak through.
        topk_row_indices_into(&[], 3, &mut buf);
        assert!(buf.is_empty());
    }

    #[test]
    fn nan_scores_never_selected_over_finite() {
        // Regression: NaNs used to corrupt select_nth's ordering and the
        // `>= thresh` filter could silently drop whole rows.
        let scores = vec![f32::NAN, 1.0, f32::NAN, 0.5];
        let m = topk_mask(&scores, 1, 4, 2);
        assert_eq!(m.row_cols(0), vec![1, 3]);
        let e = topk_mask_exact(&scores, 1, 4, 2);
        assert_eq!(e.row_cols(0), vec![1, 3]);
    }

    #[test]
    fn all_nan_row_keeps_k_exact_and_all_inclusive() {
        let scores = vec![f32::NAN; 4];
        // Inclusive rule: everything ties at the sanitized threshold.
        assert_eq!(topk_mask(&scores, 1, 4, 2).row_nnz(0), 4);
        // Exact rule: low-column tie-break, still exactly k.
        assert_eq!(topk_mask_exact(&scores, 1, 4, 2).row_cols(0), vec![0, 1]);
    }

    #[test]
    fn nan_rows_prop() {
        forall(
            &Config { cases: 40, ..Default::default() },
            |rng: &mut Rng, size| {
                let rows = 1 + rng.below(size as u64) as usize;
                let cols = 4 + rng.below(size as u64 * 8) as usize;
                let k = 1 + rng.below((cols / 2) as u64) as usize;
                let scores: Vec<f32> = (0..rows * cols)
                    .map(|_| {
                        if rng.f64() < 0.2 {
                            f32::NAN
                        } else {
                            rng.f32()
                        }
                    })
                    .collect();
                (scores, rows, cols, k)
            },
            |(scores, rows, cols, k)| {
                let e = topk_mask_exact(scores, *rows, *cols, *k);
                (0..*rows).all(|r| {
                    let row = &scores[r * cols..(r + 1) * cols];
                    let finite = row.iter().filter(|v| !v.is_nan()).count();
                    // exact-k never drops a row, and NaN columns are only
                    // selected when fewer than k finite scores exist.
                    e.row_nnz(r) == *k
                        && (finite < *k
                            || e.row_cols(r).iter().all(|&c| !row[c].is_nan()))
                })
            },
        );
    }

    #[test]
    fn exact_is_row_uniform_prop() {
        forall(
            &Config { cases: 40, ..Default::default() },
            |rng: &mut Rng, size| {
                let rows = 1 + rng.below(size as u64 * 2) as usize;
                let cols = 2 + rng.below(size as u64 * 8) as usize;
                let k = 1 + rng.below(cols as u64) as usize;
                let scores: Vec<f32> = (0..rows * cols).map(|_| rng.f32()).collect();
                (scores, rows, cols, k)
            },
            |(scores, rows, cols, k)| {
                let m = topk_mask_exact(scores, *rows, *cols, *k);
                (0..*rows).all(|r| m.row_nnz(r) == *k.min(cols))
            },
        );
    }

    #[test]
    fn inclusive_contains_exact_prop() {
        forall(
            &Config { cases: 40, ..Default::default() },
            |rng: &mut Rng, size| {
                let rows = 1 + rng.below(size as u64) as usize;
                let cols = 2 + rng.below(size as u64 * 8) as usize;
                let k = 1 + rng.below(cols as u64) as usize;
                // distinct-ish scores to avoid massive ties
                let scores: Vec<f32> =
                    (0..rows * cols).map(|i| rng.f32() + i as f32 * 1e-6).collect();
                (scores, rows, cols, k)
            },
            |(scores, rows, cols, k)| {
                let inc = topk_mask(scores, *rows, *cols, *k);
                let exa = topk_mask_exact(scores, *rows, *cols, *k);
                (0..*rows).all(|r| {
                    exa.row_cols(r).iter().all(|&c| inc.get(r, c))
                })
            },
        );
    }
}
