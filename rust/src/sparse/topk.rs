//! Row-wise top-k selection over score matrices.
//!
//! Mirrors the semantics of the JAX side (attention.topk_mask_from_scores /
//! kernels.ref.topk_mask): the k-th largest value per row is the threshold
//! and ties at the threshold are kept (so nnz per row can exceed k when
//! scores tie — relevant for quantized scores, where ties are common).

use super::mask::DenseMask;

/// Row top-k mask over a row-major `rows x cols` score matrix.
pub fn topk_mask(scores: &[f32], rows: usize, cols: usize, k: usize) -> DenseMask {
    assert_eq!(scores.len(), rows * cols);
    let k = k.clamp(1, cols.max(1));
    let mut m = DenseMask::zeros(rows, cols);
    let mut buf: Vec<f32> = Vec::with_capacity(cols);
    for r in 0..rows {
        let row = &scores[r * cols..(r + 1) * cols];
        buf.clear();
        buf.extend_from_slice(row);
        // kth largest via partial selection
        let idx = cols - k;
        buf.select_nth_unstable_by(idx, |a, b| {
            a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal)
        });
        let thresh = buf[idx];
        for (c, &v) in row.iter().enumerate() {
            if v >= thresh {
                m.set(r, c, true);
            }
        }
    }
    m
}

/// Row top-k keeping *exactly* k entries per row (ties broken by column
/// order) — the row-uniform constraint of Sec. 5.2 that balances PE load.
pub fn topk_mask_exact(scores: &[f32], rows: usize, cols: usize, k: usize) -> DenseMask {
    assert_eq!(scores.len(), rows * cols);
    let k = k.clamp(1, cols.max(1));
    let mut m = DenseMask::zeros(rows, cols);
    let mut order: Vec<usize> = Vec::with_capacity(cols);
    for r in 0..rows {
        let row = &scores[r * cols..(r + 1) * cols];
        order.clear();
        order.extend(0..cols);
        if k < cols {
            // Partial selection instead of a full per-row sort: O(cols) to
            // place the top-k prefix, then sort only that prefix for the
            // deterministic column-order tie-break. (§Perf: 8.4 ms -> see
            // EXPERIMENTS.md for the measured delta at 256x256, k=26.)
            order.select_nth_unstable_by(k, |&a, &b| {
                row[b]
                    .partial_cmp(&row[a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
        }
        let prefix = &mut order[..k];
        prefix.sort_by(|&a, &b| {
            row[b]
                .partial_cmp(&row[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        for &c in prefix.iter() {
            m.set(r, c, true);
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Config};
    use crate::util::rng::Rng;

    #[test]
    fn picks_largest() {
        let scores = vec![0.1, 0.9, 0.5, 0.3];
        let m = topk_mask(&scores, 1, 4, 2);
        assert!(m.get(0, 1) && m.get(0, 2));
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn ties_kept_inclusive() {
        let scores = vec![1.0, 1.0, 1.0, 0.0];
        let m = topk_mask(&scores, 1, 4, 2);
        assert_eq!(m.row_nnz(0), 3); // all tied at threshold kept
        let e = topk_mask_exact(&scores, 1, 4, 2);
        assert_eq!(e.row_nnz(0), 2); // exact variant trims
    }

    #[test]
    fn exact_is_row_uniform_prop() {
        forall(
            &Config { cases: 40, ..Default::default() },
            |rng: &mut Rng, size| {
                let rows = 1 + rng.below(size as u64 * 2) as usize;
                let cols = 2 + rng.below(size as u64 * 8) as usize;
                let k = 1 + rng.below(cols as u64) as usize;
                let scores: Vec<f32> = (0..rows * cols).map(|_| rng.f32()).collect();
                (scores, rows, cols, k)
            },
            |(scores, rows, cols, k)| {
                let m = topk_mask_exact(scores, *rows, *cols, *k);
                (0..*rows).all(|r| m.row_nnz(r) == *k.min(cols))
            },
        );
    }

    #[test]
    fn inclusive_contains_exact_prop() {
        forall(
            &Config { cases: 40, ..Default::default() },
            |rng: &mut Rng, size| {
                let rows = 1 + rng.below(size as u64) as usize;
                let cols = 2 + rng.below(size as u64 * 8) as usize;
                let k = 1 + rng.below(cols as u64) as usize;
                // distinct-ish scores to avoid massive ties
                let scores: Vec<f32> =
                    (0..rows * cols).map(|i| rng.f32() + i as f32 * 1e-6).collect();
                (scores, rows, cols, k)
            },
            |(scores, rows, cols, k)| {
                let inc = topk_mask(scores, *rows, *cols, *k);
                let exa = topk_mask_exact(scores, *rows, *cols, *k);
                (0..*rows).all(|r| {
                    exa.row_cols(r).iter().all(|&c| inc.get(r, c))
                })
            },
        );
    }
}
