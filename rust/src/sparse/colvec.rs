//! Column-vector sparse encoding (paper Fig. 9, Chen et al. 2021).
//!
//! The attention matrix is partitioned into panels of `vec` consecutive
//! rows; sparsity is selected at the granularity of `vec`-tall column
//! vectors inside each panel. This gives block-sparse-like data reuse for
//! SpMM/SDDMM (the whole K/V column is reused across the panel's rows)
//! while keeping the selection granularity small enough to preserve
//! accuracy (Table 4).

use super::mask::DenseMask;
use crate::util::error::{bail, Result};

/// Column-vector pattern: for each row panel, the list of selected columns.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ColVec {
    pub rows: usize,
    pub cols: usize,
    pub vec: usize,
    /// panel_cols[p] = ascending columns kept for panel p (rows p*vec..).
    pub panel_cols: Vec<Vec<u32>>,
}

impl ColVec {
    /// Encode a mask that is already column-vector structured.
    /// Fails if any panel has a column only partially set.
    pub fn from_mask(m: &DenseMask, vec: usize) -> Result<ColVec> {
        if vec == 0 || m.rows % vec != 0 {
            bail!("rows {} not divisible by vec {}", m.rows, vec);
        }
        let panels = m.rows / vec;
        let mut panel_cols = Vec::with_capacity(panels);
        for p in 0..panels {
            let mut cols = Vec::new();
            for c in 0..m.cols {
                let set: usize = (0..vec).filter(|&i| m.get(p * vec + i, c)).count();
                if set == vec {
                    cols.push(c as u32);
                } else if set != 0 {
                    bail!("panel {p} column {c} partially set ({set}/{vec})");
                }
            }
            panel_cols.push(cols);
        }
        Ok(ColVec {
            rows: m.rows,
            cols: m.cols,
            vec,
            panel_cols,
        })
    }

    /// Structure a *fine-grained* mask into column vectors by keeping, per
    /// panel, the columns with the highest hit count (ties by lower column),
    /// matching the per-panel budget = round(mean panel nnz / vec).
    pub fn structure(m: &DenseMask, vec: usize) -> Result<ColVec> {
        if vec == 0 || m.rows % vec != 0 {
            bail!("rows {} not divisible by vec {}", m.rows, vec);
        }
        let panels = m.rows / vec;
        let mut panel_cols = Vec::with_capacity(panels);
        for p in 0..panels {
            let mut hits = vec![0usize; m.cols];
            let mut nnz = 0usize;
            for i in 0..vec {
                for c in m.row_cols(p * vec + i) {
                    hits[c] += 1;
                    nnz += 1;
                }
            }
            let budget = (nnz as f64 / vec as f64).round().max(1.0) as usize;
            let mut order: Vec<usize> = (0..m.cols).collect();
            order.sort_by(|&a, &b| hits[b].cmp(&hits[a]).then(a.cmp(&b)));
            let mut cols: Vec<u32> = order
                .into_iter()
                .take(budget.min(m.cols))
                .filter(|&c| hits[c] > 0)
                .map(|c| c as u32)
                .collect();
            cols.sort_unstable();
            panel_cols.push(cols);
        }
        Ok(ColVec {
            rows: m.rows,
            cols: m.cols,
            vec,
            panel_cols,
        })
    }

    pub fn to_mask(&self) -> DenseMask {
        let mut m = DenseMask::zeros(self.rows, self.cols);
        for (p, cols) in self.panel_cols.iter().enumerate() {
            for &c in cols {
                for i in 0..self.vec {
                    m.set(p * self.vec + i, c as usize, true);
                }
            }
        }
        m
    }

    pub fn nnz(&self) -> usize {
        self.panel_cols.iter().map(|c| c.len()).sum::<usize>() * self.vec
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Data-reuse factor for the second operand (K^T columns / V rows): how
    /// many MACs each loaded operand vector serves. Fine-grained = 1; a
    /// vec-tall column vector serves `vec` rows per load (Sec. 5.1).
    pub fn reuse_factor(&self) -> f64 {
        self.vec as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_structured() {
        let mut m = DenseMask::zeros(8, 16);
        // panel 0 keeps cols 1, 7; panel 1 keeps col 3 (vec = 4)
        for i in 0..4 {
            m.set(i, 1, true);
            m.set(i, 7, true);
            m.set(4 + i, 3, true);
        }
        let cv = ColVec::from_mask(&m, 4).unwrap();
        assert_eq!(cv.panel_cols, vec![vec![1, 7], vec![3]]);
        assert_eq!(cv.to_mask(), m);
        assert_eq!(cv.nnz(), 12);
    }

    #[test]
    fn rejects_partial_columns() {
        let mut m = DenseMask::zeros(4, 4);
        m.set(0, 2, true); // only 1 of 4 rows in the panel
        assert!(ColVec::from_mask(&m, 4).is_err());
    }

    #[test]
    fn rejects_bad_vec() {
        let m = DenseMask::zeros(6, 4);
        assert!(ColVec::from_mask(&m, 4).is_err());
    }

    #[test]
    fn structure_preserves_budget() {
        let mut rng = Rng::new(5);
        let mut m = DenseMask::zeros(16, 64);
        // fine-grained ~10% mask
        for r in 0..16 {
            for _ in 0..6 {
                let c = rng.below(64) as usize;
                m.set(r, c, true);
            }
        }
        let cv = ColVec::structure(&m, 4).unwrap();
        // nnz should be in the same ballpark as the fine-grained mask
        let fine = m.nnz() as f64;
        let s = cv.nnz() as f64;
        assert!(s > 0.5 * fine && s < 2.0 * fine, "nnz {s} vs fine {fine}");
        // and the result must be losslessly encodable
        let re = ColVec::from_mask(&cv.to_mask(), 4).unwrap();
        assert_eq!(re, cv);
    }

    #[test]
    fn reuse_factor_is_vec() {
        let m = DenseMask::zeros(8, 8);
        let cv = ColVec::from_mask(&m, 8).unwrap();
        assert_eq!(cv.reuse_factor(), 8.0);
    }
}
