//! Block-sparse encoding (B×B tiles).
//!
//! The paper's Sec. 5.1 discusses block-wise structural constraints as the
//! coarse end of the granularity spectrum ("larger blocks deliver higher
//! speedup but can potentially cause accuracy loss"); on TPU a B×B block
//! is the natural unit of a skipped MXU pass (DESIGN.md
//! §Hardware-Adaptation). This encoding complements [`super::ColVec`]:
//! reuse factor B on *both* operands instead of one.

use super::mask::DenseMask;
use crate::util::error::{bail, Result};

/// Block pattern: for each block-row, the ascending list of block-columns.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockSparse {
    pub rows: usize,
    pub cols: usize,
    pub block: usize,
    /// row_blocks[i] = kept block-column indices for block-row i.
    pub row_blocks: Vec<Vec<u32>>,
}

impl BlockSparse {
    /// Encode a mask that is exactly block-structured (every B×B tile all-0
    /// or all-1).
    pub fn from_mask(m: &DenseMask, block: usize) -> Result<BlockSparse> {
        if block == 0 || m.rows % block != 0 || m.cols % block != 0 {
            bail!("mask {}x{} not divisible by block {}", m.rows, m.cols, block);
        }
        let (br, bc) = (m.rows / block, m.cols / block);
        let mut row_blocks = Vec::with_capacity(br);
        for i in 0..br {
            let mut blocks = Vec::new();
            for j in 0..bc {
                let mut set = 0usize;
                for r in 0..block {
                    for c in 0..block {
                        if m.get(i * block + r, j * block + c) {
                            set += 1;
                        }
                    }
                }
                if set == block * block {
                    blocks.push(j as u32);
                } else if set != 0 {
                    bail!("tile ({i},{j}) partially set ({set}/{})", block * block);
                }
            }
            row_blocks.push(blocks);
        }
        Ok(BlockSparse {
            rows: m.rows,
            cols: m.cols,
            block,
            row_blocks,
        })
    }

    /// Structure a fine-grained mask into blocks: keep, per block-row, the
    /// tiles with the highest hit count under a budget matching the
    /// fine-grained density (same policy as [`super::ColVec::structure`]).
    pub fn structure(m: &DenseMask, block: usize) -> Result<BlockSparse> {
        if block == 0 || m.rows % block != 0 || m.cols % block != 0 {
            bail!("mask {}x{} not divisible by block {}", m.rows, m.cols, block);
        }
        let (br, bc) = (m.rows / block, m.cols / block);
        let mut row_blocks = Vec::with_capacity(br);
        for i in 0..br {
            let mut hits = vec![0usize; bc];
            let mut nnz = 0usize;
            for r in 0..block {
                for c in m.row_cols(i * block + r) {
                    hits[c / block] += 1;
                    nnz += 1;
                }
            }
            let budget = ((nnz as f64 / (block * block) as f64).round() as usize).max(1);
            let mut order: Vec<usize> = (0..bc).collect();
            order.sort_by(|&a, &b| hits[b].cmp(&hits[a]).then(a.cmp(&b)));
            let mut blocks: Vec<u32> = order
                .into_iter()
                .take(budget.min(bc))
                .filter(|&j| hits[j] > 0)
                .map(|j| j as u32)
                .collect();
            blocks.sort_unstable();
            row_blocks.push(blocks);
        }
        Ok(BlockSparse {
            rows: m.rows,
            cols: m.cols,
            block,
            row_blocks,
        })
    }

    pub fn to_mask(&self) -> DenseMask {
        let mut m = DenseMask::zeros(self.rows, self.cols);
        for (i, blocks) in self.row_blocks.iter().enumerate() {
            for &j in blocks {
                for r in 0..self.block {
                    for c in 0..self.block {
                        m.set(i * self.block + r, j as usize * self.block + c, true);
                    }
                }
            }
        }
        m
    }

    pub fn nnz(&self) -> usize {
        self.row_blocks.iter().map(|b| b.len()).sum::<usize>() * self.block * self.block
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Fraction of MXU tile passes skipped for a (tile_m x tile_n) systolic
    /// pass grid — the TPU analogue of the paper's kernel speedups. When
    /// the encoding block divides the MXU tile, the skip rate equals the
    /// block-level sparsity; finer blocks skip conservatively (a pass runs
    /// if ANY covered block is kept).
    pub fn mxu_skip_rate(&self, tile: usize) -> f64 {
        assert!(tile >= self.block && tile % self.block == 0);
        let per = tile / self.block;
        let (tr, tc) = (self.rows / tile, self.cols / tile);
        if tr == 0 || tc == 0 {
            return 0.0;
        }
        let mut live = 0usize;
        for ti in 0..tr {
            let mut cols_live = vec![false; tc];
            for sub in 0..per {
                for &j in &self.row_blocks[ti * per + sub] {
                    cols_live[j as usize / per] = true;
                }
            }
            live += cols_live.iter().filter(|&&x| x).count();
        }
        1.0 - live as f64 / (tr * tc) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::topk;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_block_structured() {
        let mut m = DenseMask::zeros(8, 8);
        for r in 0..4 {
            for c in 4..8 {
                m.set(r, c, true); // top-right 4x4 tile
            }
        }
        let b = BlockSparse::from_mask(&m, 4).unwrap();
        assert_eq!(b.row_blocks, vec![vec![1], vec![]]);
        assert_eq!(b.to_mask(), m);
        assert!((b.sparsity() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn rejects_partial_tiles() {
        let mut m = DenseMask::zeros(4, 4);
        m.set(0, 0, true);
        assert!(BlockSparse::from_mask(&m, 2).is_err());
    }

    #[test]
    fn structure_roundtrips_and_preserves_budget() {
        let mut rng = Rng::new(2);
        let scores: Vec<f32> = (0..64 * 64).map(|_| rng.f32()).collect();
        let fine = topk::topk_mask_exact(&scores, 64, 64, 6);
        let b = BlockSparse::structure(&fine, 8).unwrap();
        let re = BlockSparse::from_mask(&b.to_mask(), 8).unwrap();
        assert_eq!(re, b);
        let ratio = b.nnz() as f64 / fine.nnz() as f64;
        assert!(ratio > 0.4 && ratio < 2.5, "budget drifted: {ratio}");
    }

    #[test]
    fn mxu_skip_rate_matches_block_sparsity_when_aligned() {
        let mut m = DenseMask::zeros(16, 16);
        // keep exactly one 8x8 tile of four
        for r in 0..8 {
            for c in 0..8 {
                m.set(r, c, true);
            }
        }
        let b = BlockSparse::from_mask(&m, 8).unwrap();
        assert!((b.mxu_skip_rate(8) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn coarser_tiles_skip_less() {
        // scattered 4x4 blocks: 16x16 grid of tiles at 25% density
        let mut rng = Rng::new(7);
        let mut m = DenseMask::zeros(64, 64);
        for i in 0..16 {
            for j in 0..16 {
                if rng.f64() < 0.25 {
                    for r in 0..4 {
                        for c in 0..4 {
                            m.set(i * 4 + r, j * 4 + c, true);
                        }
                    }
                }
            }
        }
        let b = BlockSparse::from_mask(&m, 4).unwrap();
        let fine_skip = b.mxu_skip_rate(4);
        let coarse_skip = b.mxu_skip_rate(16);
        assert!(fine_skip > coarse_skip, "{fine_skip} vs {coarse_skip}");
    }
}
