//! Sparse attention pattern representations and selection.
//!
//! * [`mask::DenseMask`] — bitset mask, the canonical form of Eq. (4)'s `M`.
//! * [`csr::Csr`] — compressed rows, what SDDMM/SpMM and the PE simulator
//!   iterate.
//! * [`colvec::ColVec`] — column-vector structural encoding (Fig. 9).
//! * [`topk`] — row-wise top-k selection (inclusive-tie and exact-k).

pub mod block;
pub mod colvec;
pub mod csr;
pub mod mask;
pub mod topk;

pub use block::BlockSparse;
pub use colvec::ColVec;
pub use csr::Csr;
pub use mask::DenseMask;
