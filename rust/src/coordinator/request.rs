//! Request / response types flowing through the serving engine. Variants
//! are carried as the typed [`Variant`] — parsing happens once at the
//! protocol/CLI boundary (`Variant::from_str`), so an unknown variant can
//! never reach the batcher or a backend.

use std::time::{Duration, Instant};

use crate::kernels::Variant;

/// A single classification request (token ids, already tokenized).
#[derive(Debug, Clone)]
pub struct InferRequest {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Model variant override; `None` = engine default (or the adaptive
    /// router's pick).
    pub variant: Option<Variant>,
    pub enqueued: Instant,
    /// Absolute deadline: if the request has not *started executing* by
    /// this instant the batcher sheds it with a structured `expired`
    /// reply. `None` = no deadline.
    pub deadline: Option<Instant>,
}

impl InferRequest {
    pub fn new(id: u64, tokens: Vec<i32>) -> Self {
        InferRequest {
            id,
            tokens,
            variant: None,
            enqueued: Instant::now(),
            deadline: None,
        }
    }

    pub fn with_variant(mut self, v: Variant) -> Self {
        self.variant = Some(v);
        self
    }

    /// Set the deadline as a budget relative to the enqueue time.
    pub fn with_deadline(mut self, budget: Duration) -> Self {
        self.deadline = Some(self.enqueued + budget);
        self
    }
}

/// A session-lifecycle operation flowing to the engine worker. Like
/// [`InferRequest`], the variant is already typed — the server parses
/// `{"op": "open" | "decode" | "close"}` once at the protocol boundary
/// and everything past it is enum-shaped.
#[derive(Debug, Clone)]
pub enum SessionOp {
    /// Open a decode session prefilled with `prompt`; the engine assigns
    /// the id. `variant: None` = engine default (or the adaptive
    /// router's pick at open time; the session then stays on it).
    Open {
        prompt: Vec<i32>,
        variant: Option<Variant>,
    },
    /// Append one token to session `session` and run a decode step.
    Decode { session: u64, token: i32 },
    /// Close session `session`, releasing its cache for reuse.
    Close { session: u64 },
    /// Rebuild a session from its journal on this replica: prefill
    /// `prompt` and append `decoded` without re-running the decode
    /// kernel. The cache state is bitwise-identical to having decoded
    /// the same tokens step by step (the kernel never writes to the
    /// cache), so migration preserves determinism. The variant is
    /// already pinned — no router consult.
    Reopen {
        prompt: Vec<i32>,
        decoded: Vec<i32>,
        variant: Variant,
    },
}

/// Successful reply to a [`SessionOp`] (errors travel as the engine's
/// structured `Result` error, rendered at the protocol boundary).
#[derive(Debug, Clone)]
pub enum SessionReply {
    Opened {
        session: u64,
        /// Prompt tokens resident in the cache after prefill.
        resident: usize,
        /// The variant the session was pinned to.
        variant: Variant,
    },
    Decoded(DecodeResponse),
    Closed {
        session: u64,
        /// Tokens that were resident when the cache was released.
        released: usize,
    },
}

/// Completed decode step.
#[derive(Debug, Clone)]
pub struct DecodeResponse {
    pub session: u64,
    pub logits: Vec<f32>,
    pub pred: usize,
    /// Tokens resident in the session cache after this step.
    pub resident: usize,
    /// Total time from enqueue to completion (the serving ITL).
    pub latency: Duration,
    /// The variant the session runs on.
    pub variant: Variant,
}

/// Completed inference result.
#[derive(Debug, Clone)]
pub struct InferResponse {
    pub id: u64,
    pub logits: Vec<f32>,
    pub pred: usize,
    /// Total time from enqueue to completion.
    pub latency: Duration,
    /// Time spent waiting in the batcher queue.
    pub queue_time: Duration,
    /// Size of the batch this request was served in (before padding).
    pub batch_size: usize,
    /// Executable bucket it ran under (after padding).
    pub bucket: usize,
    /// The variant that actually served this request (typed; render with
    /// `to_string()` at protocol boundaries).
    pub variant: Variant,
}

impl InferResponse {
    pub fn argmax(logits: &[f32]) -> usize {
        logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(InferResponse::argmax(&[0.1, 0.9]), 1);
        assert_eq!(InferResponse::argmax(&[3.0, -1.0, 2.0]), 0);
        assert_eq!(InferResponse::argmax(&[]), 0);
    }
}
