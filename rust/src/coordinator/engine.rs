//! Serving engine: the L3 hot path.
//!
//! A submission channel feeds a single worker thread driving the
//! [`Batcher`]: it sleeps until the head-of-line deadline or a full batch,
//! cuts a batch of same-variant requests, pads it to the backend's
//! execution bucket, runs the batch through an
//! [`InferBackend`](super::backend::InferBackend) as **one** backend
//! dispatch — the native backend hands the whole bucket to the batched
//! multi-head kernels, which parallelize over `(sequence, row-range)`
//! work items on the process-wide persistent worker pool
//! (`kernels::pool`) — and fans responses back through per-request
//! channels. With [`EngineConfig::router`] set, the worker also picks the
//! serving variant per batch from the live queue depth (dense under light
//! load, sparser DSA rungs as backlog grows), recording every decision
//! plus the pool counters in [`Metrics`].
//!
//! The backend is constructed **inside** the worker thread from a factory
//! closure: the PJRT artifact backend's handles are thread-local and must
//! never cross threads, and the native backend simply doesn't care.
//! Startup errors (bad artifacts, compile failures, unknown variants
//! during preload) are reported synchronously through a channel.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::backend::{InferBackend, NativeBackend, NativeModelConfig};
use super::batcher::{BatchPolicy, Batcher, SessionJob};
use super::metrics::Metrics;
use super::request::{DecodeResponse, InferRequest, InferResponse, SessionOp, SessionReply};
use super::router::{AdaptiveRouter, QueueLoad};
use crate::kernels::Variant;
use crate::util::error::{bail, Context, Result};

/// Capacity bound on live decode sessions.
#[derive(Debug, Clone)]
pub struct SessionPolicy {
    /// Hard cap on concurrently open sessions: opening one more evicts
    /// the least-recently-used session (its cache returns to the pool and
    /// the eviction is counted in [`Metrics`]; later ops on the evicted
    /// id get a structured "unknown session" error).
    pub max_sessions: usize,
}

impl Default for SessionPolicy {
    fn default() -> Self {
        SessionPolicy { max_sessions: 64 }
    }
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Typed serving variant for batches without an override — parse CLI
    /// or config strings once via `Variant::from_str` before building
    /// this (an unknown variant can then never reach the worker loop).
    pub default_variant: Variant,
    pub policy: BatchPolicy,
    /// Eagerly warm up the default variant at startup.
    pub preload: bool,
    /// Adaptive variant routing: batches of requests **without** an
    /// explicit variant override are routed by live queue depth (the
    /// backlog left after the batch is cut) instead of always serving
    /// `default_variant`. Every rung is preloaded at startup and every
    /// decision is recorded in [`Metrics`]. `None` = fixed default.
    pub router: Option<AdaptiveRouter>,
    /// Decode-session capacity (LRU eviction past the cap).
    pub sessions: SessionPolicy,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            default_variant: Variant::Dsa { pct: 90 },
            policy: BatchPolicy::default(),
            preload: true,
            router: None,
            sessions: SessionPolicy::default(),
        }
    }
}

enum Msg {
    Request(InferRequest, Sender<InferResponse>),
    Session(SessionJob),
    Shutdown,
}

/// Handle to a running engine.
pub struct Engine {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
    seq_len: usize,
    classes: usize,
}

impl Engine {
    /// Start the engine over a backend factory that runs on the worker
    /// thread (see the module docs for why).
    pub fn start_with<F>(factory: F, cfg: EngineConfig) -> Result<Engine>
    where
        F: FnOnce() -> Result<Box<dyn InferBackend>> + Send + 'static,
    {
        let metrics = Arc::new(Metrics::new());
        let running = Arc::new(AtomicBool::new(true));
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize)>>();

        let worker = {
            let metrics = metrics.clone();
            let running = running.clone();
            std::thread::Builder::new()
                .name("dsa-engine".to_string())
                .spawn(move || {
                    let mut backend = match factory() {
                        Ok(b) => b,
                        Err(e) => {
                            let _ = ready_tx.send(Err(e.context("creating backend")));
                            return;
                        }
                    };
                    if cfg.preload {
                        if let Err(e) = backend.preload(cfg.default_variant) {
                            let _ = ready_tx.send(Err(e.context("preload")));
                            return;
                        }
                        // Preload every router rung too: a mid-burst
                        // escalation must never fail (or stall) on lazy
                        // kernel instantiation.
                        if let Some(router) = &cfg.router {
                            for variant in router.variants() {
                                if let Err(e) = backend.preload(variant) {
                                    let _ = ready_tx.send(Err(e.context("preload router rung")));
                                    return;
                                }
                            }
                        }
                    }
                    crate::log_debug!(
                        "engine backend up: seq_len={} classes={} kernel_isa={}",
                        backend.seq_len(),
                        backend.classes(),
                        crate::kernels::simd::active_isa()
                    );
                    let _ = ready_tx.send(Ok((backend.seq_len(), backend.classes())));
                    worker_loop(backend.as_mut(), cfg, rx, metrics, running)
                })
                .context("spawning engine worker")?
        };
        let (seq_len, classes) = ready_rx
            .recv()
            .context("engine worker died during startup")??;

        Ok(Engine {
            tx,
            worker: Some(worker),
            next_id: AtomicU64::new(1),
            metrics,
            running,
            seq_len,
            classes,
        })
    }

    /// Start the hermetic native-kernel engine (no artifacts required).
    pub fn start_native(model: NativeModelConfig, cfg: EngineConfig) -> Result<Engine> {
        Engine::start_with(move || NativeBackend::boxed(model), cfg)
    }

    /// Start over AOT artifacts through PJRT (requires the `xla` feature).
    #[cfg(feature = "xla")]
    pub fn start(manifest: crate::runtime::Manifest, cfg: EngineConfig) -> Result<Engine> {
        Engine::start_with(
            move || super::backend::ArtifactBackend::boxed(manifest),
            cfg,
        )
    }

    /// Expected token-sequence length for requests.
    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Logits per response.
    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Submit a request; returns the channel delivering its response.
    /// The variant override is typed — protocol/CLI strings are parsed
    /// once at their boundary (`Variant::from_str`), so a bad name is
    /// rejected before it ever reaches the queue.
    pub fn submit(
        &self,
        tokens: Vec<i32>,
        variant: Option<Variant>,
    ) -> Result<Receiver<InferResponse>> {
        if tokens.len() != self.seq_len {
            bail!(
                "request length {} != model sequence length {}",
                tokens.len(),
                self.seq_len
            );
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = InferRequest::new(id, tokens);
        req.variant = variant;
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Request(req, rtx))
            .map_err(|_| crate::err!("engine stopped"))?;
        Ok(rrx)
    }

    /// Convenience: submit and block for the response.
    pub fn infer(&self, tokens: Vec<i32>, variant: Option<Variant>) -> Result<InferResponse> {
        let rx = self.submit(tokens, variant)?;
        rx.recv().context("engine dropped request")
    }

    /// Submit a session operation; returns the channel delivering the
    /// reply (`Err` inside = structured engine-side failure — unknown
    /// session, capacity, backend without decode support). Open prompts
    /// are length-checked here, mirroring [`Engine::submit`], so a
    /// malformed prompt never reaches the worker queue.
    pub fn submit_session(&self, op: SessionOp) -> Result<Receiver<Result<SessionReply>>> {
        if let SessionOp::Open { prompt, .. } = &op {
            if prompt.is_empty() || prompt.len() > self.seq_len {
                bail!(
                    "session prompt length {} out of range 1..={}",
                    prompt.len(),
                    self.seq_len
                );
            }
        }
        let (rtx, rrx) = mpsc::channel();
        let job = SessionJob {
            op,
            enqueued: Instant::now(),
            reply: rtx,
        };
        self.tx
            .send(Msg::Session(job))
            .map_err(|_| crate::err!("engine stopped"))?;
        Ok(rrx)
    }

    fn session_op(&self, op: SessionOp) -> Result<SessionReply> {
        let rx = self.submit_session(op)?;
        rx.recv().context("engine dropped session op")?
    }

    /// Open a decode session (blocking): prefill `prompt`, pin the
    /// variant (explicit, or the adaptive router's pick under the current
    /// load), and return `(session id, resident tokens, variant)`.
    pub fn open_session(
        &self,
        prompt: Vec<i32>,
        variant: Option<Variant>,
    ) -> Result<(u64, usize, Variant)> {
        match self.session_op(SessionOp::Open { prompt, variant })? {
            SessionReply::Opened { session, resident, variant } => {
                Ok((session, resident, variant))
            }
            other => bail!("engine returned mismatched session reply {other:?}"),
        }
    }

    /// Run one decode step on an open session (blocking).
    pub fn decode(&self, session: u64, token: i32) -> Result<DecodeResponse> {
        match self.session_op(SessionOp::Decode { session, token })? {
            SessionReply::Decoded(resp) => Ok(resp),
            other => bail!("engine returned mismatched session reply {other:?}"),
        }
    }

    /// Close a session (blocking), releasing its cache for pooled reuse;
    /// returns the token count that was resident.
    pub fn close_session(&self, session: u64) -> Result<usize> {
        match self.session_op(SessionOp::Close { session })? {
            SessionReply::Closed { released, .. } => Ok(released),
            other => bail!("engine returned mismatched session reply {other:?}"),
        }
    }

    pub fn shutdown(&mut self) {
        if self.running.swap(false, Ordering::SeqCst) {
            let _ = self.tx.send(Msg::Shutdown);
            if let Some(h) = self.worker.take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Worker-local decode-session bookkeeping: the LRU clock and the pinned
/// variant per live id (the backend owns the caches themselves).
#[derive(Default)]
struct SessionTable {
    /// id → (last-use tick, pinned variant).
    live: std::collections::HashMap<u64, (u64, Variant)>,
    tick: u64,
    next_id: u64,
}

/// Enqueue one inbound message; returns `false` on shutdown.
fn enqueue_msg(
    msg: Msg,
    batcher: &mut Batcher,
    waiters: &mut std::collections::HashMap<u64, Sender<InferResponse>>,
    metrics: &Metrics,
) -> bool {
    match msg {
        Msg::Request(req, rtx) => {
            let id = req.id;
            match batcher.push(req) {
                Ok(()) => {
                    waiters.insert(id, rtx);
                }
                Err(_rejected) => {
                    metrics.record_rejected(1);
                    drop(rtx); // receiver sees disconnect = rejection
                }
            }
            true
        }
        Msg::Session(job) => {
            if let Err(job) = batcher.push_session(job) {
                metrics.record_rejected(1);
                let _ = job
                    .reply
                    .send(Err(crate::err!("session queue full (backpressure)")));
            }
            true
        }
        Msg::Shutdown => false,
    }
}

fn worker_loop(
    backend: &mut dyn InferBackend,
    cfg: EngineConfig,
    rx: Receiver<Msg>,
    metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
) {
    let mut batcher = Batcher::new(cfg.policy.clone());
    let mut router = cfg.router.clone();
    let mut sessions = SessionTable::default();
    // Response channels parked by request id.
    let mut waiters: std::collections::HashMap<u64, Sender<InferResponse>> =
        std::collections::HashMap::new();
    // Warm per-batch buffers, reused across every batch this worker
    // executes: together with the backend's own batch buffers
    // (`ModelScratch`) and `forward_batch_into`, the steady-state loop
    // performs zero per-batch output allocations.
    let mut buffers = BatchBuffers::default();
    // Warm decode-logits buffer, same discipline per decode step.
    let mut dlogits: Vec<f32> = Vec::new();

    'outer: while running.load(Ordering::SeqCst) {
        // Sleep until the next deadline (or a message arrives).
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(msg) => {
                if !enqueue_msg(msg, &mut batcher, &mut waiters, &metrics) {
                    break;
                }
                // Drain whatever else is already queued without sleeping.
                let mut shutdown = false;
                while let Ok(msg) = rx.try_recv() {
                    if !enqueue_msg(msg, &mut batcher, &mut waiters, &metrics) {
                        shutdown = true;
                        break;
                    }
                }
                if shutdown {
                    break 'outer;
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }

        // Session lanes first: decode/close steps (a waiting stream's
        // inter-token latency) jump ahead of everything, then opens
        // (prefill-sized work), then one-shot batches.
        drain_sessions(
            backend, &cfg, &mut router, &mut batcher, &mut sessions, &metrics, &mut dlogits,
        );

        let now = Instant::now();
        while batcher.ready(now) {
            let batch = batcher.cut();
            if batch.is_empty() {
                break;
            }
            // Live load signal for the router: the backlog this batch
            // leaves behind across all lanes.
            let load = QueueLoad {
                prefill: batcher.len() + batcher.open_len(),
                decode: batcher.decode_len(),
            };
            execute_batch(
                backend, &cfg, &mut router, load, batch, &mut waiters, &metrics, &mut buffers,
            );
        }
    }

    // Flush any stragglers on shutdown (session lanes first, as above).
    drain_sessions(
        backend, &cfg, &mut router, &mut batcher, &mut sessions, &metrics, &mut dlogits,
    );
    while !batcher.is_empty() {
        let batch = batcher.cut();
        let load = QueueLoad {
            prefill: batcher.len(),
            decode: 0,
        };
        execute_batch(
            backend, &cfg, &mut router, load, batch, &mut waiters, &metrics, &mut buffers,
        );
    }
}

/// Drain both session lanes: every queued decode/close, then every queued
/// open.
#[allow(clippy::too_many_arguments)]
fn drain_sessions(
    backend: &mut dyn InferBackend,
    cfg: &EngineConfig,
    router: &mut Option<AdaptiveRouter>,
    batcher: &mut Batcher,
    sessions: &mut SessionTable,
    metrics: &Metrics,
    dlogits: &mut Vec<f32>,
) {
    while let Some(job) = batcher.next_decode() {
        let load = QueueLoad {
            prefill: batcher.len() + batcher.open_len(),
            decode: batcher.decode_len(),
        };
        handle_session_job(backend, cfg, router, load, job, sessions, metrics, dlogits);
    }
    while let Some(job) = batcher.next_open() {
        let load = QueueLoad {
            prefill: batcher.len() + batcher.open_len(),
            decode: batcher.decode_len(),
        };
        handle_session_job(backend, cfg, router, load, job, sessions, metrics, dlogits);
    }
}

/// Execute one session op against the backend, maintaining the LRU table
/// and the session metrics, and reply on the job's channel (errors travel
/// as the structured `Result`).
#[allow(clippy::too_many_arguments)]
fn handle_session_job(
    backend: &mut dyn InferBackend,
    cfg: &EngineConfig,
    router: &mut Option<AdaptiveRouter>,
    load: QueueLoad,
    job: SessionJob,
    table: &mut SessionTable,
    metrics: &Metrics,
    dlogits: &mut Vec<f32>,
) {
    let SessionJob { op, enqueued, reply } = job;
    let result = match op {
        SessionOp::Open { prompt, variant } => {
            // Explicit override wins; otherwise the adaptive router picks
            // the rung for the current load (recorded like any routing
            // decision) and the session is pinned to it for life — masks
            // must not shift mid-stream under a live cache.
            let variant = match variant {
                Some(v) => v,
                None => match router.as_mut() {
                    Some(r) => {
                        let v = r.select_load(load);
                        metrics.record_routed(v);
                        v
                    }
                    None => cfg.default_variant,
                },
            };
            // LRU-evict down to capacity before admitting the new
            // session: O(live) min-scan, fine at serving session counts.
            let max = cfg.sessions.max_sessions.max(1);
            while table.live.len() >= max {
                let lru = table
                    .live
                    .iter()
                    .min_by_key(|(_, (tick, _))| *tick)
                    .map(|(&id, _)| id)
                    .expect("capacity implies a non-empty table");
                table.live.remove(&lru);
                if let Err(e) = backend.close_session(lru) {
                    crate::log_error!("evicting session {lru}: {e}");
                }
                metrics.record_session_evicted();
            }
            table.next_id += 1;
            let id = table.next_id;
            match backend.open_session(id, variant, &prompt) {
                Ok(resident) => {
                    table.tick += 1;
                    table.live.insert(id, (table.tick, variant));
                    metrics.record_session_opened();
                    Ok(SessionReply::Opened { session: id, resident, variant })
                }
                Err(e) => Err(e),
            }
        }
        SessionOp::Decode { session, token } => {
            match backend.decode_into(session, token, dlogits) {
                Ok(resident) => {
                    table.tick += 1;
                    let variant = match table.live.get_mut(&session) {
                        Some(slot) => {
                            slot.0 = table.tick;
                            slot.1
                        }
                        // Backend accepted it, so the table must know it;
                        // fall back rather than panic the worker.
                        None => cfg.default_variant,
                    };
                    let latency = enqueued.elapsed();
                    metrics.record_decode(variant, latency.as_secs_f64());
                    let logits = dlogits.clone();
                    Ok(SessionReply::Decoded(DecodeResponse {
                        session,
                        pred: InferResponse::argmax(&logits),
                        logits,
                        resident,
                        latency,
                        variant,
                    }))
                }
                Err(e) => Err(e),
            }
        }
        SessionOp::Close { session } => match backend.close_session(session) {
            Ok(released) => {
                table.live.remove(&session);
                metrics.record_session_closed();
                Ok(SessionReply::Closed { session, released })
            }
            Err(e) => Err(e),
        },
    };
    // Refresh gauges before replying: a client that reads its reply and
    // immediately queries metrics must see its own session reflected.
    metrics.set_session_gauges(
        backend.session_count(),
        backend.resident_tokens(),
        backend.cache_grows(),
    );
    let _ = reply.send(result);
}

/// Worker-owned buffers reused across batches (padded token input and
/// backend logits output). They grow to the largest bucket seen and stay
/// warm: the steady-state per-batch path allocates neither.
#[derive(Default)]
struct BatchBuffers {
    tokens: Vec<i32>,
    logits: Vec<f32>,
    lat_pairs: Vec<(f64, f64)>,
}

#[allow(clippy::too_many_arguments)]
fn execute_batch(
    backend: &mut dyn InferBackend,
    cfg: &EngineConfig,
    router: &mut Option<AdaptiveRouter>,
    load: QueueLoad,
    batch: Vec<InferRequest>,
    waiters: &mut std::collections::HashMap<u64, Sender<InferResponse>>,
    metrics: &Metrics,
    buffers: &mut BatchBuffers,
) {
    // Explicit per-request variant overrides always win; otherwise the
    // adaptive router (when configured) picks the rung for the current
    // two-lane load (prefill backlog + discounted decode backlog), and
    // the decision is recorded before the batch runs.
    let variant = match batch[0].variant {
        Some(v) => v,
        None => match router.as_mut() {
            Some(r) => {
                let v = r.select_load(load);
                metrics.record_routed(v);
                v
            }
            None => cfg.default_variant,
        },
    };
    let n = batch.len();
    let bucket = backend.bucket_for(n);
    let classes = backend.classes();

    // Pad to the bucket with the first request's tokens, into the warm
    // worker-owned buffer.
    let tokens = &mut buffers.tokens;
    tokens.clear();
    for r in &batch {
        tokens.extend_from_slice(&r.tokens);
    }
    for _ in n..bucket {
        tokens.extend_from_slice(&batch[0].tokens);
    }

    let exec_start = Instant::now();
    let logits = &mut buffers.logits;
    if let Err(e) = backend.run_into(variant, tokens, bucket, logits) {
        crate::log_error!("executing variant={variant} bucket={bucket}: {e}");
        for r in &batch {
            waiters.remove(&r.id);
        }
        return;
    }
    debug_assert_eq!(logits.len(), bucket * classes);

    let done = Instant::now();
    let mut responses = Vec::with_capacity(n);
    let lat_pairs = &mut buffers.lat_pairs;
    lat_pairs.clear();
    for (i, r) in batch.iter().enumerate() {
        let l = logits[i * classes..(i + 1) * classes].to_vec();
        let resp = InferResponse {
            id: r.id,
            pred: InferResponse::argmax(&l),
            logits: l,
            latency: done.duration_since(r.enqueued),
            queue_time: exec_start.duration_since(r.enqueued),
            batch_size: n,
            bucket,
            variant,
        };
        lat_pairs.push((
            resp.latency.as_secs_f64(),
            resp.queue_time.as_secs_f64(),
        ));
        responses.push(resp);
    }
    // Record metrics BEFORE waking waiters: a client that reads its reply
    // and immediately queries /metrics must see its own request counted.
    metrics.record_batch(variant, n, lat_pairs);
    // Pool counters ride along when the native kernels have started the
    // global pool; a PJRT-only serving path must not spawn one just to
    // report zeros.
    if let Some(stats) = crate::kernels::pool::WorkerPool::try_global_stats() {
        metrics.record_pool(stats);
    }
    for resp in responses {
        if let Some(tx) = waiters.remove(&resp.id) {
            let _ = tx.send(resp);
        }
    }
}
