//! Serving engine: the L3 hot path.
//!
//! A submission channel feeds a single worker thread driving the
//! [`Batcher`]: it sleeps until the head-of-line deadline or a full batch,
//! cuts a batch of same-variant requests, pads it to the backend's
//! execution bucket, runs the batch through an
//! [`InferBackend`](super::backend::InferBackend) as **one** backend
//! dispatch — the native backend hands the whole bucket to the batched
//! multi-head kernels, which parallelize over `(sequence, row-range)`
//! work items on the process-wide persistent worker pool
//! (`kernels::pool`) — and fans responses back through per-request
//! channels. With [`EngineConfig::router`] set, the worker also picks the
//! serving variant per batch from the live queue depth (dense under light
//! load, sparser DSA rungs as backlog grows), recording every decision
//! plus the pool counters in [`Metrics`].
//!
//! The backend is constructed **inside** the worker thread from a factory
//! closure: the PJRT artifact backend's handles are thread-local and must
//! never cross threads, and the native backend simply doesn't care.
//! Startup errors (bad artifacts, compile failures, unknown variants
//! during preload) are reported synchronously through a channel.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::backend::{InferBackend, NativeBackend, NativeModelConfig};
use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::request::{InferRequest, InferResponse};
use super::router::AdaptiveRouter;
use crate::kernels::Variant;
use crate::util::error::{bail, Context, Result};

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Typed serving variant for batches without an override — parse CLI
    /// or config strings once via `Variant::from_str` before building
    /// this (an unknown variant can then never reach the worker loop).
    pub default_variant: Variant,
    pub policy: BatchPolicy,
    /// Eagerly warm up the default variant at startup.
    pub preload: bool,
    /// Adaptive variant routing: batches of requests **without** an
    /// explicit variant override are routed by live queue depth (the
    /// backlog left after the batch is cut) instead of always serving
    /// `default_variant`. Every rung is preloaded at startup and every
    /// decision is recorded in [`Metrics`]. `None` = fixed default.
    pub router: Option<AdaptiveRouter>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            default_variant: Variant::Dsa { pct: 90 },
            policy: BatchPolicy::default(),
            preload: true,
            router: None,
        }
    }
}

enum Msg {
    Request(InferRequest, Sender<InferResponse>),
    Shutdown,
}

/// Handle to a running engine.
pub struct Engine {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
    seq_len: usize,
    classes: usize,
}

impl Engine {
    /// Start the engine over a backend factory that runs on the worker
    /// thread (see the module docs for why).
    pub fn start_with<F>(factory: F, cfg: EngineConfig) -> Result<Engine>
    where
        F: FnOnce() -> Result<Box<dyn InferBackend>> + Send + 'static,
    {
        let metrics = Arc::new(Metrics::new());
        let running = Arc::new(AtomicBool::new(true));
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize)>>();

        let worker = {
            let metrics = metrics.clone();
            let running = running.clone();
            std::thread::Builder::new()
                .name("dsa-engine".to_string())
                .spawn(move || {
                    let mut backend = match factory() {
                        Ok(b) => b,
                        Err(e) => {
                            let _ = ready_tx.send(Err(e.context("creating backend")));
                            return;
                        }
                    };
                    if cfg.preload {
                        if let Err(e) = backend.preload(cfg.default_variant) {
                            let _ = ready_tx.send(Err(e.context("preload")));
                            return;
                        }
                        // Preload every router rung too: a mid-burst
                        // escalation must never fail (or stall) on lazy
                        // kernel instantiation.
                        if let Some(router) = &cfg.router {
                            for variant in router.variants() {
                                if let Err(e) = backend.preload(variant) {
                                    let _ = ready_tx.send(Err(e.context("preload router rung")));
                                    return;
                                }
                            }
                        }
                    }
                    crate::log_debug!(
                        "engine backend up: seq_len={} classes={} kernel_isa={}",
                        backend.seq_len(),
                        backend.classes(),
                        crate::kernels::simd::active_isa()
                    );
                    let _ = ready_tx.send(Ok((backend.seq_len(), backend.classes())));
                    worker_loop(backend.as_mut(), cfg, rx, metrics, running)
                })
                .context("spawning engine worker")?
        };
        let (seq_len, classes) = ready_rx
            .recv()
            .context("engine worker died during startup")??;

        Ok(Engine {
            tx,
            worker: Some(worker),
            next_id: AtomicU64::new(1),
            metrics,
            running,
            seq_len,
            classes,
        })
    }

    /// Start the hermetic native-kernel engine (no artifacts required).
    pub fn start_native(model: NativeModelConfig, cfg: EngineConfig) -> Result<Engine> {
        Engine::start_with(move || NativeBackend::boxed(model), cfg)
    }

    /// Start over AOT artifacts through PJRT (requires the `xla` feature).
    #[cfg(feature = "xla")]
    pub fn start(manifest: crate::runtime::Manifest, cfg: EngineConfig) -> Result<Engine> {
        Engine::start_with(
            move || super::backend::ArtifactBackend::boxed(manifest),
            cfg,
        )
    }

    /// Expected token-sequence length for requests.
    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Logits per response.
    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Submit a request; returns the channel delivering its response.
    /// The variant override is typed — protocol/CLI strings are parsed
    /// once at their boundary (`Variant::from_str`), so a bad name is
    /// rejected before it ever reaches the queue.
    pub fn submit(
        &self,
        tokens: Vec<i32>,
        variant: Option<Variant>,
    ) -> Result<Receiver<InferResponse>> {
        if tokens.len() != self.seq_len {
            bail!(
                "request length {} != model sequence length {}",
                tokens.len(),
                self.seq_len
            );
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = InferRequest::new(id, tokens);
        req.variant = variant;
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Request(req, rtx))
            .map_err(|_| crate::err!("engine stopped"))?;
        Ok(rrx)
    }

    /// Convenience: submit and block for the response.
    pub fn infer(&self, tokens: Vec<i32>, variant: Option<Variant>) -> Result<InferResponse> {
        let rx = self.submit(tokens, variant)?;
        rx.recv().context("engine dropped request")
    }

    pub fn shutdown(&mut self) {
        if self.running.swap(false, Ordering::SeqCst) {
            let _ = self.tx.send(Msg::Shutdown);
            if let Some(h) = self.worker.take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(
    backend: &mut dyn InferBackend,
    cfg: EngineConfig,
    rx: Receiver<Msg>,
    metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
) {
    let mut batcher = Batcher::new(cfg.policy.clone());
    let mut router = cfg.router.clone();
    // Response channels parked by request id.
    let mut waiters: std::collections::HashMap<u64, Sender<InferResponse>> =
        std::collections::HashMap::new();
    // Warm per-batch buffers, reused across every batch this worker
    // executes: together with the backend's own batch buffers
    // (`ModelScratch`) and `forward_batch_into`, the steady-state loop
    // performs zero per-batch output allocations.
    let mut buffers = BatchBuffers::default();

    'outer: while running.load(Ordering::SeqCst) {
        // Sleep until the next deadline (or a message arrives).
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Request(req, rtx)) => {
                let id = req.id;
                match batcher.push(req) {
                    Ok(()) => {
                        waiters.insert(id, rtx);
                    }
                    Err(_rejected) => {
                        metrics.record_rejected(1);
                        drop(rtx); // receiver sees disconnect = rejection
                    }
                }
                // Drain whatever else is already queued without sleeping.
                while let Ok(msg) = rx.try_recv() {
                    match msg {
                        Msg::Request(req, rtx) => {
                            let id = req.id;
                            match batcher.push(req) {
                                Ok(()) => {
                                    waiters.insert(id, rtx);
                                }
                                Err(_) => metrics.record_rejected(1),
                            }
                        }
                        Msg::Shutdown => break 'outer,
                    }
                }
            }
            Ok(Msg::Shutdown) => break,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }

        let now = Instant::now();
        while batcher.ready(now) {
            let batch = batcher.cut();
            if batch.is_empty() {
                break;
            }
            // Live load signal for the router: the backlog this batch
            // leaves behind in the queue.
            let depth = batcher.len();
            execute_batch(
                backend, &cfg, &mut router, depth, batch, &mut waiters, &metrics, &mut buffers,
            );
        }
    }

    // Flush any stragglers on shutdown.
    while !batcher.is_empty() {
        let batch = batcher.cut();
        let depth = batcher.len();
        execute_batch(
            backend, &cfg, &mut router, depth, batch, &mut waiters, &metrics, &mut buffers,
        );
    }
}

/// Worker-owned buffers reused across batches (padded token input and
/// backend logits output). They grow to the largest bucket seen and stay
/// warm: the steady-state per-batch path allocates neither.
#[derive(Default)]
struct BatchBuffers {
    tokens: Vec<i32>,
    logits: Vec<f32>,
    lat_pairs: Vec<(f64, f64)>,
}

#[allow(clippy::too_many_arguments)]
fn execute_batch(
    backend: &mut dyn InferBackend,
    cfg: &EngineConfig,
    router: &mut Option<AdaptiveRouter>,
    queue_depth: usize,
    batch: Vec<InferRequest>,
    waiters: &mut std::collections::HashMap<u64, Sender<InferResponse>>,
    metrics: &Metrics,
    buffers: &mut BatchBuffers,
) {
    // Explicit per-request variant overrides always win; otherwise the
    // adaptive router (when configured) picks the rung for the current
    // load, and the decision is recorded before the batch runs.
    let variant = match batch[0].variant {
        Some(v) => v,
        None => match router.as_mut() {
            Some(r) => {
                let v = r.select(queue_depth);
                metrics.record_routed(v);
                v
            }
            None => cfg.default_variant,
        },
    };
    let n = batch.len();
    let bucket = backend.bucket_for(n);
    let classes = backend.classes();

    // Pad to the bucket with the first request's tokens, into the warm
    // worker-owned buffer.
    let tokens = &mut buffers.tokens;
    tokens.clear();
    for r in &batch {
        tokens.extend_from_slice(&r.tokens);
    }
    for _ in n..bucket {
        tokens.extend_from_slice(&batch[0].tokens);
    }

    let exec_start = Instant::now();
    let logits = &mut buffers.logits;
    if let Err(e) = backend.run_into(variant, tokens, bucket, logits) {
        crate::log_error!("executing variant={variant} bucket={bucket}: {e}");
        for r in &batch {
            waiters.remove(&r.id);
        }
        return;
    }
    debug_assert_eq!(logits.len(), bucket * classes);

    let done = Instant::now();
    let mut responses = Vec::with_capacity(n);
    let lat_pairs = &mut buffers.lat_pairs;
    lat_pairs.clear();
    for (i, r) in batch.iter().enumerate() {
        let l = logits[i * classes..(i + 1) * classes].to_vec();
        let resp = InferResponse {
            id: r.id,
            pred: InferResponse::argmax(&l),
            logits: l,
            latency: done.duration_since(r.enqueued),
            queue_time: exec_start.duration_since(r.enqueued),
            batch_size: n,
            bucket,
            variant,
        };
        lat_pairs.push((
            resp.latency.as_secs_f64(),
            resp.queue_time.as_secs_f64(),
        ));
        responses.push(resp);
    }
    // Record metrics BEFORE waking waiters: a client that reads its reply
    // and immediately queries /metrics must see its own request counted.
    metrics.record_batch(variant, n, lat_pairs);
    // Pool counters ride along when the native kernels have started the
    // global pool; a PJRT-only serving path must not spawn one just to
    // report zeros.
    if let Some(stats) = crate::kernels::pool::WorkerPool::try_global_stats() {
        metrics.record_pool(stats);
    }
    for resp in responses {
        if let Some(tx) = waiters.remove(&resp.id) {
            let _ = tx.send(resp);
        }
    }
}
