//! Serving engine: the L3 hot path.
//!
//! A submission channel feeds a single worker thread driving the
//! [`Batcher`]: it sleeps until the head-of-line deadline or a full batch,
//! cuts a batch of same-variant requests, pads it to the backend's
//! execution bucket, runs the batch through an
//! [`InferBackend`](super::backend::InferBackend) as **one** backend
//! dispatch — the native backend hands the whole bucket to the batched
//! multi-head kernels, which parallelize over `(sequence, row-range)`
//! work items on the process-wide persistent worker pool
//! (`kernels::pool`) — and fans responses back through per-request
//! channels. With [`EngineConfig::router`] set, the worker also picks the
//! serving variant per batch from the live queue depth (dense under light
//! load, sparser DSA rungs as backlog grows), recording every decision
//! plus the pool counters in [`Metrics`].
//!
//! The backend is constructed **inside** the worker thread from a factory
//! closure: the PJRT artifact backend's handles are thread-local and must
//! never cross threads, and the native backend simply doesn't care.
//! Startup errors (bad artifacts, compile failures, unknown variants
//! during preload) are reported synchronously through a channel.
//!
//! **Overload safety.** Every admission outcome is a typed
//! [`ServeError`](super::error::ServeError) delivered on the request's
//! own reply channel, so a submitted request always gets exactly one
//! structured answer: `Overloaded` past `queue_cap` (with a
//! backlog-proportional retry hint), `Expired` when its deadline lapses
//! in queue, `ShuttingDown` once admissions stop, and `Failed` when the
//! backend errors *or panics* — batch and session execution run behind a
//! `catch_unwind` blast shield, so an injected (or real) backend panic
//! answers its waiters and the worker lives on. [`Engine::shutdown`]
//! drains: admissions stop, racing submissions are adopted, both session
//! lanes and the one-shot queue flush, then the worker exits — zero
//! in-flight work is dropped.
//!
//! **Replication hooks.** The worker publishes a monotone heartbeat tick
//! ([`Engine::tick`]) every loop iteration and [`Engine::alive`] reports
//! whether it still runs, so a [`ReplicaSet`](super::replica::ReplicaSet)
//! supervisor can distinguish healthy / crashed / wedged replicas; the
//! chaos entry points [`Engine::inject_crash`] (exit without draining —
//! reply channels drop like a panic escaping the shield) and
//! [`Engine::inject_wedge`] (stop heartbeating until torn down) simulate
//! exactly the failures the supervisor exists to catch.

use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::backend::{InferBackend, NativeBackend, NativeModelConfig};
use super::batcher::{BatchPolicy, Batcher, SessionJob};
use super::error::{ServeError, ServeResult};
use super::metrics::Metrics;
use super::request::{DecodeResponse, InferRequest, InferResponse, SessionOp, SessionReply};
use super::router::{AdaptiveRouter, QueueLoad};
use crate::kernels::Variant;
use crate::util::error::{err, Context, Result};
use crate::util::sync::lock_recover;

/// Capacity bound on live decode sessions.
#[derive(Debug, Clone)]
pub struct SessionPolicy {
    /// Hard cap on concurrently open sessions: opening one more evicts
    /// the least-recently-used session (its cache returns to the pool and
    /// the eviction is counted in [`Metrics`]; later ops on the evicted
    /// id get a structured "unknown session" error).
    pub max_sessions: usize,
}

impl Default for SessionPolicy {
    fn default() -> Self {
        SessionPolicy { max_sessions: 64 }
    }
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Typed serving variant for batches without an override — parse CLI
    /// or config strings once via `Variant::from_str` before building
    /// this (an unknown variant can then never reach the worker loop).
    pub default_variant: Variant,
    pub policy: BatchPolicy,
    /// Eagerly warm up the default variant at startup.
    pub preload: bool,
    /// Adaptive variant routing: batches of requests **without** an
    /// explicit variant override are routed by live queue depth (the
    /// backlog left after the batch is cut) instead of always serving
    /// `default_variant`. Every rung is preloaded at startup and every
    /// decision is recorded in [`Metrics`]. `None` = fixed default.
    pub router: Option<AdaptiveRouter>,
    /// Decode-session capacity (LRU eviction past the cap).
    pub sessions: SessionPolicy,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            default_variant: Variant::Dsa { pct: 90 },
            policy: BatchPolicy::default(),
            preload: true,
            router: None,
            sessions: SessionPolicy::default(),
        }
    }
}

enum Msg {
    Request(InferRequest, Sender<ServeResult<InferResponse>>),
    Session(SessionJob),
    Shutdown,
    /// Chaos: die on receipt *without* draining — parked waiters' reply
    /// channels drop, exactly like a panic escaping the blast shield.
    Die,
    /// Chaos: stop heartbeating (and serving) but stay joinable — the
    /// wedged worker idles until `running` flips, so a supervisor can
    /// still tear it down with [`Engine::shutdown`].
    Wedge,
}

/// What the worker loop should do after absorbing one inbound message.
enum Step {
    Continue,
    /// Drain both lanes, answer every waiter, then exit (clean shutdown).
    Drain,
    /// Exit immediately without draining (simulated crash).
    Crash,
    /// Stop heartbeating and idle until torn down (simulated wedge).
    Wedge,
}

/// Handle to a running engine.
pub struct Engine {
    tx: Sender<Msg>,
    /// Behind a mutex so [`Engine::shutdown`] takes `&self` (the server
    /// shares the engine as `Arc<Engine>` across connection threads).
    worker: Mutex<Option<JoinHandle<()>>>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
    /// Admission gate: once false, `submit`/`submit_session` answer
    /// `ShuttingDown` instead of enqueueing (the drain phase of
    /// shutdown).
    accepting: AtomicBool,
    /// Monotone tick the worker bumps every loop iteration; a supervisor
    /// watchdog reads it to distinguish "busy" from "wedged".
    heartbeat: Arc<AtomicU64>,
    seq_len: usize,
    classes: usize,
}

impl Engine {
    /// Start the engine over a backend factory that runs on the worker
    /// thread (see the module docs for why).
    pub fn start_with<F>(factory: F, cfg: EngineConfig) -> Result<Engine>
    where
        F: FnOnce() -> Result<Box<dyn InferBackend>> + Send + 'static,
    {
        let metrics = Arc::new(Metrics::new());
        let running = Arc::new(AtomicBool::new(true));
        let heartbeat = Arc::new(AtomicU64::new(0));
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize)>>();

        let worker = {
            let metrics = metrics.clone();
            let running = running.clone();
            let heartbeat = heartbeat.clone();
            std::thread::Builder::new()
                .name("dsa-engine".to_string())
                .spawn(move || {
                    let mut backend = match factory() {
                        Ok(b) => b,
                        Err(e) => {
                            let _ = ready_tx.send(Err(e.context("creating backend")));
                            return;
                        }
                    };
                    if cfg.preload {
                        if let Err(e) = backend.preload(cfg.default_variant) {
                            let _ = ready_tx.send(Err(e.context("preload")));
                            return;
                        }
                        // Preload every router rung too: a mid-burst
                        // escalation must never fail (or stall) on lazy
                        // kernel instantiation.
                        if let Some(router) = &cfg.router {
                            for variant in router.variants() {
                                if let Err(e) = backend.preload(variant) {
                                    let _ = ready_tx.send(Err(e.context("preload router rung")));
                                    return;
                                }
                            }
                        }
                    }
                    crate::log_debug!(
                        "engine backend up: seq_len={} classes={} kernel_isa={}",
                        backend.seq_len(),
                        backend.classes(),
                        crate::kernels::simd::active_isa()
                    );
                    let _ = ready_tx.send(Ok((backend.seq_len(), backend.classes())));
                    worker_loop(backend.as_mut(), cfg, rx, metrics, running, heartbeat)
                })
                .context("spawning engine worker")?
        };
        let (seq_len, classes) = ready_rx
            .recv()
            .context("engine worker died during startup")??;

        Ok(Engine {
            tx,
            worker: Mutex::new(Some(worker)),
            next_id: AtomicU64::new(1),
            metrics,
            running,
            accepting: AtomicBool::new(true),
            heartbeat,
            seq_len,
            classes,
        })
    }

    /// Start the hermetic native-kernel engine (no artifacts required).
    pub fn start_native(model: NativeModelConfig, cfg: EngineConfig) -> Result<Engine> {
        Engine::start_with(move || NativeBackend::boxed(model), cfg)
    }

    /// Start over AOT artifacts through PJRT (requires the `xla` feature).
    #[cfg(feature = "xla")]
    pub fn start(manifest: crate::runtime::Manifest, cfg: EngineConfig) -> Result<Engine> {
        Engine::start_with(
            move || super::backend::ArtifactBackend::boxed(manifest),
            cfg,
        )
    }

    /// Expected token-sequence length for requests.
    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Logits per response.
    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Submit a request; returns the channel delivering its typed
    /// outcome — `Ok(response)`, or a structured [`ServeError`]
    /// (`Overloaded` / `Expired` / `Failed` / `ShuttingDown`), so every
    /// admitted submission gets exactly one reply. The variant override
    /// is typed — protocol/CLI strings are parsed once at their boundary
    /// (`Variant::from_str`), so a bad name is rejected before it ever
    /// reaches the queue. `deadline` is the client's budget; `None`
    /// falls back to the policy's `default_deadline` at enqueue.
    pub fn submit(
        &self,
        tokens: Vec<i32>,
        variant: Option<Variant>,
        deadline: Option<Duration>,
    ) -> ServeResult<Receiver<ServeResult<InferResponse>>> {
        if !self.accepting.load(Ordering::SeqCst) {
            return Err(ServeError::ShuttingDown);
        }
        if tokens.len() != self.seq_len {
            return Err(ServeError::Invalid(format!(
                "request length {} != model sequence length {}",
                tokens.len(),
                self.seq_len
            )));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = InferRequest::new(id, tokens);
        req.variant = variant;
        if let Some(budget) = deadline {
            req = req.with_deadline(budget);
        }
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Request(req, rtx))
            .map_err(|_| ServeError::ShuttingDown)?;
        Ok(rrx)
    }

    /// Convenience: submit (no explicit deadline) and block for the
    /// typed outcome.
    pub fn infer(&self, tokens: Vec<i32>, variant: Option<Variant>) -> ServeResult<InferResponse> {
        let rx = self.submit(tokens, variant, None)?;
        match rx.recv() {
            Ok(outcome) => outcome,
            // The worker drained away while we waited — admitted work is
            // always answered, so this only means shutdown raced us.
            Err(_) => Err(ServeError::ShuttingDown),
        }
    }

    /// Submit a session operation; returns the channel delivering the
    /// typed reply (`Err` inside = structured [`ServeError`] — overload,
    /// expiry, or an engine-side failure such as unknown session /
    /// capacity / backend without decode support). Open prompts are
    /// length-checked here, mirroring [`Engine::submit`], so a malformed
    /// prompt never reaches the worker queue.
    pub fn submit_session(
        &self,
        op: SessionOp,
        deadline: Option<Duration>,
    ) -> ServeResult<Receiver<ServeResult<SessionReply>>> {
        if !self.accepting.load(Ordering::SeqCst) {
            return Err(ServeError::ShuttingDown);
        }
        match &op {
            SessionOp::Open { prompt, .. } => {
                if prompt.is_empty() || prompt.len() > self.seq_len {
                    return Err(ServeError::Invalid(format!(
                        "session prompt length {} out of range 1..={}",
                        prompt.len(),
                        self.seq_len
                    )));
                }
            }
            SessionOp::Reopen { prompt, decoded, .. } => {
                let total = prompt.len() + decoded.len();
                if prompt.is_empty() || total > self.seq_len {
                    return Err(ServeError::Invalid(format!(
                        "session replay length {total} out of range 1..={}",
                        self.seq_len
                    )));
                }
            }
            _ => {}
        }
        let (rtx, rrx) = mpsc::channel();
        let enqueued = Instant::now();
        let job = SessionJob {
            op,
            enqueued,
            deadline: deadline.map(|budget| enqueued + budget),
            reply: rtx,
        };
        self.tx
            .send(Msg::Session(job))
            .map_err(|_| ServeError::ShuttingDown)?;
        Ok(rrx)
    }

    fn session_op(&self, op: SessionOp) -> ServeResult<SessionReply> {
        let rx = self.submit_session(op, None)?;
        match rx.recv() {
            Ok(outcome) => outcome,
            Err(_) => Err(ServeError::ShuttingDown),
        }
    }

    /// Open a decode session (blocking): prefill `prompt`, pin the
    /// variant (explicit, or the adaptive router's pick under the current
    /// load), and return `(session id, resident tokens, variant)`.
    pub fn open_session(
        &self,
        prompt: Vec<i32>,
        variant: Option<Variant>,
    ) -> ServeResult<(u64, usize, Variant)> {
        match self.session_op(SessionOp::Open { prompt, variant })? {
            SessionReply::Opened { session, resident, variant } => {
                Ok((session, resident, variant))
            }
            other => Err(ServeError::Failed(err!(
                "engine returned mismatched session reply {other:?}"
            ))),
        }
    }

    /// Run one decode step on an open session (blocking).
    pub fn decode(&self, session: u64, token: i32) -> ServeResult<DecodeResponse> {
        match self.session_op(SessionOp::Decode { session, token })? {
            SessionReply::Decoded(resp) => Ok(resp),
            other => Err(ServeError::Failed(err!(
                "engine returned mismatched session reply {other:?}"
            ))),
        }
    }

    /// Close a session (blocking), releasing its cache for pooled reuse;
    /// returns the token count that was resident.
    pub fn close_session(&self, session: u64) -> ServeResult<usize> {
        match self.session_op(SessionOp::Close { session })? {
            SessionReply::Closed { released, .. } => Ok(released),
            other => Err(ServeError::Failed(err!(
                "engine returned mismatched session reply {other:?}"
            ))),
        }
    }

    /// Monotone heartbeat tick: the worker bumps it every loop iteration
    /// (at least every ~50ms when healthy, even idle). A watchdog that
    /// sees the tick frozen past its interval may conclude the worker is
    /// wedged — size the interval above the worst-case batch latency.
    pub fn tick(&self) -> u64 {
        self.heartbeat.load(Ordering::SeqCst)
    }

    /// Whether the worker thread is still running. `false` after a clean
    /// shutdown — or after a crash: a worker that died without draining
    /// reads as dead here while its clients' reply channels read as
    /// disconnected.
    pub fn alive(&self) -> bool {
        lock_recover(&self.worker)
            .as_ref()
            .map(|h| !h.is_finished())
            .unwrap_or(false)
    }

    /// Chaos: make the worker exit on receipt *without* draining, as if a
    /// panic escaped the blast shield — every parked waiter's reply
    /// channel drops. The supervisor (or a test) observes [`Engine::alive`]
    /// flip false and respawns.
    pub fn inject_crash(&self) {
        let _ = self.tx.send(Msg::Die);
    }

    /// Chaos: make the worker stop heartbeating (and serving) while
    /// staying joinable — the watchdog path. [`Engine::shutdown`] still
    /// tears a wedged worker down promptly.
    pub fn inject_wedge(&self) {
        let _ = self.tx.send(Msg::Wedge);
    }

    /// Stop admitting new work without stopping the worker: subsequent
    /// `submit`/`submit_session` calls answer `ShuttingDown` while
    /// already-admitted work keeps executing. First phase of drain.
    pub fn stop_admissions(&self) {
        self.accepting.store(false, Ordering::SeqCst);
    }

    /// Whether the engine still admits new work.
    pub fn accepting(&self) -> bool {
        self.accepting.load(Ordering::SeqCst)
    }

    /// Drain-then-stop: stop admissions, tell the worker to finish, and
    /// join it. The worker adopts any submission that raced the shutdown
    /// message, flushes both session lanes and every queued batch (each
    /// waiter gets its reply), then exits. Idempotent and `&self`, so
    /// any thread holding the shared `Arc<Engine>` may initiate it.
    pub fn shutdown(&self) {
        self.stop_admissions();
        if self.running.swap(false, Ordering::SeqCst) {
            let _ = self.tx.send(Msg::Shutdown);
        }
        // Outside the `running` guard: if two threads race, the loser
        // still waits for the worker to finish draining.
        if let Some(h) = lock_recover(&self.worker).take() {
            let _ = h.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Worker-local decode-session bookkeeping: the LRU clock and the pinned
/// variant per live id (the backend owns the caches themselves).
#[derive(Default)]
struct SessionTable {
    /// id → (last-use tick, pinned variant).
    live: std::collections::HashMap<u64, (u64, Variant)>,
    tick: u64,
    next_id: u64,
}

/// Enqueue one inbound message; the returned [`Step`] tells the worker
/// loop whether to keep going, drain, crash, or wedge. Requests without a
/// deadline inherit the policy default here (enqueue time is when the
/// budget starts). A submission past `queue_cap` is answered with a typed
/// `Overloaded` carrying the batcher's backlog-proportional retry hint —
/// never a silently dropped channel.
fn enqueue_msg(
    msg: Msg,
    batcher: &mut Batcher,
    waiters: &mut std::collections::HashMap<u64, Sender<ServeResult<InferResponse>>>,
    metrics: &Metrics,
) -> Step {
    let retry_after_ms = |b: &Batcher| b.retry_after().as_millis() as u64;
    match msg {
        Msg::Request(mut req, rtx) => {
            let id = req.id;
            if req.deadline.is_none() {
                if let Some(budget) = batcher.policy.default_deadline {
                    req.deadline = Some(req.enqueued + budget);
                }
            }
            match batcher.push(req) {
                Ok(()) => {
                    waiters.insert(id, rtx);
                }
                Err(_rejected) => {
                    metrics.record_rejected(1);
                    let _ = rtx.send(Err(ServeError::Overloaded {
                        retry_after_ms: retry_after_ms(batcher),
                    }));
                }
            }
            Step::Continue
        }
        Msg::Session(mut job) => {
            if job.deadline.is_none() {
                if let Some(budget) = batcher.policy.default_deadline {
                    job.deadline = Some(job.enqueued + budget);
                }
            }
            if let Err(job) = batcher.push_session(job) {
                metrics.record_rejected(1);
                let _ = job.reply.send(Err(ServeError::Overloaded {
                    retry_after_ms: retry_after_ms(batcher),
                }));
            }
            Step::Continue
        }
        Msg::Shutdown => Step::Drain,
        Msg::Die => Step::Crash,
        Msg::Wedge => Step::Wedge,
    }
}

/// Shed every expired queued request, answering each with a structured
/// `Expired` reply and counting it under the variant it would have run
/// as.
fn shed_expired(
    batcher: &mut Batcher,
    waiters: &mut std::collections::HashMap<u64, Sender<ServeResult<InferResponse>>>,
    metrics: &Metrics,
    default_variant: Variant,
    now: Instant,
) {
    for req in batcher.shed_expired(now) {
        let variant = req.variant.unwrap_or(default_variant);
        metrics.record_expired(variant, 1);
        if let Some(tx) = waiters.remove(&req.id) {
            let waited_ms = now.duration_since(req.enqueued).as_millis() as u64;
            let _ = tx.send(Err(ServeError::Expired { waited_ms }));
        }
    }
}

/// Render a caught panic payload as a message (panics carry `&str` or
/// `String` in practice; anything else gets a generic label).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// Idle without heartbeating until `running` flips false: the simulated
/// wedge. Parked waiters stay parked (their senders live in this worker's
/// stack), exactly like a worker stuck in a hung syscall — until the
/// supervisor's teardown flips `running`, joins us, and the stack unwinds
/// dropping every reply channel.
fn wedge_idle(running: &AtomicBool) {
    while running.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn worker_loop(
    backend: &mut dyn InferBackend,
    cfg: EngineConfig,
    rx: Receiver<Msg>,
    metrics: Arc<Metrics>,
    running: Arc<AtomicBool>,
    heartbeat: Arc<AtomicU64>,
) {
    let mut batcher = Batcher::new(cfg.policy.clone());
    let mut router = cfg.router.clone();
    let mut sessions = SessionTable::default();
    // Response channels parked by request id.
    let mut waiters: std::collections::HashMap<u64, Sender<ServeResult<InferResponse>>> =
        std::collections::HashMap::new();
    // Warm per-batch buffers, reused across every batch this worker
    // executes: together with the backend's own batch buffers
    // (`ModelScratch`) and `forward_batch_into`, the steady-state loop
    // performs zero per-batch output allocations.
    let mut buffers = BatchBuffers::default();
    // Warm decode-logits buffer, same discipline per decode step.
    let mut dlogits: Vec<f32> = Vec::new();

    'outer: while running.load(Ordering::SeqCst) {
        // Liveness signal for the supervisor watchdog: bump once per
        // iteration (the idle recv below times out within 50ms, so a
        // healthy worker's tick is never stale for long).
        heartbeat.fetch_add(1, Ordering::SeqCst);
        // Sleep until the next deadline (or a message arrives).
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(msg) => {
                match enqueue_msg(msg, &mut batcher, &mut waiters, &metrics) {
                    Step::Continue => {}
                    Step::Drain => break 'outer,
                    Step::Crash => return,
                    Step::Wedge => return wedge_idle(&running),
                }
                // Drain whatever else is already queued without sleeping.
                while let Ok(msg) = rx.try_recv() {
                    match enqueue_msg(msg, &mut batcher, &mut waiters, &metrics) {
                        Step::Continue => {}
                        Step::Drain => break 'outer,
                        Step::Crash => return,
                        Step::Wedge => return wedge_idle(&running),
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }

        // Session lanes first: decode/close steps (a waiting stream's
        // inter-token latency) jump ahead of everything, then opens
        // (prefill-sized work), then one-shot batches.
        drain_sessions(
            backend, &cfg, &mut router, &mut batcher, &mut sessions, &metrics, &mut dlogits,
        );

        // Shed whoever missed their deadline before cutting work.
        let now = Instant::now();
        shed_expired(&mut batcher, &mut waiters, &metrics, cfg.default_variant, now);
        while batcher.ready(now) {
            let batch = batcher.cut();
            if batch.is_empty() {
                break;
            }
            // Live load signal for the router: the backlog this batch
            // leaves behind across all lanes.
            let load = QueueLoad {
                prefill: batcher.len() + batcher.open_len(),
                decode: batcher.decode_len(),
            };
            execute_batch(
                backend, &cfg, &mut router, load, batch, &mut waiters, &metrics, &mut buffers,
            );
        }
    }

    // Drain phase: a submission can race the Shutdown message onto the
    // channel; adopt everything still in flight so each such request
    // gets a real reply (served / overloaded / expired) rather than a
    // dropped channel. Admissions are already gated off engine-side.
    // A chaos Die/Wedge racing a clean shutdown is ignored here — the
    // drain already in progress wins.
    while let Ok(msg) = rx.try_recv() {
        let _ = enqueue_msg(msg, &mut batcher, &mut waiters, &metrics);
    }

    // Flush any stragglers on shutdown (session lanes first, as above).
    // Deadlines are still honored — an expired request gets its
    // structured reply here too, never silence.
    drain_sessions(
        backend, &cfg, &mut router, &mut batcher, &mut sessions, &metrics, &mut dlogits,
    );
    shed_expired(
        &mut batcher,
        &mut waiters,
        &metrics,
        cfg.default_variant,
        Instant::now(),
    );
    while !batcher.is_empty() {
        let batch = batcher.cut();
        let load = QueueLoad {
            prefill: batcher.len(),
            decode: 0,
        };
        execute_batch(
            backend, &cfg, &mut router, load, batch, &mut waiters, &metrics, &mut buffers,
        );
    }
}

/// Drain both session lanes: every queued decode/close, then every queued
/// open.
#[allow(clippy::too_many_arguments)]
fn drain_sessions(
    backend: &mut dyn InferBackend,
    cfg: &EngineConfig,
    router: &mut Option<AdaptiveRouter>,
    batcher: &mut Batcher,
    sessions: &mut SessionTable,
    metrics: &Metrics,
    dlogits: &mut Vec<f32>,
) {
    while let Some(job) = batcher.next_decode() {
        let load = QueueLoad {
            prefill: batcher.len() + batcher.open_len(),
            decode: batcher.decode_len(),
        };
        handle_session_job(backend, cfg, router, load, job, sessions, metrics, dlogits);
    }
    while let Some(job) = batcher.next_open() {
        let load = QueueLoad {
            prefill: batcher.len() + batcher.open_len(),
            decode: batcher.decode_len(),
        };
        handle_session_job(backend, cfg, router, load, job, sessions, metrics, dlogits);
    }
}

/// Execute one session op against the backend, maintaining the LRU table
/// and the session metrics, and reply on the job's channel (errors travel
/// as the typed [`ServeError`]). Expired jobs are answered `Expired`
/// without touching the backend — except `Close`, which always runs: a
/// deadline must never leak a session. Backend calls run inside the
/// worker's `catch_unwind` blast shield, so a backend panic answers this
/// job and the worker lives on.
#[allow(clippy::too_many_arguments)]
fn handle_session_job(
    backend: &mut dyn InferBackend,
    cfg: &EngineConfig,
    router: &mut Option<AdaptiveRouter>,
    load: QueueLoad,
    job: SessionJob,
    table: &mut SessionTable,
    metrics: &Metrics,
    dlogits: &mut Vec<f32>,
) {
    let SessionJob { op, enqueued, deadline, reply } = job;
    if let Some(d) = deadline {
        let now = Instant::now();
        if now >= d && !matches!(op, SessionOp::Close { .. }) {
            let variant = match &op {
                SessionOp::Open { variant, .. } => (*variant).unwrap_or(cfg.default_variant),
                SessionOp::Reopen { variant, .. } => *variant,
                SessionOp::Decode { session, .. } => table
                    .live
                    .get(session)
                    .map(|(_, v)| *v)
                    .unwrap_or(cfg.default_variant),
                // lint: allow(panic, the expiry scan never sees Close ops by construction)
                SessionOp::Close { .. } => unreachable!("close ops are exempt from expiry"),
            };
            metrics.record_expired(variant, 1);
            let waited_ms = now.duration_since(enqueued).as_millis() as u64;
            let _ = reply.send(Err(ServeError::Expired { waited_ms }));
            return;
        }
    }
    let result = run_session_op(backend, cfg, router, load, op, table, metrics, enqueued, dlogits);
    if result.is_err() {
        metrics.record_errored(1);
    }
    // Refresh gauges before replying: a client that reads its reply and
    // immediately queries metrics must see its own session reflected.
    metrics.set_session_gauges(
        backend.session_count(),
        backend.resident_tokens(),
        backend.cache_grows(),
    );
    let _ = reply.send(result);
}

/// The backend-touching body of [`handle_session_job`], behind the panic
/// blast shield: a panicking backend call becomes a structured `Failed`
/// reply instead of killing the engine worker.
#[allow(clippy::too_many_arguments)]
fn run_session_op(
    backend: &mut dyn InferBackend,
    cfg: &EngineConfig,
    router: &mut Option<AdaptiveRouter>,
    load: QueueLoad,
    op: SessionOp,
    table: &mut SessionTable,
    metrics: &Metrics,
    enqueued: Instant,
    dlogits: &mut Vec<f32>,
) -> ServeResult<SessionReply> {
    let caught = panic::catch_unwind(AssertUnwindSafe(|| -> Result<SessionReply> {
        session_op_body(backend, cfg, router, load, op, table, metrics, enqueued, dlogits)
    }));
    match caught {
        Ok(Ok(reply)) => Ok(reply),
        Ok(Err(e)) => Err(ServeError::Failed(e)),
        Err(payload) => Err(ServeError::Failed(err!(
            "session op panicked: {}",
            panic_message(payload.as_ref())
        ))),
    }
}

#[allow(clippy::too_many_arguments)]
fn session_op_body(
    backend: &mut dyn InferBackend,
    cfg: &EngineConfig,
    router: &mut Option<AdaptiveRouter>,
    load: QueueLoad,
    op: SessionOp,
    table: &mut SessionTable,
    metrics: &Metrics,
    enqueued: Instant,
    dlogits: &mut Vec<f32>,
) -> Result<SessionReply> {
    match op {
        SessionOp::Open { prompt, variant } => {
            // Explicit override wins; otherwise the adaptive router picks
            // the rung for the current load — including the shed ladder's
            // degradation pin under pressure (recorded like any routing
            // decision) — and the session stays on it for life: masks
            // must not shift mid-stream under a live cache.
            let variant = match variant {
                Some(v) => v,
                None => match router.as_mut() {
                    Some(r) => {
                        let routed = r.route(load);
                        metrics.record_routed(routed.variant);
                        if routed.degraded {
                            metrics.record_degraded(routed.variant);
                        }
                        routed.variant
                    }
                    None => cfg.default_variant,
                },
            };
            // LRU-evict down to capacity before admitting the new
            // session: O(live) min-scan, fine at serving session counts.
            let max = cfg.sessions.max_sessions.max(1);
            while table.live.len() >= max {
                let lru = table
                    .live
                    .iter()
                    .min_by_key(|(_, (tick, _))| *tick)
                    .map(|(&id, _)| id)
                    // lint: allow(panic, the loop guard proves the table is non-empty)
                    .expect("capacity implies a non-empty table");
                table.live.remove(&lru);
                if let Err(e) = backend.close_session(lru) {
                    crate::log_error!("evicting session {lru}: {e}");
                }
                metrics.record_session_evicted();
            }
            table.next_id += 1;
            let id = table.next_id;
            match backend.open_session(id, variant, &prompt) {
                Ok(resident) => {
                    table.tick += 1;
                    table.live.insert(id, (table.tick, variant));
                    metrics.record_session_opened();
                    Ok(SessionReply::Opened { session: id, resident, variant })
                }
                Err(e) => Err(e),
            }
        }
        SessionOp::Reopen { prompt, decoded, variant } => {
            // Journal replay for a migrated session: the variant is
            // already pinned (no router consult — masks must not shift
            // across a migration), but eviction and accounting mirror a
            // fresh open: the rebuilt session IS a new session on this
            // replica, with a new local id.
            let max = cfg.sessions.max_sessions.max(1);
            while table.live.len() >= max {
                let lru = table
                    .live
                    .iter()
                    .min_by_key(|(_, (tick, _))| *tick)
                    .map(|(&id, _)| id)
                    // lint: allow(panic, the loop guard proves the table is non-empty)
                    .expect("capacity implies a non-empty table");
                table.live.remove(&lru);
                if let Err(e) = backend.close_session(lru) {
                    crate::log_error!("evicting session {lru}: {e}");
                }
                metrics.record_session_evicted();
            }
            table.next_id += 1;
            let id = table.next_id;
            match backend.reopen_session(id, variant, &prompt, &decoded) {
                Ok(resident) => {
                    table.tick += 1;
                    table.live.insert(id, (table.tick, variant));
                    metrics.record_session_opened();
                    Ok(SessionReply::Opened { session: id, resident, variant })
                }
                Err(e) => Err(e),
            }
        }
        SessionOp::Decode { session, token } => {
            match backend.decode_into(session, token, dlogits) {
                Ok(resident) => {
                    table.tick += 1;
                    let variant = match table.live.get_mut(&session) {
                        Some(slot) => {
                            slot.0 = table.tick;
                            slot.1
                        }
                        // Backend accepted it, so the table must know it;
                        // fall back rather than panic the worker.
                        None => cfg.default_variant,
                    };
                    let latency = enqueued.elapsed();
                    metrics.record_decode(variant, latency.as_secs_f64());
                    let logits = dlogits.clone();
                    Ok(SessionReply::Decoded(DecodeResponse {
                        session,
                        pred: InferResponse::argmax(&logits),
                        logits,
                        resident,
                        latency,
                        variant,
                    }))
                }
                Err(e) => Err(e),
            }
        }
        SessionOp::Close { session } => match backend.close_session(session) {
            Ok(released) => {
                table.live.remove(&session);
                metrics.record_session_closed();
                Ok(SessionReply::Closed { session, released })
            }
            Err(e) => Err(e),
        },
    }
}

/// Worker-owned buffers reused across batches (padded token input and
/// backend logits output). They grow to the largest bucket seen and stay
/// warm: the steady-state per-batch path allocates neither.
#[derive(Default)]
struct BatchBuffers {
    tokens: Vec<i32>,
    logits: Vec<f32>,
    lat_pairs: Vec<(f64, f64)>,
}

#[allow(clippy::too_many_arguments)]
fn execute_batch(
    backend: &mut dyn InferBackend,
    cfg: &EngineConfig,
    router: &mut Option<AdaptiveRouter>,
    load: QueueLoad,
    batch: Vec<InferRequest>,
    waiters: &mut std::collections::HashMap<u64, Sender<ServeResult<InferResponse>>>,
    metrics: &Metrics,
    buffers: &mut BatchBuffers,
) {
    // Explicit per-request variant overrides always win; otherwise the
    // adaptive router (when configured) picks the rung for the current
    // two-lane load (prefill backlog + discounted decode backlog) —
    // jumping straight to the sparsest rung when the shed ladder trips
    // (counted as a degradation) — and the decision is recorded before
    // the batch runs.
    let variant = match batch[0].variant {
        Some(v) => v,
        None => match router.as_mut() {
            Some(r) => {
                let routed = r.route(load);
                metrics.record_routed(routed.variant);
                if routed.degraded {
                    metrics.record_degraded(routed.variant);
                }
                routed.variant
            }
            None => cfg.default_variant,
        },
    };
    let n = batch.len();
    let bucket = backend.bucket_for(n);
    let classes = backend.classes();

    // Pad to the bucket with the first request's tokens, into the warm
    // worker-owned buffer.
    let tokens = &mut buffers.tokens;
    tokens.clear();
    for r in &batch {
        tokens.extend_from_slice(&r.tokens);
    }
    for _ in n..bucket {
        tokens.extend_from_slice(&batch[0].tokens);
    }

    let exec_start = Instant::now();
    let logits = &mut buffers.logits;
    // Blast shield: a backend panic (e.g. injected via the fault
    // harness, or a real kernel bug) must answer this batch's waiters
    // and leave the worker alive — the warm buffers are rewritten from
    // scratch every batch, so a mid-run abort cannot poison later ones.
    let run = panic::catch_unwind(AssertUnwindSafe(|| {
        backend.run_into(variant, tokens, bucket, logits)
    }));
    let failure = match run {
        Ok(Ok(())) => None,
        Ok(Err(e)) => Some(format!("executing variant={variant} bucket={bucket}: {e}")),
        Err(payload) => Some(format!(
            "executing variant={variant} bucket={bucket}: backend panicked: {}",
            panic_message(payload.as_ref())
        )),
    };
    if let Some(msg) = failure {
        crate::log_error!("{msg}");
        metrics.record_errored(n as u64);
        for r in &batch {
            if let Some(tx) = waiters.remove(&r.id) {
                let _ = tx.send(Err(ServeError::Failed(err!("{msg}"))));
            }
        }
        return;
    }
    debug_assert_eq!(logits.len(), bucket * classes);

    let done = Instant::now();
    let mut responses = Vec::with_capacity(n);
    let lat_pairs = &mut buffers.lat_pairs;
    lat_pairs.clear();
    for (i, r) in batch.iter().enumerate() {
        let l = logits[i * classes..(i + 1) * classes].to_vec();
        let resp = InferResponse {
            id: r.id,
            pred: InferResponse::argmax(&l),
            logits: l,
            latency: done.duration_since(r.enqueued),
            queue_time: exec_start.duration_since(r.enqueued),
            batch_size: n,
            bucket,
            variant,
        };
        lat_pairs.push((
            resp.latency.as_secs_f64(),
            resp.queue_time.as_secs_f64(),
        ));
        responses.push(resp);
    }
    // Record metrics BEFORE waking waiters: a client that reads its reply
    // and immediately queries /metrics must see its own request counted.
    metrics.record_batch(variant, n, lat_pairs);
    // Pool counters ride along when the native kernels have started the
    // global pool; a PJRT-only serving path must not spawn one just to
    // report zeros.
    if let Some(stats) = crate::kernels::pool::WorkerPool::try_global_stats() {
        metrics.record_pool(stats);
    }
    for resp in responses {
        if let Some(tx) = waiters.remove(&resp.id) {
            let _ = tx.send(Ok(resp));
        }
    }
}
