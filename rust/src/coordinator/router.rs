//! Adaptive variant routing policy.
//!
//! The paper's Sec. 3.3 frames the sparsity ratio alpha as a per-task,
//! per-platform knob. At serving time that becomes a routing decision:
//! under light load, serve the dense model (best quality); as load grows,
//! shift traffic to progressively sparser DSA variants (cheaper per
//! request). This module implements that policy over queue-depth
//! hysteresis; the engine worker drives it per batch (see
//! `EngineConfig::router`) using the live post-cut queue depth, and every
//! decision is recorded in `Metrics` (`router` section of the stats
//! JSON). The ablation bench exercises the same ladder (`bench_serving`
//! closed-loop rows give the per-variant costs the thresholds encode).

/// One rung of the policy ladder.
#[derive(Debug, Clone)]
pub struct Rung {
    pub variant: String,
    /// Route here once queue depth is >= this threshold.
    pub min_queue: usize,
}

/// Queue-depth-driven variant selector with hysteresis.
#[derive(Debug, Clone)]
pub struct AdaptiveRouter {
    /// Rungs in ascending min_queue order; rung 0 must have min_queue 0.
    rungs: Vec<Rung>,
    /// Hysteresis: step down (toward denser) only when depth falls below
    /// the rung's threshold minus this margin.
    hysteresis: usize,
    current: usize,
}

impl AdaptiveRouter {
    /// Build from (variant, min_queue) pairs.
    ///
    /// Panics if empty, unsorted, or rung 0 is not the zero-threshold rung.
    pub fn new(rungs: Vec<Rung>, hysteresis: usize) -> Self {
        assert!(!rungs.is_empty(), "need at least one rung");
        assert_eq!(rungs[0].min_queue, 0, "rung 0 must cover empty queues");
        assert!(
            rungs.windows(2).all(|w| w[0].min_queue < w[1].min_queue),
            "rungs must be strictly ascending in min_queue"
        );
        AdaptiveRouter {
            rungs,
            hysteresis,
            current: 0,
        }
    }

    /// The ladder used by the serving example: dense → dsa90 → dsa95.
    pub fn default_ladder() -> Self {
        AdaptiveRouter::new(
            vec![
                Rung { variant: "dense".into(), min_queue: 0 },
                Rung { variant: "dsa90".into(), min_queue: 8 },
                Rung { variant: "dsa95".into(), min_queue: 32 },
            ],
            2,
        )
    }

    /// Select the variant for the next batch given the current queue depth.
    pub fn select(&mut self, queue_depth: usize) -> &str {
        // escalate while the next rung's threshold is met
        while self.current + 1 < self.rungs.len()
            && queue_depth >= self.rungs[self.current + 1].min_queue
        {
            self.current += 1;
        }
        // de-escalate with hysteresis
        while self.current > 0
            && queue_depth + self.hysteresis < self.rungs[self.current].min_queue
        {
            self.current -= 1;
        }
        &self.rungs[self.current].variant
    }

    pub fn current_variant(&self) -> &str {
        &self.rungs[self.current].variant
    }

    /// Variant name of every rung, densest first — the engine preloads
    /// all of them at startup so a mid-burst escalation never pays (or
    /// fails) lazy kernel instantiation.
    pub fn variants(&self) -> impl Iterator<Item = &str> {
        self.rungs.iter().map(|r| r.variant.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ladder() -> AdaptiveRouter {
        AdaptiveRouter::default_ladder()
    }

    #[test]
    fn exposes_rung_variants_in_order() {
        let r = ladder();
        let vs: Vec<&str> = r.variants().collect();
        assert_eq!(vs, vec!["dense", "dsa90", "dsa95"]);
    }

    #[test]
    fn starts_dense() {
        let mut r = ladder();
        assert_eq!(r.select(0), "dense");
        assert_eq!(r.select(7), "dense");
    }

    #[test]
    fn escalates_under_load() {
        let mut r = ladder();
        assert_eq!(r.select(8), "dsa90");
        assert_eq!(r.select(40), "dsa95");
    }

    #[test]
    fn skips_rungs_on_burst() {
        let mut r = ladder();
        assert_eq!(r.select(100), "dsa95");
    }

    #[test]
    fn hysteresis_prevents_flapping() {
        let mut r = ladder();
        assert_eq!(r.select(8), "dsa90");
        // depth 7 is below the threshold but inside the hysteresis band
        assert_eq!(r.select(7), "dsa90");
        assert_eq!(r.select(6), "dsa90");
        // only well below does it de-escalate
        assert_eq!(r.select(5), "dense");
    }

    #[test]
    fn de_escalates_fully_when_idle() {
        let mut r = ladder();
        r.select(100);
        assert_eq!(r.select(0), "dense");
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn rejects_unsorted_rungs() {
        AdaptiveRouter::new(
            vec![
                Rung { variant: "a".into(), min_queue: 0 },
                Rung { variant: "b".into(), min_queue: 5 },
                Rung { variant: "c".into(), min_queue: 5 },
            ],
            1,
        );
    }
}
