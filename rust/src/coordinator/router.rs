//! Adaptive variant routing policy.
//!
//! The paper's Sec. 3.3 frames the sparsity ratio alpha as a per-task,
//! per-platform knob. At serving time that becomes a routing decision:
//! under light load, serve the dense model (best quality); as load grows,
//! shift traffic to progressively sparser DSA variants (cheaper per
//! request). This module implements that policy over queue-depth
//! hysteresis; the engine worker drives it per batch (see
//! `EngineConfig::router`) using the live post-cut queue depth, and every
//! decision is recorded in `Metrics` (`router` section of the stats
//! JSON). The ablation bench exercises the same ladder (`bench_serving`
//! closed-loop rows give the per-variant costs the thresholds encode).
//!
//! Rungs carry the **typed** [`Variant`]: a ladder built from
//! configuration strings goes through [`AdaptiveRouter::from_pairs`],
//! which validates every rung via `Variant::from_str` at construction —
//! a typo'd rung fails engine startup instead of silently routing batches
//! to a dead variant at runtime.

use crate::kernels::Variant;
use crate::util::error::{bail, Context, Result};

/// One rung of the policy ladder.
#[derive(Debug, Clone, Copy)]
pub struct Rung {
    pub variant: Variant,
    /// Route here once queue depth is >= this threshold.
    pub min_queue: usize,
}

/// Weight of one queued decode step relative to one queued prefill-sized
/// request in the router's load signal: a decode step touches one cached
/// query row where a prefill / one-shot request runs `seq_len` of them,
/// so a deep decode lane is far cheaper backlog than the same depth of
/// prompts. 16 ≈ the cost ratio at the serving default `seq_len = 256`
/// with decode steps averaging a half-full cache.
pub const DECODE_WEIGHT: usize = 16;

/// The router's two-lane load signal: queued prefill-sized work (one-shot
/// requests + session opens) and queued decode steps. Collapsed to one
/// effective depth via [`QueueLoad::effective_depth`] so the ladder
/// thresholds keep their meaning from the closed-loop benches.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueueLoad {
    /// Backlogged one-shot requests and session opens (full forwards).
    pub prefill: usize,
    /// Backlogged decode steps (single cached rows).
    pub decode: usize,
}

impl QueueLoad {
    /// Prefill-equivalent queue depth: decode steps are discounted by
    /// [`DECODE_WEIGHT`] (rounding up, so a non-empty decode lane is
    /// never mistaken for an idle queue).
    pub fn effective_depth(&self) -> usize {
        self.prefill + self.decode.div_ceil(DECODE_WEIGHT)
    }
}

/// One routing decision from [`AdaptiveRouter::route`]: the variant to
/// run, and whether it was a *degradation* — default-variant traffic
/// forced onto the sparsest rung by overload pressure rather than chosen
/// by the normal ladder walk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Routed {
    pub variant: Variant,
    pub degraded: bool,
}

/// Queue-depth-driven variant selector with hysteresis.
#[derive(Debug, Clone)]
pub struct AdaptiveRouter {
    /// Rungs in ascending min_queue order; rung 0 must have min_queue 0.
    rungs: Vec<Rung>,
    /// Hysteresis: step down (toward denser) only when depth falls below
    /// the rung's threshold minus this margin.
    hysteresis: usize,
    current: usize,
    /// Shed-ladder threshold: at effective depth >= this, `route` pins
    /// traffic to the sparsest rung (graceful degradation — spend the
    /// paper's accuracy/cost knob before shedding work). `None` = off.
    degrade_depth: Option<usize>,
}

impl AdaptiveRouter {
    /// Build from typed rungs, panicking on a malformed ladder
    /// (programmer error in code-constructed ladders; config-derived
    /// ladders go through [`AdaptiveRouter::from_pairs`], which returns
    /// `Err` instead). Both paths share [`AdaptiveRouter::from_rungs`],
    /// so the two construction routes can never enforce different rules.
    pub fn new(rungs: Vec<Rung>, hysteresis: usize) -> Self {
        // lint: allow(panic, documented contract - malformed code-constructed ladders are programmer error)
        AdaptiveRouter::from_rungs(rungs, hysteresis).unwrap_or_else(|e| panic!("{e}"))
    }

    /// The single validating constructor: non-empty ladder, rung 0 covers
    /// depth 0, thresholds strictly ascending.
    pub fn from_rungs(rungs: Vec<Rung>, hysteresis: usize) -> Result<AdaptiveRouter> {
        if rungs.is_empty() {
            bail!("router ladder needs at least one rung");
        }
        if rungs[0].min_queue != 0 {
            bail!(
                "router rung 0 ({}) must have min_queue 0 to cover empty queues",
                rungs[0].variant
            );
        }
        if let Some(w) = rungs.windows(2).find(|w| w[0].min_queue >= w[1].min_queue) {
            bail!(
                "router rungs must be strictly ascending in min_queue ({} at {} then {} at {})",
                w[0].variant,
                w[0].min_queue,
                w[1].variant,
                w[1].min_queue
            );
        }
        Ok(AdaptiveRouter { rungs, hysteresis, current: 0, degrade_depth: None })
    }

    /// Enable the shed ladder: at effective depth >= `depth`, [`route`]
    /// pins default-variant traffic to the sparsest rung and flags the
    /// decision as degraded (counted separately in `Metrics`). Shedding
    /// proper stays the batcher's `queue_cap` — the ladder buys headroom
    /// *before* that bound bites, so set `depth` below the queue cap.
    ///
    /// [`route`]: AdaptiveRouter::route
    pub fn with_degrade_depth(mut self, depth: usize) -> Self {
        self.degrade_depth = Some(depth);
        self
    }

    pub fn degrade_depth(&self) -> Option<usize> {
        self.degrade_depth
    }

    /// Build a ladder from `(variant name, min_queue)` pairs, validating
    /// each name via `Variant::from_str` (the error names the offending
    /// rung) before handing the typed rungs to
    /// [`AdaptiveRouter::from_rungs`] — a bad config fails engine startup
    /// instead of routing to a dead variant at runtime.
    pub fn from_pairs(pairs: &[(&str, usize)], hysteresis: usize) -> Result<AdaptiveRouter> {
        let mut rungs = Vec::with_capacity(pairs.len());
        for (name, min_queue) in pairs {
            let variant = name
                .parse::<Variant>()
                .with_context(|| format!("router rung at min_queue {min_queue}"))?;
            rungs.push(Rung { variant, min_queue: *min_queue });
        }
        AdaptiveRouter::from_rungs(rungs, hysteresis)
    }

    /// The ladder used by the serving example: dense → dsa90 → dsa95.
    pub fn default_ladder() -> Self {
        AdaptiveRouter::new(
            vec![
                Rung { variant: Variant::Dense, min_queue: 0 },
                Rung { variant: Variant::Dsa { pct: 90 }, min_queue: 8 },
                Rung { variant: Variant::Dsa { pct: 95 }, min_queue: 32 },
            ],
            2,
        )
    }

    /// Select the variant for the next batch given the current queue depth.
    pub fn select(&mut self, queue_depth: usize) -> Variant {
        // escalate while the next rung's threshold is met
        while self.current + 1 < self.rungs.len()
            && queue_depth >= self.rungs[self.current + 1].min_queue
        {
            self.current += 1;
        }
        // de-escalate with hysteresis
        while self.current > 0
            && queue_depth + self.hysteresis < self.rungs[self.current].min_queue
        {
            self.current -= 1;
        }
        self.rungs[self.current].variant
    }

    /// Select the variant for the next dispatch from the two-lane load
    /// signal (what the engine worker uses now that decode streams share
    /// the queue with one-shot requests): decode backlog is discounted to
    /// prefill-equivalents by [`QueueLoad::effective_depth`], then the
    /// same ladder-with-hysteresis walk as [`AdaptiveRouter::select`]
    /// applies.
    pub fn select_load(&mut self, load: QueueLoad) -> Variant {
        self.select(load.effective_depth())
    }

    /// The engine's routing entry point: like [`select_load`], but when
    /// the shed ladder is enabled and the effective depth has reached
    /// `degrade_depth`, the decision jumps straight to the sparsest rung
    /// and is flagged `degraded` — overload spends sparsity (the paper's
    /// tunable accuracy/cost knob) before the queue cap sheds work.
    /// Pinning also moves the hysteresis state to the top rung, so the
    /// ladder de-escalates gradually once pressure lifts.
    ///
    /// [`select_load`]: AdaptiveRouter::select_load
    pub fn route(&mut self, load: QueueLoad) -> Routed {
        let depth = load.effective_depth();
        if let Some(d) = self.degrade_depth {
            if depth >= d {
                self.current = self.rungs.len() - 1;
                return Routed { variant: self.rungs[self.current].variant, degraded: true };
            }
        }
        Routed { variant: self.select(depth), degraded: false }
    }

    pub fn current_variant(&self) -> Variant {
        self.rungs[self.current].variant
    }

    /// Variant of every rung, densest first — the engine preloads all of
    /// them at startup so a mid-burst escalation never pays (or fails)
    /// lazy kernel instantiation.
    pub fn variants(&self) -> impl Iterator<Item = Variant> + '_ {
        self.rungs.iter().map(|r| r.variant)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ladder() -> AdaptiveRouter {
        AdaptiveRouter::default_ladder()
    }

    const DENSE: Variant = Variant::Dense;
    const DSA90: Variant = Variant::Dsa { pct: 90 };
    const DSA95: Variant = Variant::Dsa { pct: 95 };

    #[test]
    fn exposes_rung_variants_in_order() {
        let r = ladder();
        let vs: Vec<Variant> = r.variants().collect();
        assert_eq!(vs, vec![DENSE, DSA90, DSA95]);
    }

    #[test]
    fn starts_dense() {
        let mut r = ladder();
        assert_eq!(r.select(0), DENSE);
        assert_eq!(r.select(7), DENSE);
    }

    #[test]
    fn escalates_under_load() {
        let mut r = ladder();
        assert_eq!(r.select(8), DSA90);
        assert_eq!(r.select(40), DSA95);
    }

    #[test]
    fn skips_rungs_on_burst() {
        let mut r = ladder();
        assert_eq!(r.select(100), DSA95);
    }

    /// Decode backlog is discounted: a lane full of single-token decode
    /// steps escalates far later than the same depth of prefill-sized
    /// requests, but is never invisible (one queued decode rounds up to
    /// one effective unit), and mixed load sums.
    #[test]
    fn decode_load_is_discounted_not_ignored() {
        assert_eq!(QueueLoad { prefill: 3, decode: 0 }.effective_depth(), 3);
        assert_eq!(QueueLoad { prefill: 0, decode: 1 }.effective_depth(), 1);
        assert_eq!(
            QueueLoad { prefill: 0, decode: DECODE_WEIGHT * 2 }.effective_depth(),
            2
        );
        assert_eq!(
            QueueLoad { prefill: 6, decode: DECODE_WEIGHT * 2 + 1 }.effective_depth(),
            9
        );

        let mut r = ladder();
        // 7 prefill + a big decode lane crosses the dsa90 threshold (8)...
        assert_eq!(r.select_load(QueueLoad { prefill: 7, decode: DECODE_WEIGHT }), DSA90);
        // ...while the same total count as pure decode steps stays dense.
        let mut r = ladder();
        assert_eq!(
            r.select_load(QueueLoad { prefill: 0, decode: 7 + DECODE_WEIGHT }),
            DENSE
        );
    }

    #[test]
    fn hysteresis_prevents_flapping() {
        let mut r = ladder();
        assert_eq!(r.select(8), DSA90);
        // depth 7 is below the threshold but inside the hysteresis band
        assert_eq!(r.select(7), DSA90);
        assert_eq!(r.select(6), DSA90);
        // only well below does it de-escalate
        assert_eq!(r.select(5), DENSE);
    }

    #[test]
    fn de_escalates_fully_when_idle() {
        let mut r = ladder();
        r.select(100);
        assert_eq!(r.select(0), DENSE);
    }

    /// The `from_pairs` satellite: valid ladders construct (typed,
    /// matching the code-built equivalent), while a typo'd rung — or a
    /// malformed ladder shape — fails with an error at construction, i.e.
    /// at engine startup, never as a dead route at runtime.
    #[test]
    fn from_pairs_validates_rungs_at_construction() {
        let r = AdaptiveRouter::from_pairs(&[("dense", 0), ("dsa90", 8), ("dsa95", 32)], 2)
            .expect("valid ladder");
        let vs: Vec<Variant> = r.variants().collect();
        assert_eq!(vs, vec![DENSE, DSA90, DSA95]);

        let typo = AdaptiveRouter::from_pairs(&[("dense", 0), ("dsa9O", 8)], 2);
        let msg = format!("{:#}", typo.expect_err("typo'd rung must fail"));
        assert!(msg.contains("dsa9O"), "error must name the bad variant: {msg}");
        assert!(msg.contains("min_queue 8"), "error must locate the rung: {msg}");

        assert!(AdaptiveRouter::from_pairs(&[], 1).is_err(), "empty ladder");
        assert!(
            AdaptiveRouter::from_pairs(&[("dense", 3)], 1).is_err(),
            "first rung must cover depth 0"
        );
        assert!(
            AdaptiveRouter::from_pairs(&[("dense", 0), ("dsa90", 5), ("dsa95", 5)], 1).is_err(),
            "non-ascending thresholds"
        );
    }

    /// The shed ladder: below the degrade depth `route` matches the
    /// normal ladder walk; at or past it, traffic pins to the sparsest
    /// rung flagged `degraded`, and de-escalation is gradual (hysteresis
    /// from the top rung) once pressure lifts.
    #[test]
    fn route_degrades_to_sparsest_under_pressure() {
        let mut r = ladder().with_degrade_depth(16);
        assert_eq!(
            r.route(QueueLoad { prefill: 3, decode: 0 }),
            Routed { variant: DENSE, degraded: false }
        );
        assert_eq!(
            r.route(QueueLoad { prefill: 9, decode: 0 }),
            Routed { variant: DSA90, degraded: false }
        );
        // depth 16 < the dsa95 rung's own threshold (32), but the shed
        // ladder pins it there anyway.
        assert_eq!(
            r.route(QueueLoad { prefill: 16, decode: 0 }),
            Routed { variant: DSA95, degraded: true }
        );
        // pressure lifts a little: still sparse (hysteresis from the top
        // rung), no longer counted as degraded.
        assert_eq!(
            r.route(QueueLoad { prefill: 31, decode: 0 }),
            Routed { variant: DSA95, degraded: false }
        );
        // fully idle: all the way back to dense.
        assert_eq!(
            r.route(QueueLoad { prefill: 0, decode: 0 }),
            Routed { variant: DENSE, degraded: false }
        );
    }

    /// Without `with_degrade_depth`, `route` never degrades — it is
    /// exactly the select_load walk.
    #[test]
    fn route_without_shed_ladder_never_degrades() {
        let mut a = ladder();
        let mut b = ladder();
        for depth in [0usize, 9, 100, 40, 7, 0, 33] {
            let load = QueueLoad { prefill: depth, decode: 0 };
            let routed = a.route(load);
            assert!(!routed.degraded);
            assert_eq!(routed.variant, b.select_load(load));
        }
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn rejects_unsorted_rungs() {
        AdaptiveRouter::new(
            vec![
                Rung { variant: DENSE, min_queue: 0 },
                Rung { variant: DSA90, min_queue: 5 },
                Rung { variant: DSA95, min_queue: 5 },
            ],
            1,
        );
    }
}
