//! Typed overload-safety outcomes for the serving path.
//!
//! Every admission decision the engine can take — accept, shed, expire,
//! quota-reject, refuse during drain, lose a session to a replica crash,
//! fail — is one [`ServeError`] arm
//! with a stable wire code, so the server renders a structured
//! `{"ok": false, "error": <code>, ...}` reply instead of a dropped line
//! and tests/clients can match on codes instead of message prose.
//!
//! [`ServeError`] implements `std::error::Error`, so the crate-wide
//! blanket `From<E: std::error::Error> for util::Error` gives `?`
//! conversion into plain [`Error`] for callers (benches, CLI) that do
//! not care about the code.

use std::fmt;

use crate::util::error::Error;
use crate::util::json::Json;

/// Result type for the engine's admission-controlled serving surface.
pub type ServeResult<T> = std::result::Result<T, ServeError>;

/// Why a request did not get a normal reply. See module docs.
#[derive(Debug)]
pub enum ServeError {
    /// Queue past `queue_cap`: shed at admission. `retry_after_ms` is the
    /// batcher's estimate of when the backlog will have drained.
    Overloaded { retry_after_ms: u64 },
    /// Deadline budget elapsed before the work started executing.
    Expired { waited_ms: u64 },
    /// A per-client quota (request rate or open sessions) tripped.
    QuotaExceeded { what: &'static str, limit: u64 },
    /// Admissions are stopped; the engine is draining toward exit.
    ShuttingDown,
    /// The replica holding this decode session died AND migration could
    /// not rebuild it on a sibling — replay budget exhausted, no healthy
    /// sibling, or the resident-token budget would be breached. With
    /// journaled replay in place this is the *failure* path, never the
    /// default: a recoverable session is migrated transparently and the
    /// caller sees nothing. The id will never serve again — reopen to
    /// continue.
    SessionLost { session: u64 },
    /// The request itself is malformed (bad length, bad field value).
    Invalid(String),
    /// Backend or batch execution failed — including panics caught by the
    /// engine worker's blast shield.
    Failed(Error),
}

impl ServeError {
    /// Stable wire code rendered in the `"error"` field of structured
    /// replies (and matched by the chaos tests).
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::Overloaded { .. } => "overloaded",
            ServeError::Expired { .. } => "expired",
            ServeError::QuotaExceeded { .. } => "quota_exceeded",
            ServeError::ShuttingDown => "shutting_down",
            ServeError::SessionLost { .. } => "session_lost",
            ServeError::Invalid(_) => "invalid",
            ServeError::Failed(_) => "error",
        }
    }

    /// Structured reply body: `{"ok": false, "error": <code>, "message":
    /// <prose>}` plus per-arm hint fields (`retry_after_ms`, `limit`).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("ok", Json::Bool(false)),
            ("error", Json::str(self.code())),
            ("message", Json::str(self.to_string())),
        ];
        match self {
            ServeError::Overloaded { retry_after_ms } => {
                fields.push(("retry_after_ms", Json::num(*retry_after_ms as f64)));
            }
            ServeError::QuotaExceeded { limit, .. } => {
                fields.push(("limit", Json::num(*limit as f64)));
            }
            ServeError::SessionLost { session } => {
                fields.push(("session", Json::num(*session as f64)));
            }
            _ => {}
        }
        Json::obj(fields)
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Overloaded { retry_after_ms } => {
                write!(f, "queue full, retry after {retry_after_ms}ms")
            }
            ServeError::Expired { waited_ms } => {
                write!(f, "deadline expired after {waited_ms}ms in queue")
            }
            ServeError::QuotaExceeded { what, limit } => {
                write!(f, "client quota exceeded: {what} (limit {limit})")
            }
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
            ServeError::SessionLost { session } => {
                write!(
                    f,
                    "session {session} lost: its replica died and migration was \
                     exhausted (budget/siblings/memory); reopen to continue"
                )
            }
            ServeError::Invalid(msg) => f.write_str(msg),
            // util::Error's Display already prints the full context chain.
            ServeError::Failed(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for ServeError {}

impl From<Error> for ServeError {
    fn from(e: Error) -> ServeError {
        ServeError::Failed(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::error::err;

    #[test]
    fn codes_are_stable() {
        assert_eq!(ServeError::Overloaded { retry_after_ms: 5 }.code(), "overloaded");
        assert_eq!(ServeError::Expired { waited_ms: 9 }.code(), "expired");
        assert_eq!(
            ServeError::QuotaExceeded { what: "in-flight requests", limit: 4 }.code(),
            "quota_exceeded"
        );
        assert_eq!(ServeError::ShuttingDown.code(), "shutting_down");
        assert_eq!(ServeError::SessionLost { session: 7 }.code(), "session_lost");
        assert_eq!(ServeError::Invalid("x".into()).code(), "invalid");
        assert_eq!(ServeError::Failed(err!("boom")).code(), "error");
    }

    #[test]
    fn json_reply_is_structured() {
        let j = ServeError::Overloaded { retry_after_ms: 25 }.to_json();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(j.get("error").and_then(Json::as_str), Some("overloaded"));
        assert_eq!(j.get("retry_after_ms").and_then(Json::as_f64), Some(25.0));
        assert!(j.get("message").is_some());

        let j = ServeError::QuotaExceeded { what: "open sessions", limit: 2 }.to_json();
        assert_eq!(j.get("error").and_then(Json::as_str), Some("quota_exceeded"));
        assert_eq!(j.get("limit").and_then(Json::as_f64), Some(2.0));

        let j = ServeError::SessionLost { session: 11 }.to_json();
        assert_eq!(j.get("error").and_then(Json::as_str), Some("session_lost"));
        assert_eq!(j.get("session").and_then(Json::as_f64), Some(11.0));
    }

    #[test]
    fn failed_preserves_context_chain() {
        let e = ServeError::Failed(err!("inner").context("outer"));
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn converts_into_util_error_via_question_mark() {
        fn f() -> crate::util::error::Result<()> {
            Err(ServeError::ShuttingDown)?
        }
        assert_eq!(f().unwrap_err().to_string(), "server is shutting down");
    }
}
