//! L3 coordinator: the serving-side contribution of the stack.
//!
//! * [`request`] — request/response types for one-shot inference
//!   ([`InferRequest`]/[`InferResponse`]) and decode sessions
//!   ([`SessionOp`]/[`SessionReply`]/[`DecodeResponse`]); variants are
//!   the typed `kernels::Variant` end to end (strings parse once at the
//!   protocol/CLI boundary).
//! * [`batcher`] — dynamic batching policy (max-batch / deadline / variant
//!   grouping / backpressure) plus the two session lanes (decode/close
//!   before open before one-shot batches, so prefill backlog never stalls
//!   a live stream's inter-token latency).
//! * [`backend`] — execution backends: hermetic native kernels (always;
//!   kernels built from `Variant` via the global `KernelRegistry`, batches
//!   run through warm buffers + `forward_batch_into`, so the steady-state
//!   loop makes zero per-batch output allocations; decode sessions over a
//!   pooled ragged `KvCache`) and PJRT artifacts (`xla` feature; one-shot
//!   only — session ops return a structured "unsupported" error).
//! * [`engine`] — worker loop: drain session lanes (LRU-bounded lifecycle
//!   per [`SessionPolicy`]) → shed expired deadlines → batch → route
//!   variant (optionally via the adaptive router) → pad to bucket (warm
//!   worker-owned buffers) → backend `run_into` behind a `catch_unwind`
//!   blast shield → fan out typed outcomes. `shutdown` drains: admissions
//!   stop, racing submissions are adopted, every lane flushes, then the
//!   worker exits with zero in-flight work dropped.
//! * [`error`] — the typed overload-safety outcome [`ServeError`]
//!   (`overloaded` / `expired` / `quota_exceeded` / `shutting_down` /
//!   `session_lost` / `invalid` / `error`), each with a stable wire code
//!   the server renders as a structured `{"ok":false,...}` reply.
//! * [`replica`] — replicated serving: a [`ReplicaSet`] runs N engines
//!   from one backend factory behind a heartbeat-watchdog supervisor
//!   (crashed/wedged replicas torn down and respawned with the same
//!   kernel registry preload), a failover dispatcher (accepted one-shots
//!   whose replica dies mid-flight retry on a sibling within a bounded
//!   budget), per-replica circuit breakers, and **durable decode
//!   sessions**: every session's journal (prompt + decoded tokens) lives
//!   in the replica-independent route table and replays onto a healthy
//!   sibling when its replica dies or drains — bitwise-identical by
//!   decode determinism, bounded by `replay_budget_tokens` — so
//!   structured `session_lost` is reserved for exhausted migrations. A
//!   global `max_resident_tokens` ledger budget refuses opens past
//!   memory pressure, `drain_replica` migrates-then-swaps a slot (the
//!   rolling-restart building block), and `health_json` reports
//!   per-replica liveness. The [`Serving`] trait abstracts the TCP
//!   front end over `Engine` vs `ReplicaSet`.
//! * [`router`] — queue-depth-driven variant ladder (dense → dsa90 →
//!   dsa95) the engine worker consults per dispatch; typed rungs,
//!   `AdaptiveRouter::from_pairs` validates names at construction; the
//!   [`QueueLoad`] two-lane signal discounts decode backlog against
//!   prefill-sized work; `with_degrade_depth` adds the shed ladder —
//!   under sustained overload, default-variant traffic pins to the
//!   sparsest rung (the paper's accuracy/cost knob spent as serving
//!   headroom) before anything is shed.
//! * [`metrics`] — latency/throughput/occupancy accounting plus router
//!   decisions, worker-pool counters, the session/decode sections
//!   (lifecycle counts, cache-resident tokens, cache grows, per-variant
//!   inter-token latency) and the always-present `overload` section
//!   (shed / per-variant expired / degraded batches / quota rejections /
//!   errored).

pub mod backend;
pub mod batcher;
pub mod engine;
pub mod error;
pub mod metrics;
pub mod replica;
pub mod request;
pub mod router;

pub use backend::{InferBackend, NativeBackend, NativeModelConfig};
pub use batcher::{BatchPolicy, Batcher, SessionJob};
pub use engine::{Engine, EngineConfig, SessionPolicy};
pub use error::{ServeError, ServeResult};
pub use metrics::Metrics;
pub use replica::{PendingInfer, ReplicaConfig, ReplicaSet, Serving};
pub use request::{DecodeResponse, InferRequest, InferResponse, SessionOp, SessionReply};
pub use router::{AdaptiveRouter, QueueLoad, Routed, Rung};
