//! L3 coordinator: the serving-side contribution of the stack.
//!
//! * [`request`] — request/response types.
//! * [`batcher`] — dynamic batching policy (max-batch / deadline / variant
//!   grouping / backpressure).
//! * [`backend`] — execution backends: hermetic native kernels (always)
//!   and PJRT artifacts (`xla` feature).
//! * [`engine`] — worker loop: batch → pad to bucket → backend execute →
//!   fan out responses.
//! * [`metrics`] — latency/throughput/occupancy accounting.

pub mod backend;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod router;

pub use backend::{InferBackend, NativeBackend, NativeModelConfig};
pub use batcher::{BatchPolicy, Batcher};
pub use engine::{Engine, EngineConfig};
pub use metrics::Metrics;
pub use request::{InferRequest, InferResponse};
