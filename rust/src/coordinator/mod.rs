//! L3 coordinator: the serving-side contribution of the stack.
//!
//! * [`request`] — request/response types.
//! * [`batcher`] — dynamic batching policy (max-batch / deadline / variant
//!   grouping / backpressure).
//! * [`backend`] — execution backends: hermetic native kernels (always)
//!   and PJRT artifacts (`xla` feature).
//! * [`engine`] — worker loop: batch → route variant (optionally via the
//!   adaptive router) → pad to bucket → backend execute → fan out
//!   responses.
//! * [`router`] — queue-depth-driven variant ladder (dense → dsa90 →
//!   dsa95) the engine worker consults per batch.
//! * [`metrics`] — latency/throughput/occupancy accounting plus router
//!   decisions and worker-pool counters.

pub mod backend;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod router;

pub use backend::{InferBackend, NativeBackend, NativeModelConfig};
pub use batcher::{BatchPolicy, Batcher};
pub use engine::{Engine, EngineConfig};
pub use metrics::Metrics;
pub use request::{InferRequest, InferResponse};
pub use router::{AdaptiveRouter, Rung};
