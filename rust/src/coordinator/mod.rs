//! L3 coordinator: the serving-side contribution of the stack.
//!
//! * [`request`] — request/response types; variants are the typed
//!   `kernels::Variant` end to end (strings parse once at the
//!   protocol/CLI boundary).
//! * [`batcher`] — dynamic batching policy (max-batch / deadline / variant
//!   grouping / backpressure).
//! * [`backend`] — execution backends: hermetic native kernels (always;
//!   kernels built from `Variant` via the global `KernelRegistry`, batches
//!   run through warm buffers + `forward_batch_into`, so the steady-state
//!   loop makes zero per-batch output allocations) and PJRT artifacts
//!   (`xla` feature).
//! * [`engine`] — worker loop: batch → route variant (optionally via the
//!   adaptive router) → pad to bucket (warm worker-owned buffers) →
//!   backend `run_into` → fan out responses.
//! * [`router`] — queue-depth-driven variant ladder (dense → dsa90 →
//!   dsa95) the engine worker consults per batch; typed rungs,
//!   `AdaptiveRouter::from_pairs` validates names at construction.
//! * [`metrics`] — latency/throughput/occupancy accounting plus router
//!   decisions and worker-pool counters.

pub mod backend;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod router;

pub use backend::{InferBackend, NativeBackend, NativeModelConfig};
pub use batcher::{BatchPolicy, Batcher};
pub use engine::{Engine, EngineConfig};
pub use metrics::Metrics;
pub use request::{InferRequest, InferResponse};
pub use router::{AdaptiveRouter, Rung};
