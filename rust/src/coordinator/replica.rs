//! Replicated serving: N independent engine replicas behind a supervisor
//! and a failover dispatcher.
//!
//! A [`ReplicaSet`] owns `N` [`Engine`]s — each with its own worker
//! thread, batcher lanes, session table and metrics shard, all built from
//! the **same** backend factory (same `KernelRegistry`, same
//! `KernelSpec`), so every replica — including a respawned one — serves
//! bit-identical logits. On top of them:
//!
//! * **Supervisor.** A thread polls each replica's heartbeat tick
//!   ([`Engine::tick`]) and liveness ([`Engine::alive`]) every quarter
//!   watchdog interval. A replica whose worker exited without draining
//!   (a panic escaped the pool shield — simulated by
//!   [`Engine::inject_crash`]) or whose heartbeat froze past the watchdog
//!   interval (wedged — [`Engine::inject_wedge`]) is torn down
//!   ([`Engine::shutdown`] joins it; a wedged worker exits on the running
//!   flip) and replaced by a fresh replica from the same factory. The
//!   `replicas` metrics section tracks `alive`/`configured` gauges plus
//!   `crashes`/`respawns` counters.
//! * **Dispatcher.** One-shot requests round-robin over healthy replicas.
//!   A request accepted by a replica that dies before replying is
//!   transparently retried on a sibling — bounded by
//!   [`ReplicaConfig::retry_budget`], counted once under `retried`, and
//!   still counted exactly once as served. The original deadline budget
//!   spans all attempts.
//! * **Circuit breaker.** Each replica carries a consecutive-failure
//!   breaker: past [`ReplicaConfig::breaker_threshold`] failures it opens
//!   (the dispatcher routes around it), after
//!   [`ReplicaConfig::breaker_cooldown`] it admits one half-open probe,
//!   and the probe's outcome closes or re-opens it — a flapping replica
//!   is never fed sustained traffic.
//! * **Durable sticky sessions.** Decode sessions pin to the replica
//!   that opened them; the set hands out *global* session ids and routes
//!   ops to the owning replica's inner id. Each route carries a
//!   [`SessionJournal`] — the prompt plus every decoded token, appended
//!   on each successful decode reply (cheap: tokens, not KV state).
//!   Because every replica preloads the same `KernelRegistry`, a
//!   session's KV cache is a deterministic function of its token
//!   history — so when the owning replica dies, the dispatcher
//!   transparently **migrates** the session: it replays the journal on a
//!   healthy sibling through the kernel-free `Reopen` path
//!   (bitwise-identical cache reconstruction) and the op proceeds as if
//!   nothing happened. Migration is bounded by
//!   [`ReplicaConfig::replay_budget_tokens`] and the op's deadline;
//!   budget exhaustion, no healthy sibling, or memory pressure falls
//!   back to a structured [`ServeError::SessionLost`] — never a hang —
//!   so under the extended accounting identity
//!   `submitted == served + overloaded + expired + errored + session_lost`
//!   the `session_lost` term counts **only** exhausted migrations.
//! * **Drain-and-rebalance.** The same replay machinery powers
//!   [`ReplicaSet::drain_replica`]: proactively migrate every live
//!   session off a replica, then swap in a fresh engine — the building
//!   block for live reconfig and rolling kernel swaps. Wedge/crash
//!   teardown in the supervisor migrates proactively too, so sessions
//!   survive even when no op happens to touch them mid-failure.
//! * **Resident-token budget.** [`ReplicaConfig::max_resident_tokens`]
//!   caps journal-tracked resident tokens across all replicas: `open`
//!   past the budget gets a structured `quota_exceeded` refusal (with
//!   the limit as the hint), and migration consults the same ledger so
//!   replay cannot OOM a survivor.
//! * **Chaos sites.** With [`ReplicaConfig::faults`] set, every dispatch
//!   rolls the seeded `replica.crash` / `replica.wedge` sites: any
//!   injected fault kills (resp. wedges) the replica the round-robin
//!   cursor points at, so chaos tests kill replicas deterministically by
//!   seed.
//!
//! The [`Serving`] trait abstracts "something the TCP front end can serve
//! from" — implemented by both a bare [`Engine`] and a [`ReplicaSet`], so
//! the server (and its tests) work over either.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::backend::{InferBackend, NativeBackend, NativeModelConfig};
use super::engine::{Engine, EngineConfig};
use super::error::{ServeError, ServeResult};
use super::metrics::Metrics;
use super::request::{DecodeResponse, InferResponse, SessionOp, SessionReply};
use crate::kernels::Variant;
use crate::util::error::{err, Result};
use crate::util::faults::{Fault, FaultInjector};
use crate::util::json::Json;
use crate::util::sync::lock_recover;

/// Anything the serving front end can drive: blocking one-shot inference,
/// blocking session ops, metrics snapshots, and drain-then-shutdown.
/// Implemented by [`Engine`] (single replica, zero overhead) and
/// [`ReplicaSet`] (supervised replicas with failover).
pub trait Serving: Send + Sync {
    /// Expected token-sequence length for requests.
    fn seq_len(&self) -> usize;
    /// Logits per response.
    fn classes(&self) -> usize;
    /// Blocking one-shot inference with the typed outcome.
    fn infer_with(
        &self,
        tokens: Vec<i32>,
        variant: Option<Variant>,
        deadline: Option<Duration>,
    ) -> ServeResult<InferResponse>;
    /// Blocking session op (`Open`/`Decode`/`Close`) with the typed reply.
    fn session(&self, op: SessionOp, deadline: Option<Duration>) -> ServeResult<SessionReply>;
    /// Machine-readable metrics snapshot (the `{"op":"metrics"}` body).
    fn metrics_json(&self) -> Json;
    /// Readiness probe (the `{"op":"health"}` body): alive/configured
    /// counts plus per-replica
    /// `{slot, incarnation, alive, breaker_state, resident_tokens}` —
    /// cheap enough for load balancers to poll without parsing the full
    /// metrics report.
    fn health_json(&self) -> Json;
    /// Admin surface (the `{"op":"drain_replica"}` body): proactively
    /// migrate every live session off replica `slot`, then replace it
    /// with a fresh engine. Returns the number of sessions migrated;
    /// `Invalid` on a single-engine server or a bad slot.
    fn drain_replica(&self, slot: usize) -> ServeResult<usize>;
    /// Human-readable metrics report (printed at server exit).
    fn metrics_report(&self) -> String;
    /// Count one submission refused by a per-client quota.
    fn note_quota_rejected(&self);
    /// Stop admitting new work (first phase of drain).
    fn stop_admissions(&self);
    /// Drain-then-shutdown; idempotent.
    fn drain(&self);
}

impl Serving for Engine {
    fn seq_len(&self) -> usize {
        Engine::seq_len(self)
    }

    fn classes(&self) -> usize {
        Engine::classes(self)
    }

    fn infer_with(
        &self,
        tokens: Vec<i32>,
        variant: Option<Variant>,
        deadline: Option<Duration>,
    ) -> ServeResult<InferResponse> {
        let rx = self.submit(tokens, variant, deadline)?;
        match rx.recv() {
            Ok(outcome) => outcome,
            // Admitted work is always answered; a closed channel can only
            // mean shutdown raced us.
            Err(_) => Err(ServeError::ShuttingDown),
        }
    }

    fn session(&self, op: SessionOp, deadline: Option<Duration>) -> ServeResult<SessionReply> {
        let rx = self.submit_session(op, deadline)?;
        match rx.recv() {
            Ok(outcome) => outcome,
            Err(_) => Err(ServeError::ShuttingDown),
        }
    }

    fn metrics_json(&self) -> Json {
        self.metrics.to_json()
    }

    fn health_json(&self) -> Json {
        // A bare engine is one permanent pseudo-replica: incarnation 0,
        // breaker always closed (there is no dispatcher to trip one).
        let alive = self.alive();
        let resident = self.metrics.resident_tokens();
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("alive", Json::num(if alive { 1.0 } else { 0.0 })),
            ("configured", Json::num(1.0)),
            ("resident_tokens", Json::num(resident as f64)),
            (
                "replicas",
                Json::Arr(vec![Json::obj(vec![
                    ("slot", Json::num(0.0)),
                    ("incarnation", Json::num(0.0)),
                    ("alive", Json::Bool(alive)),
                    ("breaker_state", Json::str("closed")),
                    ("resident_tokens", Json::num(resident as f64)),
                ])]),
            ),
        ])
    }

    fn drain_replica(&self, slot: usize) -> ServeResult<usize> {
        Err(ServeError::Invalid(format!(
            "cannot drain replica {slot}: single-engine server (run with --replicas > 1)"
        )))
    }

    fn metrics_report(&self) -> String {
        self.metrics.report()
    }

    fn note_quota_rejected(&self) {
        self.metrics.record_quota_rejected();
    }

    fn stop_admissions(&self) {
        Engine::stop_admissions(self);
    }

    fn drain(&self) {
        self.shutdown();
    }
}

/// Replication policy of a [`ReplicaSet`].
#[derive(Debug, Clone)]
pub struct ReplicaConfig {
    /// Engine replicas to run (>= 1; each gets its own worker thread,
    /// batcher, session table and metrics shard).
    pub replicas: usize,
    /// Heartbeat staleness past which a live-but-silent replica counts as
    /// wedged (clamped to >= 100ms: a healthy idle worker ticks every
    /// ~50ms, and the interval must also exceed the worst-case batch
    /// latency). Also the supervisor's detection bound: no client waits
    /// on a wedged replica longer than roughly this plus one poll tick.
    pub watchdog: Duration,
    /// How many times one accepted one-shot request may be re-dispatched
    /// onto a sibling after its replica died mid-flight (0 = never; the
    /// death then surfaces as a structured `error` reply).
    pub retry_budget: usize,
    /// Consecutive dispatch failures that open a replica's circuit
    /// breaker.
    pub breaker_threshold: u32,
    /// How long an open breaker blocks dispatch before admitting one
    /// half-open probe.
    pub breaker_cooldown: Duration,
    /// Longest journal (prompt + decoded tokens) a dead replica's session
    /// may replay onto a sibling; a longer journal makes its session
    /// answer `session_lost` instead of migrating (0 disables migration
    /// outright — the earlier lazy-loss behaviour).
    pub replay_budget_tokens: usize,
    /// Global memory backpressure: journal-tracked resident tokens across
    /// all replicas past which `open` is refused with a structured
    /// `quota_exceeded` (and migration declines to replay). 0 = unlimited.
    pub max_resident_tokens: usize,
    /// Chaos hook: when set, every dispatch rolls the `replica.crash` /
    /// `replica.wedge` sites and any injected fault kills (resp. wedges)
    /// the replica under the round-robin cursor.
    pub faults: Option<Arc<FaultInjector>>,
}

impl Default for ReplicaConfig {
    fn default() -> Self {
        ReplicaConfig {
            replicas: 1,
            watchdog: Duration::from_millis(500),
            retry_budget: 2,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(250),
            replay_budget_tokens: 4096,
            max_resident_tokens: 0,
            faults: None,
        }
    }
}

/// Consecutive-failure circuit breaker: Closed → (threshold failures) →
/// Open → (cooldown) → HalfOpen probe → Closed on success / Open on
/// failure. A half-open probe whose outcome never arrives (the client
/// abandoned its wait) unblocks after another full cooldown.
#[derive(Debug, Clone, Copy)]
enum BreakerState {
    Closed,
    Open { since: Instant },
    HalfOpen { since: Instant },
}

#[derive(Debug, Clone, Copy)]
struct Breaker {
    consecutive: u32,
    state: BreakerState,
}

impl Breaker {
    fn new() -> Breaker {
        Breaker { consecutive: 0, state: BreakerState::Closed }
    }

    /// May this replica receive a dispatch right now? Transitions an
    /// expired Open into the half-open probe as a side effect.
    fn admit(&mut self, cooldown: Duration) -> bool {
        match self.state {
            BreakerState::Closed => true,
            BreakerState::Open { since } | BreakerState::HalfOpen { since } => {
                if since.elapsed() >= cooldown {
                    self.state = BreakerState::HalfOpen { since: Instant::now() };
                    true
                } else {
                    // Open and still cooling, or a probe is already out.
                    false
                }
            }
        }
    }

    fn success(&mut self) {
        self.consecutive = 0;
        self.state = BreakerState::Closed;
    }

    fn failure(&mut self, threshold: u32) {
        self.consecutive = self.consecutive.saturating_add(1);
        if matches!(self.state, BreakerState::HalfOpen { .. })
            || self.consecutive >= threshold.max(1)
        {
            self.state = BreakerState::Open { since: Instant::now() };
        }
    }

    /// Stable wire name of the current state (the health probe's
    /// `breaker_state` field).
    fn state_name(&self) -> &'static str {
        match self.state {
            BreakerState::Closed => "closed",
            BreakerState::Open { .. } => "open",
            BreakerState::HalfOpen { .. } => "half_open",
        }
    }
}

/// One replica slot: the live engine, its incarnation (bumped per
/// respawn, so stale session routes and breaker notes can't touch a
/// fresh replica), and its breaker.
struct Slot {
    engine: Arc<Engine>,
    incarnation: u64,
    breaker: Breaker,
}

/// Replica-independent record of everything needed to rebuild a decode
/// session's KV cache from scratch: the prompt, every token decoded so
/// far (appended on each successful decode reply), and the pinned
/// variant. By the determinism guarantee (same `KernelRegistry` preload
/// on every replica) replaying these tokens through the kernel-free
/// `Reopen` path reconstructs the cache **bitwise** — the journal is the
/// session's durable identity, the cache just a materialization.
#[derive(Debug, Clone)]
pub struct SessionJournal {
    prompt: Vec<i32>,
    decoded: Vec<i32>,
    variant: Variant,
}

impl SessionJournal {
    /// Tokens a replay of this journal would make resident.
    fn tokens(&self) -> usize {
        self.prompt.len() + self.decoded.len()
    }
}

/// Where a global session id lives: which slot, which incarnation of it,
/// the engine-local session id, and the journal that can rebuild it
/// anywhere.
struct SessionRoute {
    slot: usize,
    incarnation: u64,
    inner: u64,
    journal: SessionJournal,
}

/// The global route table plus a running resident-token ledger (the sum
/// of every route's journal length), maintained on insert/append/remove
/// so budget checks never walk the map.
struct RouteTable {
    map: HashMap<u64, SessionRoute>,
    resident: u64,
}

impl RouteTable {
    fn new() -> RouteTable {
        RouteTable { map: HashMap::new(), resident: 0 }
    }

    fn insert(&mut self, global: u64, route: SessionRoute) {
        self.resident += route.journal.tokens() as u64;
        self.map.insert(global, route);
    }

    fn remove(&mut self, global: u64) -> Option<SessionRoute> {
        let route = self.map.remove(&global);
        if let Some(r) = &route {
            self.resident -= r.journal.tokens() as u64;
        }
        route
    }

    /// Journal one successfully decoded token.
    fn append_decoded(&mut self, global: u64, token: i32) {
        if let Some(r) = self.map.get_mut(&global) {
            r.journal.decoded.push(token);
            self.resident += 1;
        }
    }
}

/// State shared between the handle, the dispatcher and the supervisor.
struct Inner {
    slots: Mutex<Vec<Slot>>,
    sessions: Mutex<RouteTable>,
    factory: Arc<dyn Fn() -> Result<Box<dyn InferBackend>> + Send + Sync>,
    engine_cfg: EngineConfig,
    cfg: ReplicaConfig,
    metrics: Arc<Metrics>,
    /// Round-robin dispatch cursor (also the chaos sites' victim pointer).
    rr: AtomicUsize,
    next_session: AtomicU64,
    /// Supervisor liveness; flipped by shutdown *before* engines drain so
    /// the supervisor never respawns a draining replica.
    running: AtomicBool,
    accepting: AtomicBool,
    seq_len: usize,
    classes: usize,
}

/// Handle to a supervised set of engine replicas. See module docs.
pub struct ReplicaSet {
    inner: Arc<Inner>,
    supervisor: Mutex<Option<JoinHandle<()>>>,
}

/// A route-table lookup's outcome: a live target to forward to, or the
/// dead/respawned owner a migration (or a local close) must deal with.
enum Routed {
    Live(Arc<Engine>, usize, u64, u64),
    Dead { slot: usize, incarnation: u64 },
}

/// Spawn one replica from the shared factory (same registry/spec preload
/// as every sibling — a respawn serves bit-identical logits).
fn spawn_replica(
    factory: &Arc<dyn Fn() -> Result<Box<dyn InferBackend>> + Send + Sync>,
    engine_cfg: &EngineConfig,
) -> Result<Arc<Engine>> {
    let factory = factory.clone();
    Engine::start_with(move || factory(), engine_cfg.clone()).map(Arc::new)
}

/// Pick a dispatch target: round-robin over slots that are alive,
/// accepting, and admitted by their breaker. `exclude` skips the replica
/// a retry just died on (ignored when it is the only slot).
fn pick(inner: &Inner, exclude: Option<usize>) -> ServeResult<(usize, u64, Arc<Engine>)> {
    let mut slots = lock_recover(&inner.slots);
    let n = slots.len();
    let start = inner.rr.fetch_add(1, Ordering::Relaxed);
    for k in 0..n {
        let i = (start + k) % n;
        if exclude == Some(i) && n > 1 {
            continue;
        }
        let slot = &mut slots[i];
        if !slot.engine.alive() || !slot.engine.accepting() {
            continue;
        }
        if !slot.breaker.admit(inner.cfg.breaker_cooldown) {
            continue;
        }
        return Ok((i, slot.incarnation, slot.engine.clone()));
    }
    // Every replica is dead, draining or breaker-blocked: a structured
    // refusal with the watchdog as the retry hint (by then the supervisor
    // will have respawned something).
    inner.metrics.record_rejected(1);
    Err(ServeError::Overloaded {
        retry_after_ms: inner.cfg.watchdog.as_millis() as u64,
    })
}

/// Note a dispatch outcome on a slot's breaker — only if the slot still
/// holds the incarnation the dispatch went to (a respawned replica must
/// not inherit its predecessor's failures).
fn note(inner: &Inner, slot: usize, incarnation: u64, ok: bool) {
    let mut slots = lock_recover(&inner.slots);
    if let Some(s) = slots.get_mut(slot) {
        if s.incarnation == incarnation {
            if ok {
                s.breaker.success();
            } else {
                s.breaker.failure(inner.cfg.breaker_threshold);
            }
        }
    }
}

/// Roll the seeded chaos sites once per dispatch: any injected fault at
/// `replica.crash` kills — and at `replica.wedge` wedges — the replica
/// the round-robin cursor currently points at.
fn chaos_roll(inner: &Inner) {
    let Some(faults) = &inner.cfg.faults else {
        return;
    };
    let victim = |inner: &Inner| -> Option<Arc<Engine>> {
        let slots = lock_recover(&inner.slots);
        if slots.is_empty() {
            return None;
        }
        let i = inner.rr.load(Ordering::Relaxed) % slots.len();
        Some(slots[i].engine.clone())
    };
    if faults.roll("replica.crash") != Fault::None {
        if let Some(e) = victim(inner) {
            e.inject_crash();
        }
    }
    if faults.roll("replica.wedge") != Fault::None {
        if let Some(e) = victim(inner) {
            e.inject_wedge();
        }
    }
}

/// Drop a lost session's route (releasing its ledger tokens), count it,
/// and reply `SessionLost`.
fn lost(inner: &Inner, session: u64) -> ServeError {
    lock_recover(&inner.sessions).remove(session);
    inner.metrics.record_session_lost();
    refresh_session_gauges(inner);
    ServeError::SessionLost { session }
}

/// A migration that could not complete: counted under `migration_failed`,
/// then the session converts to the structured loss — the **only** path
/// that records `session_lost` now that recoverable sessions migrate.
fn lost_migration(inner: &Inner, session: u64) -> ServeError {
    inner.metrics.record_migration_failed();
    lost(inner, session)
}

/// Refresh the set-level session gauges (live routes, journal-resident
/// tokens) from the route-table ledger.
fn refresh_session_gauges(inner: &Inner) {
    let routes = lock_recover(&inner.sessions);
    let (active, resident) = (routes.map.len(), routes.resident as usize);
    drop(routes);
    inner.metrics.set_session_gauges(active, resident, 0);
}

/// Rebuild session `session` — whose owner `(slot, incarnation)` in
/// `from` is dead or being drained — on a healthy sibling by replaying
/// its journal through the kernel-free `Reopen` path. On success the
/// route is updated in place and the new `(engine, slot, incarnation,
/// local id)` target is returned; the caller re-issues its op there.
/// Refused (→ `migration_failed` + `session_lost`) when the journal
/// exceeds [`ReplicaConfig::replay_budget_tokens`], the resident-token
/// ledger is past [`ReplicaConfig::max_resident_tokens`], no healthy
/// sibling admits the replay, or the replay itself dies. `deadline` is
/// the op's remaining budget, so a migration can never outlive the op
/// that triggered it.
///
/// With `defer_loss` (the proactive teardown/drain path) a refusal
/// leaves the route and counters untouched: the session stays parked on
/// the dead incarnation and the *client's* next op retries the
/// migration lazily — by then a sibling may have respawned — or
/// converts it, so the `session_lost` count always matches a structured
/// reply some client actually received.
fn migrate(
    inner: &Inner,
    session: u64,
    from: (usize, u64),
    deadline: Option<Duration>,
    defer_loss: bool,
) -> ServeResult<(Arc<Engine>, usize, u64, u64)> {
    let fail = || {
        if defer_loss {
            ServeError::SessionLost { session }
        } else {
            lost_migration(inner, session)
        }
    };
    let journal = {
        let routes = lock_recover(&inner.sessions);
        match routes.map.get(&session) {
            Some(r) if (r.slot, r.incarnation) == from => r.journal.clone(),
            // A concurrent migration already moved it: hand back the
            // fresh route if it is live, else convert.
            Some(r) => {
                let (slot, incarnation, local) = (r.slot, r.incarnation, r.inner);
                drop(routes);
                let slots = lock_recover(&inner.slots);
                return match slots.get(slot) {
                    Some(s) if s.incarnation == incarnation && s.engine.alive() => {
                        Ok((s.engine.clone(), slot, incarnation, local))
                    }
                    _ => {
                        drop(slots);
                        Err(fail())
                    }
                };
            }
            None => return Err(ServeError::Failed(err!("unknown session {session}"))),
        }
    };
    let replay = journal.tokens();
    if replay > inner.cfg.replay_budget_tokens {
        crate::log_error!(
            "session {session}: journal of {replay} tokens exceeds the replay budget ({}); lost",
            inner.cfg.replay_budget_tokens
        );
        return Err(fail());
    }
    // Memory pressure: the ledger still counts this session (its route is
    // intact), so being past the budget means the survivors are already
    // over-committed — replaying onto one would deepen the overshoot.
    if inner.cfg.max_resident_tokens > 0 {
        let resident = lock_recover(&inner.sessions).resident;
        if resident > inner.cfg.max_resident_tokens as u64 {
            crate::log_error!(
                "session {session}: resident ledger {resident} past budget ({}); not replaying",
                inner.cfg.max_resident_tokens
            );
            return Err(fail());
        }
    }
    // `pick` skips dead/draining/breaker-blocked replicas on its own; the
    // explicit exclude covers a *wedged* owner (alive but frozen), which
    // would otherwise swallow the replay until its teardown.
    let (slot, incarnation, engine) = match pick(inner, Some(from.0)) {
        // `pick` ignores the exclude on a single-slot set: landing back
        // on the dead/wedged incarnation itself means there is no
        // sibling to migrate to (a *respawned* same slot — bumped
        // incarnation — is a legitimate target).
        Ok(t) if (t.0, t.1) == from => return Err(fail()),
        Ok(t) => t,
        Err(_) => return Err(fail()),
    };
    let op = SessionOp::Reopen {
        prompt: journal.prompt.clone(),
        decoded: journal.decoded.clone(),
        variant: journal.variant,
    };
    match forward(inner, &engine, slot, incarnation, op, deadline) {
        Some(Ok(SessionReply::Opened { session: local, .. })) => {
            let mut routes = lock_recover(&inner.sessions);
            match routes.map.get_mut(&session) {
                Some(r) if (r.slot, r.incarnation) == from => {
                    r.slot = slot;
                    r.incarnation = incarnation;
                    r.inner = local;
                    drop(routes);
                    inner.metrics.record_session_migrated(replay as u64);
                    Ok((engine, slot, incarnation, local))
                }
                _ => {
                    // Closed or re-migrated while we replayed (the
                    // supervisor's proactive sweep can race a client's
                    // lazy migration of the same session): this copy is
                    // an orphan — release it and hand back the table's
                    // current truth so the race stays invisible.
                    let current =
                        routes.map.get(&session).map(|r| (r.slot, r.incarnation, r.inner));
                    drop(routes);
                    let close = SessionOp::Close { session: local };
                    let _ = forward(inner, &engine, slot, incarnation, close, None);
                    match current {
                        Some((s2, i2, l2)) => {
                            let slots = lock_recover(&inner.slots);
                            match slots.get(s2) {
                                Some(sl) if sl.incarnation == i2 && sl.engine.alive() => {
                                    Ok((sl.engine.clone(), s2, i2, l2))
                                }
                                _ => {
                                    drop(slots);
                                    Err(fail())
                                }
                            }
                        }
                        None => Err(ServeError::Failed(err!(
                            "session {session} closed during migration"
                        ))),
                    }
                }
            }
        }
        _ => Err(fail()),
    }
}

/// Proactively migrate every session routed to `(slot, incarnation)` —
/// the supervisor's teardown path and [`ReplicaSet::drain_replica`]'s
/// workhorse. Returns how many sessions moved. Failures defer: the
/// route stays parked on the dead incarnation and converts (or retries
/// the migration) on the client's next op, so no session is counted
/// lost without a client receiving the structured reply.
fn migrate_all(inner: &Inner, slot: usize, incarnation: u64) -> usize {
    // Migration disabled: skip the scan (and its per-session logging)
    // entirely — every route converts lazily, the pre-durability
    // behaviour.
    if inner.cfg.replay_budget_tokens == 0 {
        return 0;
    }
    let victims: Vec<u64> = {
        let routes = lock_recover(&inner.sessions);
        routes
            .map
            .iter()
            .filter(|(_, r)| r.slot == slot && r.incarnation == incarnation)
            .map(|(&g, _)| g)
            .collect()
    };
    let mut moved = 0usize;
    for session in victims {
        if migrate(inner, session, (slot, incarnation), None, true).is_ok() {
            moved += 1;
        }
    }
    moved
}

/// Supervisor loop: watch heartbeats, tear down crashed/wedged replicas,
/// respawn, and keep the alive gauge fresh.
fn supervise(inner: Arc<Inner>) {
    let watchdog = inner.cfg.watchdog;
    let poll = (watchdog / 4).clamp(Duration::from_millis(5), Duration::from_millis(50));
    let n = inner.cfg.replicas;
    let now = Instant::now();
    let mut seen: Vec<(u64, Instant)> = {
        let slots = lock_recover(&inner.slots);
        slots.iter().map(|s| (s.engine.tick(), now)).collect()
    };
    // Which incarnation's death was already counted per slot, so a failed
    // respawn (corpse lingers, retried next sweep) counts one crash.
    let mut counted: Vec<Option<u64>> = vec![None; n];
    while inner.running.load(Ordering::SeqCst) {
        std::thread::sleep(poll);
        if !inner.running.load(Ordering::SeqCst) {
            break;
        }
        let mut alive = 0usize;
        for i in 0..n {
            let (engine, incarnation) = {
                let slots = lock_recover(&inner.slots);
                (slots[i].engine.clone(), slots[i].incarnation)
            };
            let tick = engine.tick();
            let now = Instant::now();
            if tick != seen[i].0 {
                seen[i] = (tick, now);
            }
            let dead = !engine.alive();
            let wedged = !dead && now.duration_since(seen[i].1) > watchdog;
            if !(dead || wedged) {
                alive += 1;
                continue;
            }
            if counted[i] != Some(incarnation) {
                counted[i] = Some(incarnation);
                inner.metrics.record_replica_crash();
                crate::log_error!(
                    "replica {i} (incarnation {incarnation}) {}; tearing down",
                    if dead { "crashed" } else { "wedged" }
                );
            }
            // Proactive migration BEFORE teardown: every session routed
            // to the dying incarnation is rebuilt on a healthy sibling
            // from its journal, so sessions survive even when no op
            // happens to touch them mid-failure. Refusals (budget, no
            // sibling) defer: the route stays parked and the client's
            // next op retries or converts it. Any route that races past
            // this sweep migrates lazily the same way.
            let moved = migrate_all(&inner, i, incarnation);
            if moved > 0 {
                crate::log_error!(
                    "replica {i} (incarnation {incarnation}): migrated {moved} session(s) to siblings"
                );
            }
            // Tear down: joins the worker (a wedged one exits on the
            // running flip inside shutdown), dropping every parked reply
            // channel — waiting clients fail over or migrate instead of
            // hanging.
            engine.shutdown();
            match spawn_replica(&inner.factory, &inner.engine_cfg) {
                Ok(fresh) => {
                    let mut slots = lock_recover(&inner.slots);
                    seen[i] = (fresh.tick(), Instant::now());
                    slots[i] = Slot {
                        engine: fresh,
                        incarnation: incarnation + 1,
                        breaker: Breaker::new(),
                    };
                    drop(slots);
                    inner.metrics.record_replica_respawn();
                    alive += 1;
                }
                Err(e) => {
                    // Leave the corpse; the next sweep retries the respawn
                    // (its crash is already counted).
                    crate::log_error!("respawning replica {i}: {e}");
                }
            }
        }
        inner.metrics.set_replica_gauges(alive, n);
    }
}

/// An accepted one-shot dispatch: hold it and [`PendingInfer::wait`] for
/// the typed outcome. Submissions stay pipelined (submit a burst, then
/// wait each); the failover retry runs inside `wait`.
pub struct PendingInfer<'a> {
    inner: &'a Inner,
    rx: std::sync::mpsc::Receiver<ServeResult<InferResponse>>,
    slot: usize,
    incarnation: u64,
    resubmit: Option<Resubmit>,
}

/// What a retry needs to re-dispatch the request on a sibling.
struct Resubmit {
    tokens: Vec<i32>,
    variant: Option<Variant>,
    deadline: Option<Duration>,
    t0: Instant,
    attempts: usize,
}

impl PendingInfer<'_> {
    /// Block for the typed outcome. A reply channel that drops without an
    /// answer means the replica died mid-flight: the request is
    /// re-dispatched on a healthy sibling (up to the retry budget, with
    /// the original deadline budget spanning attempts, each retry counted
    /// under `retried`) — the served reply still counts exactly once.
    pub fn wait(mut self) -> ServeResult<InferResponse> {
        loop {
            match self.rx.recv() {
                Ok(Ok(resp)) => {
                    note(self.inner, self.slot, self.incarnation, true);
                    return Ok(resp);
                }
                Ok(Err(e)) => {
                    if matches!(e, ServeError::Failed(_)) {
                        note(self.inner, self.slot, self.incarnation, false);
                    }
                    return Err(e);
                }
                Err(_) => {
                    note(self.inner, self.slot, self.incarnation, false);
                    let Some(r) = self.resubmit.as_mut() else {
                        return Err(ServeError::Failed(err!(
                            "replica died before replying (no failover sibling)"
                        )));
                    };
                    if r.attempts >= self.inner.cfg.retry_budget {
                        return Err(ServeError::Failed(err!(
                            "replica died before replying; retry budget ({}) exhausted",
                            self.inner.cfg.retry_budget
                        )));
                    }
                    r.attempts += 1;
                    let deadline = match r.deadline {
                        Some(budget) => {
                            let remaining = budget.saturating_sub(r.t0.elapsed());
                            if remaining.is_zero() {
                                return Err(ServeError::Expired {
                                    waited_ms: r.t0.elapsed().as_millis() as u64,
                                });
                            }
                            Some(remaining)
                        }
                        None => None,
                    };
                    let (slot, incarnation, engine) = pick(self.inner, Some(self.slot))?;
                    match engine.submit(r.tokens.clone(), r.variant, deadline) {
                        Ok(rx) => {
                            self.inner.metrics.record_retried();
                            self.rx = rx;
                            self.slot = slot;
                            self.incarnation = incarnation;
                        }
                        Err(e) => return Err(e),
                    }
                }
            }
        }
    }
}

impl ReplicaSet {
    /// Start `cfg.replicas` engines over a backend factory — `Fn`, not
    /// `FnOnce`, because the supervisor re-invokes it to respawn a dead
    /// replica with the same registry/spec preload.
    pub fn start_with<F>(
        factory: F,
        engine_cfg: EngineConfig,
        mut cfg: ReplicaConfig,
    ) -> Result<ReplicaSet>
    where
        F: Fn() -> Result<Box<dyn InferBackend>> + Send + Sync + 'static,
    {
        cfg.replicas = cfg.replicas.max(1);
        cfg.watchdog = cfg.watchdog.max(Duration::from_millis(100));
        let factory: Arc<dyn Fn() -> Result<Box<dyn InferBackend>> + Send + Sync> =
            Arc::new(factory);
        let mut slots = Vec::with_capacity(cfg.replicas);
        let mut shape = (0usize, 0usize);
        for i in 0..cfg.replicas {
            match spawn_replica(&factory, &engine_cfg) {
                Ok(engine) => {
                    shape = (engine.seq_len(), engine.classes());
                    slots.push(Slot { engine, incarnation: 0, breaker: Breaker::new() });
                }
                Err(e) => {
                    for s in &slots {
                        s.engine.shutdown();
                    }
                    return Err(e.context(format!("starting replica {i}")));
                }
            }
        }
        let inner = Arc::new(Inner {
            slots: Mutex::new(slots),
            sessions: Mutex::new(RouteTable::new()),
            factory,
            engine_cfg,
            cfg,
            metrics: Arc::new(Metrics::new()),
            rr: AtomicUsize::new(0),
            next_session: AtomicU64::new(0),
            running: AtomicBool::new(true),
            accepting: AtomicBool::new(true),
            seq_len: shape.0,
            classes: shape.1,
        });
        inner
            .metrics
            .set_replica_gauges(inner.cfg.replicas, inner.cfg.replicas);
        let supervisor = {
            let inner = inner.clone();
            std::thread::Builder::new()
                .name("dsa-replica-supervisor".to_string())
                .spawn(move || supervise(inner))
                .map_err(|e| err!("spawning replica supervisor: {e}"))?
        };
        Ok(ReplicaSet { inner, supervisor: Mutex::new(Some(supervisor)) })
    }

    /// Start a replicated set of hermetic native-kernel engines.
    pub fn start_native(
        model: NativeModelConfig,
        engine_cfg: EngineConfig,
        cfg: ReplicaConfig,
    ) -> Result<ReplicaSet> {
        ReplicaSet::start_with(move || NativeBackend::boxed(model.clone()), engine_cfg, cfg)
    }

    /// Expected token-sequence length for requests.
    pub fn seq_len(&self) -> usize {
        self.inner.seq_len
    }

    /// Logits per response.
    pub fn classes(&self) -> usize {
        self.inner.classes
    }

    /// Configured replica count.
    pub fn replicas(&self) -> usize {
        self.inner.cfg.replicas
    }

    /// Replicas whose worker is currently running.
    pub fn alive_replicas(&self) -> usize {
        lock_recover(&self.inner.slots)
            .iter()
            .filter(|s| s.engine.alive())
            .count()
    }

    /// Replica-level metrics (the `replicas` section plus set-level
    /// refusals); per-replica shards ride under `shards` in
    /// [`ReplicaSet::metrics_to_json`].
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.inner.metrics
    }

    /// Dispatch one one-shot request to a healthy replica; call
    /// [`PendingInfer::wait`] for the outcome (failover retries happen
    /// there). The chaos sites roll here, once per dispatch.
    pub fn submit(
        &self,
        mut tokens: Vec<i32>,
        variant: Option<Variant>,
        deadline: Option<Duration>,
    ) -> ServeResult<PendingInfer<'_>> {
        let inner = &*self.inner;
        if !inner.accepting.load(Ordering::SeqCst) {
            return Err(ServeError::ShuttingDown);
        }
        chaos_roll(inner);
        // Failover needs its own copy of the tokens (the engine consumes
        // them); skip the clone when no retry could ever use it.
        let mut resubmit = if inner.cfg.retry_budget > 0 && inner.cfg.replicas > 1 {
            Some(Resubmit {
                tokens: tokens.clone(),
                variant,
                deadline,
                t0: Instant::now(),
                attempts: 0,
            })
        } else {
            None
        };
        let mut exclude = None;
        let mut tries = 0usize;
        loop {
            let (slot, incarnation, engine) = pick(inner, exclude)?;
            let payload = match &resubmit {
                Some(r) => r.tokens.clone(),
                None => std::mem::take(&mut tokens),
            };
            match engine.submit(payload, variant, deadline) {
                Ok(rx) => {
                    return Ok(PendingInfer {
                        inner,
                        rx,
                        slot,
                        incarnation,
                        resubmit: resubmit.take(),
                    })
                }
                // The replica's channel died under us (crash racing the
                // dispatch) while the set is still accepting: fail over
                // pre-acceptance — not counted as `retried` (the request
                // was never accepted anywhere) but under
                // `failover_races`, so the accounting identity has no
                // invisible path.
                Err(ServeError::ShuttingDown)
                    if inner.accepting.load(Ordering::SeqCst)
                        && resubmit.is_some()
                        && tries + 1 < inner.cfg.replicas =>
                {
                    inner.metrics.record_failover_race();
                    note(inner, slot, incarnation, false);
                    exclude = Some(slot);
                    tries += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Blocking one-shot inference (submit + wait, including failover).
    pub fn infer(&self, tokens: Vec<i32>, variant: Option<Variant>) -> ServeResult<InferResponse> {
        self.submit(tokens, variant, None)?.wait()
    }

    /// Open a decode session on a healthy replica (blocking); returns
    /// `(global session id, resident tokens, pinned variant)`. The
    /// session is sticky but durable: ops route to the owning replica,
    /// and if that replica dies the session migrates to a sibling by
    /// journal replay (falling back to `session_lost` only when the
    /// replay budget, siblings, or the memory budget are exhausted).
    pub fn open_session(
        &self,
        prompt: Vec<i32>,
        variant: Option<Variant>,
    ) -> ServeResult<(u64, usize, Variant)> {
        match self.session_impl(SessionOp::Open { prompt, variant }, None)? {
            SessionReply::Opened { session, resident, variant } => {
                Ok((session, resident, variant))
            }
            other => Err(ServeError::Failed(err!(
                "replica returned mismatched session reply {other:?}"
            ))),
        }
    }

    /// Run one decode step on an open session (blocking).
    pub fn decode(&self, session: u64, token: i32) -> ServeResult<DecodeResponse> {
        match self.session_impl(SessionOp::Decode { session, token }, None)? {
            SessionReply::Decoded(resp) => Ok(resp),
            other => Err(ServeError::Failed(err!(
                "replica returned mismatched session reply {other:?}"
            ))),
        }
    }

    /// Close a session (blocking), releasing its replica-side cache.
    pub fn close_session(&self, session: u64) -> ServeResult<usize> {
        match self.session_impl(SessionOp::Close { session }, None)? {
            SessionReply::Closed { released, .. } => Ok(released),
            other => Err(ServeError::Failed(err!(
                "replica returned mismatched session reply {other:?}"
            ))),
        }
    }

    /// Session dispatch: translate global ↔ engine-local ids, keep the
    /// route table (and its journal/ledger) honest, and convert replica
    /// deaths into transparent migration — falling back to `SessionLost`
    /// only when migration is exhausted.
    fn session_impl(
        &self,
        op: SessionOp,
        deadline: Option<Duration>,
    ) -> ServeResult<SessionReply> {
        let inner = &*self.inner;
        if !inner.accepting.load(Ordering::SeqCst) {
            return Err(ServeError::ShuttingDown);
        }
        chaos_roll(inner);
        match op {
            SessionOp::Open { prompt, variant } => {
                // Global memory backpressure first: admitting past the
                // resident-token budget is refused with the limit as the
                // hint, before any replica does prefill work.
                if inner.cfg.max_resident_tokens > 0 {
                    let resident = lock_recover(&inner.sessions).resident;
                    if resident + prompt.len() as u64 > inner.cfg.max_resident_tokens as u64 {
                        inner.metrics.record_resident_budget_rejected();
                        return Err(ServeError::QuotaExceeded {
                            what: "resident tokens",
                            limit: inner.cfg.max_resident_tokens as u64,
                        });
                    }
                }
                let (slot, incarnation, engine) = pick(inner, None)?;
                let journal_prompt = prompt.clone();
                let op = SessionOp::Open { prompt, variant };
                let reply = forward(inner, &engine, slot, incarnation, op, deadline)
                    .ok_or_else(|| {
                        // Died during open: no session was established,
                        // so this is a plain structured failure, not a
                        // lost session.
                        ServeError::Failed(err!("replica died during session open"))
                    })?;
                match reply {
                    Ok(SessionReply::Opened { session: local, resident, variant }) => {
                        let global = inner.next_session.fetch_add(1, Ordering::Relaxed) + 1;
                        lock_recover(&inner.sessions).insert(global, SessionRoute {
                            slot,
                            incarnation,
                            inner: local,
                            journal: SessionJournal {
                                prompt: journal_prompt,
                                decoded: Vec::new(),
                                variant,
                            },
                        });
                        refresh_session_gauges(inner);
                        Ok(SessionReply::Opened { session: global, resident, variant })
                    }
                    other => other,
                }
            }
            SessionOp::Decode { session, token } => {
                // route() migrates a dead owner before returning, so the
                // target here is always live (or the `?` already answered
                // a structured error).
                let Routed::Live(engine, slot, incarnation, local) =
                    self.route(session, deadline)?
                else {
                    return Err(ServeError::Failed(err!(
                        "session {session}: route() returned a dead target"
                    )));
                };
                let op = SessionOp::Decode { session: local, token };
                let reply = match forward(inner, &engine, slot, incarnation, op, deadline) {
                    Some(r) => r,
                    // The owner died with the step in flight: migrate
                    // (replaying the journal, which does NOT yet contain
                    // this token) and re-issue the step exactly once on
                    // the new owner.
                    None => {
                        let (engine, slot, incarnation, local) =
                            migrate(inner, session, (slot, incarnation), deadline, false)?;
                        let op = SessionOp::Decode { session: local, token };
                        forward(inner, &engine, slot, incarnation, op, deadline)
                            .ok_or_else(|| lost_migration(inner, session))?
                    }
                };
                match reply {
                    Ok(SessionReply::Decoded(mut resp)) => {
                        resp.session = session;
                        // Journal the token only after the step served:
                        // a refused/failed step must not pollute replay.
                        lock_recover(&inner.sessions).append_decoded(session, token);
                        refresh_session_gauges(inner);
                        Ok(SessionReply::Decoded(resp))
                    }
                    other => other,
                }
            }
            SessionOp::Close { session } => {
                let routed = self.route_for_close(session)?;
                let reply = match routed {
                    Routed::Live(engine, slot, incarnation, local) => {
                        let op = SessionOp::Close { session: local };
                        forward(inner, &engine, slot, incarnation, op, deadline)
                    }
                    // Dead owner: nothing to release remotely — the cache
                    // died with the replica. Closing is journal removal.
                    Routed::Dead { .. } => None,
                };
                // Served, refused, or died mid-close: the client
                // relinquished the id either way — drop the route and
                // release its ledger tokens.
                let journaled = lock_recover(&inner.sessions)
                    .remove(session)
                    .map(|r| r.journal.tokens())
                    .unwrap_or(0);
                refresh_session_gauges(inner);
                match reply {
                    Some(Ok(SessionReply::Closed { released, .. })) => {
                        Ok(SessionReply::Closed { session, released })
                    }
                    Some(other) => other,
                    // No live owner answered; the journal is the releasable
                    // truth. Never `session_lost`: the client asked for the
                    // session to end, and it did.
                    None => Ok(SessionReply::Closed { session, released: journaled }),
                }
            }
            // Reopen is the dispatcher's own migration vehicle; clients
            // re-establish state by opening a fresh session.
            SessionOp::Reopen { .. } => Err(ServeError::Invalid(
                "reopen is internal to session migration".to_string(),
            )),
        }
    }

    /// Resolve a global session id to its live replica; a dead or
    /// respawned owner triggers transparent migration (bounded by the
    /// replay budget and `deadline`), so the caller only ever sees a live
    /// target or a structured error (`SessionLost` when migration is
    /// exhausted, "unknown session" when never routed).
    fn route(&self, session: u64, deadline: Option<Duration>) -> ServeResult<Routed> {
        match self.route_for_close(session)? {
            live @ Routed::Live(..) => Ok(live),
            Routed::Dead { slot, incarnation } => {
                let (engine, slot, incarnation, local) =
                    migrate(&self.inner, session, (slot, incarnation), deadline, false)?;
                Ok(Routed::Live(engine, slot, incarnation, local))
            }
        }
    }

    /// Route lookup without the migration side effect: `Close` wants a
    /// dead owner reported as-is (closing a dead session succeeds locally
    /// off the journal; replaying it just to close it would be absurd).
    fn route_for_close(&self, session: u64) -> ServeResult<Routed> {
        let inner = &*self.inner;
        let (slot_idx, incarnation, local) = {
            let sessions = lock_recover(&inner.sessions);
            match sessions.map.get(&session) {
                Some(r) => (r.slot, r.incarnation, r.inner),
                None => {
                    return Err(ServeError::Failed(err!("unknown session {session}")));
                }
            }
        };
        {
            let slots = lock_recover(&inner.slots);
            if let Some(s) = slots.get(slot_idx) {
                if s.incarnation == incarnation && s.engine.alive() {
                    return Ok(Routed::Live(s.engine.clone(), slot_idx, incarnation, local));
                }
            }
        }
        Ok(Routed::Dead { slot: slot_idx, incarnation })
    }

    /// Stop admitting new work across the set (and on every replica).
    pub fn stop_admissions(&self) {
        self.inner.accepting.store(false, Ordering::SeqCst);
        for s in lock_recover(&self.inner.slots).iter() {
            s.engine.stop_admissions();
        }
    }

    /// Whether the set still admits new work.
    pub fn accepting(&self) -> bool {
        self.inner.accepting.load(Ordering::SeqCst)
    }

    /// Chaos/test hook: crash replica `idx` (worker exits without
    /// draining). The supervisor detects and respawns it.
    pub fn inject_crash(&self, idx: usize) {
        let slots = lock_recover(&self.inner.slots);
        if !slots.is_empty() {
            slots[idx % slots.len()].engine.inject_crash();
        }
    }

    /// Chaos/test hook: wedge replica `idx` (heartbeat freezes until the
    /// watchdog tears it down).
    pub fn inject_wedge(&self, idx: usize) {
        let slots = lock_recover(&self.inner.slots);
        if !slots.is_empty() {
            slots[idx % slots.len()].engine.inject_wedge();
        }
    }

    /// Graceful drain-and-rebalance: stop replica `idx` from accepting,
    /// migrate every session it owns onto siblings (journal replay —
    /// bitwise-identical caches), then swap in a fresh engine from the
    /// factory and retire the old one. The building block for live
    /// reconfig and rolling kernel swaps: sessions and in-flight work
    /// survive, and the swap is counted as a `respawn`, not a crash.
    /// Returns the number of sessions migrated.
    pub fn drain_replica(&self, idx: usize) -> ServeResult<usize> {
        let inner = &*self.inner;
        if !inner.accepting.load(Ordering::SeqCst) {
            return Err(ServeError::ShuttingDown);
        }
        let n = lock_recover(&inner.slots).len();
        if idx >= n {
            return Err(ServeError::Invalid(format!(
                "no replica slot {idx} (configured {n})"
            )));
        }
        if n == 1 {
            return Err(ServeError::Invalid(
                "cannot drain the only replica (sessions would have no sibling)".to_string(),
            ));
        }
        let (old, incarnation) = {
            let slots = lock_recover(&inner.slots);
            (slots[idx].engine.clone(), slots[idx].incarnation)
        };
        // Admissions off first so the dispatcher stops routing new opens
        // here, then move the live sessions while the old engine still
        // answers its accepted work.
        old.stop_admissions();
        let moved = migrate_all(inner, idx, incarnation);
        match spawn_replica(&inner.factory, &inner.engine_cfg) {
            Ok(fresh) => {
                {
                    let mut slots = lock_recover(&inner.slots);
                    // The supervisor may have raced a teardown of the
                    // draining replica; incarnation-gate the swap so two
                    // replacements never fight over the slot.
                    if slots[idx].incarnation == incarnation {
                        slots[idx] = Slot {
                            engine: fresh,
                            incarnation: incarnation + 1,
                            breaker: Breaker::new(),
                        };
                    } else {
                        fresh.shutdown();
                    }
                }
                inner.metrics.record_replica_respawn();
                // Drain outside the slots lock: answers queued work, then
                // joins the worker.
                old.shutdown();
                Ok(moved)
            }
            Err(e) => {
                // The drain itself happened; make the corpse visibly dead
                // so the supervisor's next sweep replaces it.
                old.shutdown();
                Err(ServeError::Failed(
                    e.context(format!("respawning drained replica {idx}")),
                ))
            }
        }
    }

    /// Readiness probe: alive/configured counts, the resident-token
    /// ledger against its budget, and per-replica slot state — the
    /// `{"op":"health"}` body, cheap enough for load balancers to poll.
    pub fn health_json(&self) -> Json {
        let inner = &*self.inner;
        let (replicas, alive) = {
            let slots = lock_recover(&inner.slots);
            let replicas: Vec<Json> = slots
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    Json::obj(vec![
                        ("slot", Json::num(i as f64)),
                        ("incarnation", Json::num(s.incarnation as f64)),
                        ("alive", Json::Bool(s.engine.alive())),
                        ("breaker_state", Json::str(s.breaker.state_name())),
                        (
                            "resident_tokens",
                            Json::num(s.engine.metrics.resident_tokens() as f64),
                        ),
                    ])
                })
                .collect();
            let alive = slots.iter().filter(|s| s.engine.alive()).count();
            (replicas, alive)
        };
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("alive", Json::num(alive as f64)),
            ("configured", Json::num(inner.cfg.replicas as f64)),
            (
                "resident_tokens",
                Json::num(lock_recover(&inner.sessions).resident as f64),
            ),
            (
                "max_resident_tokens",
                Json::num(inner.cfg.max_resident_tokens as f64),
            ),
            ("replicas", Json::Arr(replicas)),
        ])
    }

    /// Set-level metrics snapshot with per-replica `shards` attached.
    pub fn metrics_to_json(&self) -> Json {
        let mut doc = self.inner.metrics.to_json();
        let shards: Vec<Json> = lock_recover(&self.inner.slots)
            .iter()
            .map(|s| s.engine.metrics.to_json())
            .collect();
        if let Json::Obj(map) = &mut doc {
            map.insert("shards".into(), Json::Arr(shards));
        }
        doc
    }

    /// Human-readable report: the set-level counters, then each shard.
    pub fn report(&self) -> String {
        let mut s = self.inner.metrics.report();
        let shards: Vec<(usize, String)> = lock_recover(&self.inner.slots)
            .iter()
            .enumerate()
            .map(|(i, slot)| (i, slot.engine.metrics.report()))
            .collect();
        for (i, shard) in shards {
            s.push_str(&format!("replica {i}:\n{shard}"));
        }
        s
    }

    /// Drain-then-shutdown: stop admissions, stop the supervisor (so it
    /// never respawns a draining replica), then drain every replica —
    /// each answers its queued work before exiting. Idempotent.
    pub fn shutdown(&self) {
        self.inner.accepting.store(false, Ordering::SeqCst);
        self.inner.running.store(false, Ordering::SeqCst);
        if let Some(h) = lock_recover(&self.supervisor).take() {
            let _ = h.join();
        }
        let engines: Vec<Arc<Engine>> = lock_recover(&self.inner.slots)
            .iter()
            .map(|s| s.engine.clone())
            .collect();
        for e in &engines {
            e.stop_admissions();
        }
        for e in &engines {
            e.shutdown();
        }
        self.inner
            .metrics
            .set_replica_gauges(0, self.inner.cfg.replicas);
    }
}

/// Forward one (already id-translated) session op to a replica and wait.
/// `None` means the replica died before answering (channel dropped or
/// refused while the set still accepts) — the caller converts that to
/// `SessionLost` / a structured open failure.
#[allow(clippy::type_complexity)]
fn forward(
    inner: &Inner,
    engine: &Engine,
    slot: usize,
    incarnation: u64,
    op: SessionOp,
    deadline: Option<Duration>,
) -> Option<ServeResult<SessionReply>> {
    let rx = match engine.submit_session(op, deadline) {
        Ok(rx) => rx,
        Err(ServeError::ShuttingDown) if inner.accepting.load(Ordering::SeqCst) => {
            note(inner, slot, incarnation, false);
            return None;
        }
        Err(e) => return Some(Err(e)),
    };
    match rx.recv() {
        Ok(Ok(reply)) => {
            note(inner, slot, incarnation, true);
            Some(Ok(reply))
        }
        Ok(Err(e)) => {
            if matches!(e, ServeError::Failed(_)) {
                note(inner, slot, incarnation, false);
            }
            Some(Err(e))
        }
        Err(_) => {
            note(inner, slot, incarnation, false);
            None
        }
    }
}

impl Drop for ReplicaSet {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl Serving for ReplicaSet {
    fn seq_len(&self) -> usize {
        ReplicaSet::seq_len(self)
    }

    fn classes(&self) -> usize {
        ReplicaSet::classes(self)
    }

    fn infer_with(
        &self,
        tokens: Vec<i32>,
        variant: Option<Variant>,
        deadline: Option<Duration>,
    ) -> ServeResult<InferResponse> {
        self.submit(tokens, variant, deadline)?.wait()
    }

    fn session(&self, op: SessionOp, deadline: Option<Duration>) -> ServeResult<SessionReply> {
        self.session_impl(op, deadline)
    }

    fn metrics_json(&self) -> Json {
        self.metrics_to_json()
    }

    fn health_json(&self) -> Json {
        ReplicaSet::health_json(self)
    }

    fn drain_replica(&self, slot: usize) -> ServeResult<usize> {
        ReplicaSet::drain_replica(self, slot)
    }

    fn metrics_report(&self) -> String {
        self.report()
    }

    fn note_quota_rejected(&self) {
        self.inner.metrics.record_quota_rejected();
    }

    fn stop_admissions(&self) {
        ReplicaSet::stop_admissions(self);
    }

    fn drain(&self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The breaker's full state machine: Closed survives sub-threshold
    /// failures, opens at the threshold, blocks while cooling, admits one
    /// half-open probe after the cooldown, and the probe's outcome closes
    /// or re-opens it.
    #[test]
    fn breaker_state_machine() {
        let cooldown = Duration::from_millis(20);
        let mut b = Breaker::new();
        assert!(b.admit(cooldown));
        b.failure(3);
        b.failure(3);
        assert!(b.admit(cooldown), "below threshold stays closed");
        b.failure(3);
        assert!(!b.admit(cooldown), "third consecutive failure opens");
        std::thread::sleep(cooldown + Duration::from_millis(5));
        assert!(b.admit(cooldown), "cooldown admits the half-open probe");
        assert!(!b.admit(cooldown), "only one probe at a time");
        b.failure(3);
        assert!(!b.admit(cooldown), "failed probe re-opens immediately");
        std::thread::sleep(cooldown + Duration::from_millis(5));
        assert!(b.admit(cooldown));
        b.success();
        assert!(b.admit(cooldown), "successful probe closes");
        assert!(b.admit(cooldown), "closed admits freely");
    }

    #[test]
    fn breaker_success_resets_consecutive_count() {
        let cooldown = Duration::from_millis(10);
        let mut b = Breaker::new();
        for _ in 0..10 {
            b.failure(3);
            b.success();
        }
        assert!(b.admit(cooldown), "interleaved successes never open");
    }
}
