//! Replicated serving: N independent engine replicas behind a supervisor
//! and a failover dispatcher.
//!
//! A [`ReplicaSet`] owns `N` [`Engine`]s — each with its own worker
//! thread, batcher lanes, session table and metrics shard, all built from
//! the **same** backend factory (same `KernelRegistry`, same
//! `KernelSpec`), so every replica — including a respawned one — serves
//! bit-identical logits. On top of them:
//!
//! * **Supervisor.** A thread polls each replica's heartbeat tick
//!   ([`Engine::tick`]) and liveness ([`Engine::alive`]) every quarter
//!   watchdog interval. A replica whose worker exited without draining
//!   (a panic escaped the pool shield — simulated by
//!   [`Engine::inject_crash`]) or whose heartbeat froze past the watchdog
//!   interval (wedged — [`Engine::inject_wedge`]) is torn down
//!   ([`Engine::shutdown`] joins it; a wedged worker exits on the running
//!   flip) and replaced by a fresh replica from the same factory. The
//!   `replicas` metrics section tracks `alive`/`configured` gauges plus
//!   `crashes`/`respawns` counters.
//! * **Dispatcher.** One-shot requests round-robin over healthy replicas.
//!   A request accepted by a replica that dies before replying is
//!   transparently retried on a sibling — bounded by
//!   [`ReplicaConfig::retry_budget`], counted once under `retried`, and
//!   still counted exactly once as served. The original deadline budget
//!   spans all attempts.
//! * **Circuit breaker.** Each replica carries a consecutive-failure
//!   breaker: past [`ReplicaConfig::breaker_threshold`] failures it opens
//!   (the dispatcher routes around it), after
//!   [`ReplicaConfig::breaker_cooldown`] it admits one half-open probe,
//!   and the probe's outcome closes or re-opens it — a flapping replica
//!   is never fed sustained traffic.
//! * **Sticky sessions.** Decode sessions pin to the replica that opened
//!   them (a KV cache cannot migrate); the set hands out *global* session
//!   ids and routes ops to the owning replica's inner id. When a replica
//!   dies, ops on its sessions answer a structured
//!   [`ServeError::SessionLost`] — never a hang — and the extended
//!   accounting identity
//!   `submitted == served + overloaded + expired + errored + session_lost`
//!   holds under replica kills.
//! * **Chaos sites.** With [`ReplicaConfig::faults`] set, every dispatch
//!   rolls the seeded `replica.crash` / `replica.wedge` sites: any
//!   injected fault kills (resp. wedges) the replica the round-robin
//!   cursor points at, so chaos tests kill replicas deterministically by
//!   seed.
//!
//! The [`Serving`] trait abstracts "something the TCP front end can serve
//! from" — implemented by both a bare [`Engine`] and a [`ReplicaSet`], so
//! the server (and its tests) work over either.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::backend::{InferBackend, NativeBackend, NativeModelConfig};
use super::engine::{Engine, EngineConfig};
use super::error::{ServeError, ServeResult};
use super::metrics::Metrics;
use super::request::{DecodeResponse, InferResponse, SessionOp, SessionReply};
use crate::kernels::Variant;
use crate::util::error::{err, Result};
use crate::util::faults::{Fault, FaultInjector};
use crate::util::json::Json;

/// Anything the serving front end can drive: blocking one-shot inference,
/// blocking session ops, metrics snapshots, and drain-then-shutdown.
/// Implemented by [`Engine`] (single replica, zero overhead) and
/// [`ReplicaSet`] (supervised replicas with failover).
pub trait Serving: Send + Sync {
    /// Expected token-sequence length for requests.
    fn seq_len(&self) -> usize;
    /// Logits per response.
    fn classes(&self) -> usize;
    /// Blocking one-shot inference with the typed outcome.
    fn infer_with(
        &self,
        tokens: Vec<i32>,
        variant: Option<Variant>,
        deadline: Option<Duration>,
    ) -> ServeResult<InferResponse>;
    /// Blocking session op (`Open`/`Decode`/`Close`) with the typed reply.
    fn session(&self, op: SessionOp, deadline: Option<Duration>) -> ServeResult<SessionReply>;
    /// Machine-readable metrics snapshot (the `{"op":"metrics"}` body).
    fn metrics_json(&self) -> Json;
    /// Human-readable metrics report (printed at server exit).
    fn metrics_report(&self) -> String;
    /// Count one submission refused by a per-client quota.
    fn note_quota_rejected(&self);
    /// Stop admitting new work (first phase of drain).
    fn stop_admissions(&self);
    /// Drain-then-shutdown; idempotent.
    fn drain(&self);
}

impl Serving for Engine {
    fn seq_len(&self) -> usize {
        Engine::seq_len(self)
    }

    fn classes(&self) -> usize {
        Engine::classes(self)
    }

    fn infer_with(
        &self,
        tokens: Vec<i32>,
        variant: Option<Variant>,
        deadline: Option<Duration>,
    ) -> ServeResult<InferResponse> {
        let rx = self.submit(tokens, variant, deadline)?;
        match rx.recv() {
            Ok(outcome) => outcome,
            // Admitted work is always answered; a closed channel can only
            // mean shutdown raced us.
            Err(_) => Err(ServeError::ShuttingDown),
        }
    }

    fn session(&self, op: SessionOp, deadline: Option<Duration>) -> ServeResult<SessionReply> {
        let rx = self.submit_session(op, deadline)?;
        match rx.recv() {
            Ok(outcome) => outcome,
            Err(_) => Err(ServeError::ShuttingDown),
        }
    }

    fn metrics_json(&self) -> Json {
        self.metrics.to_json()
    }

    fn metrics_report(&self) -> String {
        self.metrics.report()
    }

    fn note_quota_rejected(&self) {
        self.metrics.record_quota_rejected();
    }

    fn stop_admissions(&self) {
        Engine::stop_admissions(self);
    }

    fn drain(&self) {
        self.shutdown();
    }
}

/// Replication policy of a [`ReplicaSet`].
#[derive(Debug, Clone)]
pub struct ReplicaConfig {
    /// Engine replicas to run (>= 1; each gets its own worker thread,
    /// batcher, session table and metrics shard).
    pub replicas: usize,
    /// Heartbeat staleness past which a live-but-silent replica counts as
    /// wedged (clamped to >= 100ms: a healthy idle worker ticks every
    /// ~50ms, and the interval must also exceed the worst-case batch
    /// latency). Also the supervisor's detection bound: no client waits
    /// on a wedged replica longer than roughly this plus one poll tick.
    pub watchdog: Duration,
    /// How many times one accepted one-shot request may be re-dispatched
    /// onto a sibling after its replica died mid-flight (0 = never; the
    /// death then surfaces as a structured `error` reply).
    pub retry_budget: usize,
    /// Consecutive dispatch failures that open a replica's circuit
    /// breaker.
    pub breaker_threshold: u32,
    /// How long an open breaker blocks dispatch before admitting one
    /// half-open probe.
    pub breaker_cooldown: Duration,
    /// Chaos hook: when set, every dispatch rolls the `replica.crash` /
    /// `replica.wedge` sites and any injected fault kills (resp. wedges)
    /// the replica under the round-robin cursor.
    pub faults: Option<Arc<FaultInjector>>,
}

impl Default for ReplicaConfig {
    fn default() -> Self {
        ReplicaConfig {
            replicas: 1,
            watchdog: Duration::from_millis(500),
            retry_budget: 2,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(250),
            faults: None,
        }
    }
}

/// Consecutive-failure circuit breaker: Closed → (threshold failures) →
/// Open → (cooldown) → HalfOpen probe → Closed on success / Open on
/// failure. A half-open probe whose outcome never arrives (the client
/// abandoned its wait) unblocks after another full cooldown.
#[derive(Debug, Clone, Copy)]
enum BreakerState {
    Closed,
    Open { since: Instant },
    HalfOpen { since: Instant },
}

#[derive(Debug, Clone, Copy)]
struct Breaker {
    consecutive: u32,
    state: BreakerState,
}

impl Breaker {
    fn new() -> Breaker {
        Breaker { consecutive: 0, state: BreakerState::Closed }
    }

    /// May this replica receive a dispatch right now? Transitions an
    /// expired Open into the half-open probe as a side effect.
    fn admit(&mut self, cooldown: Duration) -> bool {
        match self.state {
            BreakerState::Closed => true,
            BreakerState::Open { since } | BreakerState::HalfOpen { since } => {
                if since.elapsed() >= cooldown {
                    self.state = BreakerState::HalfOpen { since: Instant::now() };
                    true
                } else {
                    // Open and still cooling, or a probe is already out.
                    false
                }
            }
        }
    }

    fn success(&mut self) {
        self.consecutive = 0;
        self.state = BreakerState::Closed;
    }

    fn failure(&mut self, threshold: u32) {
        self.consecutive = self.consecutive.saturating_add(1);
        if matches!(self.state, BreakerState::HalfOpen { .. })
            || self.consecutive >= threshold.max(1)
        {
            self.state = BreakerState::Open { since: Instant::now() };
        }
    }
}

/// One replica slot: the live engine, its incarnation (bumped per
/// respawn, so stale session routes and breaker notes can't touch a
/// fresh replica), and its breaker.
struct Slot {
    engine: Arc<Engine>,
    incarnation: u64,
    breaker: Breaker,
}

/// Where a global session id lives: which slot, which incarnation of it,
/// and the engine-local session id.
struct SessionRoute {
    slot: usize,
    incarnation: u64,
    inner: u64,
}

/// State shared between the handle, the dispatcher and the supervisor.
struct Inner {
    slots: Mutex<Vec<Slot>>,
    sessions: Mutex<HashMap<u64, SessionRoute>>,
    factory: Arc<dyn Fn() -> Result<Box<dyn InferBackend>> + Send + Sync>,
    engine_cfg: EngineConfig,
    cfg: ReplicaConfig,
    metrics: Arc<Metrics>,
    /// Round-robin dispatch cursor (also the chaos sites' victim pointer).
    rr: AtomicUsize,
    next_session: AtomicU64,
    /// Supervisor liveness; flipped by shutdown *before* engines drain so
    /// the supervisor never respawns a draining replica.
    running: AtomicBool,
    accepting: AtomicBool,
    seq_len: usize,
    classes: usize,
}

/// Handle to a supervised set of engine replicas. See module docs.
pub struct ReplicaSet {
    inner: Arc<Inner>,
    supervisor: Mutex<Option<JoinHandle<()>>>,
}

/// Spawn one replica from the shared factory (same registry/spec preload
/// as every sibling — a respawn serves bit-identical logits).
fn spawn_replica(
    factory: &Arc<dyn Fn() -> Result<Box<dyn InferBackend>> + Send + Sync>,
    engine_cfg: &EngineConfig,
) -> Result<Arc<Engine>> {
    let factory = factory.clone();
    Engine::start_with(move || factory(), engine_cfg.clone()).map(Arc::new)
}

/// Pick a dispatch target: round-robin over slots that are alive,
/// accepting, and admitted by their breaker. `exclude` skips the replica
/// a retry just died on (ignored when it is the only slot).
fn pick(inner: &Inner, exclude: Option<usize>) -> ServeResult<(usize, u64, Arc<Engine>)> {
    let mut slots = inner.slots.lock().unwrap();
    let n = slots.len();
    let start = inner.rr.fetch_add(1, Ordering::Relaxed);
    for k in 0..n {
        let i = (start + k) % n;
        if exclude == Some(i) && n > 1 {
            continue;
        }
        let slot = &mut slots[i];
        if !slot.engine.alive() || !slot.engine.accepting() {
            continue;
        }
        if !slot.breaker.admit(inner.cfg.breaker_cooldown) {
            continue;
        }
        return Ok((i, slot.incarnation, slot.engine.clone()));
    }
    // Every replica is dead, draining or breaker-blocked: a structured
    // refusal with the watchdog as the retry hint (by then the supervisor
    // will have respawned something).
    inner.metrics.record_rejected(1);
    Err(ServeError::Overloaded {
        retry_after_ms: inner.cfg.watchdog.as_millis() as u64,
    })
}

/// Note a dispatch outcome on a slot's breaker — only if the slot still
/// holds the incarnation the dispatch went to (a respawned replica must
/// not inherit its predecessor's failures).
fn note(inner: &Inner, slot: usize, incarnation: u64, ok: bool) {
    let mut slots = inner.slots.lock().unwrap();
    if let Some(s) = slots.get_mut(slot) {
        if s.incarnation == incarnation {
            if ok {
                s.breaker.success();
            } else {
                s.breaker.failure(inner.cfg.breaker_threshold);
            }
        }
    }
}

/// Roll the seeded chaos sites once per dispatch: any injected fault at
/// `replica.crash` kills — and at `replica.wedge` wedges — the replica
/// the round-robin cursor currently points at.
fn chaos_roll(inner: &Inner) {
    let Some(faults) = &inner.cfg.faults else {
        return;
    };
    let victim = |inner: &Inner| -> Option<Arc<Engine>> {
        let slots = inner.slots.lock().unwrap();
        if slots.is_empty() {
            return None;
        }
        let i = inner.rr.load(Ordering::Relaxed) % slots.len();
        Some(slots[i].engine.clone())
    };
    if faults.roll("replica.crash") != Fault::None {
        if let Some(e) = victim(inner) {
            e.inject_crash();
        }
    }
    if faults.roll("replica.wedge") != Fault::None {
        if let Some(e) = victim(inner) {
            e.inject_wedge();
        }
    }
}

/// Drop a lost session's route, count it, and reply `SessionLost`.
fn lost(inner: &Inner, session: u64) -> ServeError {
    inner.sessions.lock().unwrap().remove(&session);
    inner.metrics.record_session_lost();
    ServeError::SessionLost { session }
}

/// Supervisor loop: watch heartbeats, tear down crashed/wedged replicas,
/// respawn, and keep the alive gauge fresh.
fn supervise(inner: Arc<Inner>) {
    let watchdog = inner.cfg.watchdog;
    let poll = (watchdog / 4).clamp(Duration::from_millis(5), Duration::from_millis(50));
    let n = inner.cfg.replicas;
    let now = Instant::now();
    let mut seen: Vec<(u64, Instant)> = {
        let slots = inner.slots.lock().unwrap();
        slots.iter().map(|s| (s.engine.tick(), now)).collect()
    };
    // Which incarnation's death was already counted per slot, so a failed
    // respawn (corpse lingers, retried next sweep) counts one crash.
    let mut counted: Vec<Option<u64>> = vec![None; n];
    while inner.running.load(Ordering::SeqCst) {
        std::thread::sleep(poll);
        if !inner.running.load(Ordering::SeqCst) {
            break;
        }
        let mut alive = 0usize;
        for i in 0..n {
            let (engine, incarnation) = {
                let slots = inner.slots.lock().unwrap();
                (slots[i].engine.clone(), slots[i].incarnation)
            };
            let tick = engine.tick();
            let now = Instant::now();
            if tick != seen[i].0 {
                seen[i] = (tick, now);
            }
            let dead = !engine.alive();
            let wedged = !dead && now.duration_since(seen[i].1) > watchdog;
            if !(dead || wedged) {
                alive += 1;
                continue;
            }
            if counted[i] != Some(incarnation) {
                counted[i] = Some(incarnation);
                inner.metrics.record_replica_crash();
                crate::log_error!(
                    "replica {i} (incarnation {incarnation}) {}; tearing down",
                    if dead { "crashed" } else { "wedged" }
                );
            }
            // Tear down: joins the worker (a wedged one exits on the
            // running flip inside shutdown), dropping every parked reply
            // channel — waiting clients fail over or see `session_lost`
            // instead of hanging. Sessions routed to this incarnation
            // convert lazily: the bumped incarnation makes their next op
            // answer `SessionLost`.
            engine.shutdown();
            match spawn_replica(&inner.factory, &inner.engine_cfg) {
                Ok(fresh) => {
                    let mut slots = inner.slots.lock().unwrap();
                    seen[i] = (fresh.tick(), Instant::now());
                    slots[i] = Slot {
                        engine: fresh,
                        incarnation: incarnation + 1,
                        breaker: Breaker::new(),
                    };
                    drop(slots);
                    inner.metrics.record_replica_respawn();
                    alive += 1;
                }
                Err(e) => {
                    // Leave the corpse; the next sweep retries the respawn
                    // (its crash is already counted).
                    crate::log_error!("respawning replica {i}: {e}");
                }
            }
        }
        inner.metrics.set_replica_gauges(alive, n);
    }
}

/// An accepted one-shot dispatch: hold it and [`PendingInfer::wait`] for
/// the typed outcome. Submissions stay pipelined (submit a burst, then
/// wait each); the failover retry runs inside `wait`.
pub struct PendingInfer<'a> {
    inner: &'a Inner,
    rx: std::sync::mpsc::Receiver<ServeResult<InferResponse>>,
    slot: usize,
    incarnation: u64,
    resubmit: Option<Resubmit>,
}

/// What a retry needs to re-dispatch the request on a sibling.
struct Resubmit {
    tokens: Vec<i32>,
    variant: Option<Variant>,
    deadline: Option<Duration>,
    t0: Instant,
    attempts: usize,
}

impl PendingInfer<'_> {
    /// Block for the typed outcome. A reply channel that drops without an
    /// answer means the replica died mid-flight: the request is
    /// re-dispatched on a healthy sibling (up to the retry budget, with
    /// the original deadline budget spanning attempts, each retry counted
    /// under `retried`) — the served reply still counts exactly once.
    pub fn wait(mut self) -> ServeResult<InferResponse> {
        loop {
            match self.rx.recv() {
                Ok(Ok(resp)) => {
                    note(self.inner, self.slot, self.incarnation, true);
                    return Ok(resp);
                }
                Ok(Err(e)) => {
                    if matches!(e, ServeError::Failed(_)) {
                        note(self.inner, self.slot, self.incarnation, false);
                    }
                    return Err(e);
                }
                Err(_) => {
                    note(self.inner, self.slot, self.incarnation, false);
                    let Some(r) = self.resubmit.as_mut() else {
                        return Err(ServeError::Failed(err!(
                            "replica died before replying (no failover sibling)"
                        )));
                    };
                    if r.attempts >= self.inner.cfg.retry_budget {
                        return Err(ServeError::Failed(err!(
                            "replica died before replying; retry budget ({}) exhausted",
                            self.inner.cfg.retry_budget
                        )));
                    }
                    r.attempts += 1;
                    let deadline = match r.deadline {
                        Some(budget) => {
                            let remaining = budget.saturating_sub(r.t0.elapsed());
                            if remaining.is_zero() {
                                return Err(ServeError::Expired {
                                    waited_ms: r.t0.elapsed().as_millis() as u64,
                                });
                            }
                            Some(remaining)
                        }
                        None => None,
                    };
                    let (slot, incarnation, engine) = pick(self.inner, Some(self.slot))?;
                    match engine.submit(r.tokens.clone(), r.variant, deadline) {
                        Ok(rx) => {
                            self.inner.metrics.record_retried();
                            self.rx = rx;
                            self.slot = slot;
                            self.incarnation = incarnation;
                        }
                        Err(e) => return Err(e),
                    }
                }
            }
        }
    }
}

impl ReplicaSet {
    /// Start `cfg.replicas` engines over a backend factory — `Fn`, not
    /// `FnOnce`, because the supervisor re-invokes it to respawn a dead
    /// replica with the same registry/spec preload.
    pub fn start_with<F>(
        factory: F,
        engine_cfg: EngineConfig,
        mut cfg: ReplicaConfig,
    ) -> Result<ReplicaSet>
    where
        F: Fn() -> Result<Box<dyn InferBackend>> + Send + Sync + 'static,
    {
        cfg.replicas = cfg.replicas.max(1);
        cfg.watchdog = cfg.watchdog.max(Duration::from_millis(100));
        let factory: Arc<dyn Fn() -> Result<Box<dyn InferBackend>> + Send + Sync> =
            Arc::new(factory);
        let mut slots = Vec::with_capacity(cfg.replicas);
        let mut shape = (0usize, 0usize);
        for i in 0..cfg.replicas {
            match spawn_replica(&factory, &engine_cfg) {
                Ok(engine) => {
                    shape = (engine.seq_len(), engine.classes());
                    slots.push(Slot { engine, incarnation: 0, breaker: Breaker::new() });
                }
                Err(e) => {
                    for s in &slots {
                        s.engine.shutdown();
                    }
                    return Err(e.context(format!("starting replica {i}")));
                }
            }
        }
        let inner = Arc::new(Inner {
            slots: Mutex::new(slots),
            sessions: Mutex::new(HashMap::new()),
            factory,
            engine_cfg,
            cfg,
            metrics: Arc::new(Metrics::new()),
            rr: AtomicUsize::new(0),
            next_session: AtomicU64::new(0),
            running: AtomicBool::new(true),
            accepting: AtomicBool::new(true),
            seq_len: shape.0,
            classes: shape.1,
        });
        inner
            .metrics
            .set_replica_gauges(inner.cfg.replicas, inner.cfg.replicas);
        let supervisor = {
            let inner = inner.clone();
            std::thread::Builder::new()
                .name("dsa-replica-supervisor".to_string())
                .spawn(move || supervise(inner))
                .map_err(|e| err!("spawning replica supervisor: {e}"))?
        };
        Ok(ReplicaSet { inner, supervisor: Mutex::new(Some(supervisor)) })
    }

    /// Start a replicated set of hermetic native-kernel engines.
    pub fn start_native(
        model: NativeModelConfig,
        engine_cfg: EngineConfig,
        cfg: ReplicaConfig,
    ) -> Result<ReplicaSet> {
        ReplicaSet::start_with(move || NativeBackend::boxed(model.clone()), engine_cfg, cfg)
    }

    /// Expected token-sequence length for requests.
    pub fn seq_len(&self) -> usize {
        self.inner.seq_len
    }

    /// Logits per response.
    pub fn classes(&self) -> usize {
        self.inner.classes
    }

    /// Configured replica count.
    pub fn replicas(&self) -> usize {
        self.inner.cfg.replicas
    }

    /// Replicas whose worker is currently running.
    pub fn alive_replicas(&self) -> usize {
        self.inner
            .slots
            .lock()
            .unwrap()
            .iter()
            .filter(|s| s.engine.alive())
            .count()
    }

    /// Replica-level metrics (the `replicas` section plus set-level
    /// refusals); per-replica shards ride under `shards` in
    /// [`ReplicaSet::metrics_to_json`].
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.inner.metrics
    }

    /// Dispatch one one-shot request to a healthy replica; call
    /// [`PendingInfer::wait`] for the outcome (failover retries happen
    /// there). The chaos sites roll here, once per dispatch.
    pub fn submit(
        &self,
        mut tokens: Vec<i32>,
        variant: Option<Variant>,
        deadline: Option<Duration>,
    ) -> ServeResult<PendingInfer<'_>> {
        let inner = &*self.inner;
        if !inner.accepting.load(Ordering::SeqCst) {
            return Err(ServeError::ShuttingDown);
        }
        chaos_roll(inner);
        // Failover needs its own copy of the tokens (the engine consumes
        // them); skip the clone when no retry could ever use it.
        let mut resubmit = if inner.cfg.retry_budget > 0 && inner.cfg.replicas > 1 {
            Some(Resubmit {
                tokens: tokens.clone(),
                variant,
                deadline,
                t0: Instant::now(),
                attempts: 0,
            })
        } else {
            None
        };
        let mut exclude = None;
        let mut tries = 0usize;
        loop {
            let (slot, incarnation, engine) = pick(inner, exclude)?;
            let payload = match &resubmit {
                Some(r) => r.tokens.clone(),
                None => std::mem::take(&mut tokens),
            };
            match engine.submit(payload, variant, deadline) {
                Ok(rx) => {
                    return Ok(PendingInfer {
                        inner,
                        rx,
                        slot,
                        incarnation,
                        resubmit: resubmit.take(),
                    })
                }
                // The replica's channel died under us (crash racing the
                // dispatch) while the set is still accepting: fail over
                // pre-acceptance — not counted as `retried`, the request
                // was never accepted anywhere.
                Err(ServeError::ShuttingDown)
                    if inner.accepting.load(Ordering::SeqCst)
                        && resubmit.is_some()
                        && tries + 1 < inner.cfg.replicas =>
                {
                    note(inner, slot, incarnation, false);
                    exclude = Some(slot);
                    tries += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Blocking one-shot inference (submit + wait, including failover).
    pub fn infer(&self, tokens: Vec<i32>, variant: Option<Variant>) -> ServeResult<InferResponse> {
        self.submit(tokens, variant, None)?.wait()
    }

    /// Open a decode session on a healthy replica (blocking); returns
    /// `(global session id, resident tokens, pinned variant)`. The
    /// session is sticky: every later op routes to the opening replica,
    /// and dies with it as a structured `session_lost`.
    pub fn open_session(
        &self,
        prompt: Vec<i32>,
        variant: Option<Variant>,
    ) -> ServeResult<(u64, usize, Variant)> {
        match self.session_impl(SessionOp::Open { prompt, variant }, None)? {
            SessionReply::Opened { session, resident, variant } => {
                Ok((session, resident, variant))
            }
            other => Err(ServeError::Failed(err!(
                "replica returned mismatched session reply {other:?}"
            ))),
        }
    }

    /// Run one decode step on an open session (blocking).
    pub fn decode(&self, session: u64, token: i32) -> ServeResult<DecodeResponse> {
        match self.session_impl(SessionOp::Decode { session, token }, None)? {
            SessionReply::Decoded(resp) => Ok(resp),
            other => Err(ServeError::Failed(err!(
                "replica returned mismatched session reply {other:?}"
            ))),
        }
    }

    /// Close a session (blocking), releasing its replica-side cache.
    pub fn close_session(&self, session: u64) -> ServeResult<usize> {
        match self.session_impl(SessionOp::Close { session }, None)? {
            SessionReply::Closed { released, .. } => Ok(released),
            other => Err(ServeError::Failed(err!(
                "replica returned mismatched session reply {other:?}"
            ))),
        }
    }

    /// Session dispatch: translate global ↔ engine-local ids, keep the
    /// route table honest, and convert replica deaths into `SessionLost`.
    fn session_impl(
        &self,
        op: SessionOp,
        deadline: Option<Duration>,
    ) -> ServeResult<SessionReply> {
        let inner = &*self.inner;
        if !inner.accepting.load(Ordering::SeqCst) {
            return Err(ServeError::ShuttingDown);
        }
        chaos_roll(inner);
        match op {
            SessionOp::Open { prompt, variant } => {
                let (slot, incarnation, engine) = pick(inner, None)?;
                let op = SessionOp::Open { prompt, variant };
                let reply = forward(inner, &engine, slot, incarnation, op, deadline)
                    .ok_or_else(|| {
                        // Died during open: no session was established,
                        // so this is a plain structured failure, not a
                        // lost session.
                        ServeError::Failed(err!("replica died during session open"))
                    })?;
                match reply {
                    Ok(SessionReply::Opened { session: local, resident, variant }) => {
                        let global = inner.next_session.fetch_add(1, Ordering::Relaxed) + 1;
                        inner.sessions.lock().unwrap().insert(global, SessionRoute {
                            slot,
                            incarnation,
                            inner: local,
                        });
                        Ok(SessionReply::Opened { session: global, resident, variant })
                    }
                    other => other,
                }
            }
            SessionOp::Decode { session, token } => {
                let (engine, slot, incarnation, local) = self.route(session)?;
                let op = SessionOp::Decode { session: local, token };
                let reply = forward(inner, &engine, slot, incarnation, op, deadline)
                    .ok_or_else(|| lost(inner, session))?;
                match reply {
                    Ok(SessionReply::Decoded(mut resp)) => {
                        resp.session = session;
                        Ok(SessionReply::Decoded(resp))
                    }
                    other => other,
                }
            }
            SessionOp::Close { session } => {
                let (engine, slot, incarnation, local) = self.route(session)?;
                let op = SessionOp::Close { session: local };
                let reply = forward(inner, &engine, slot, incarnation, op, deadline)
                    .ok_or_else(|| lost(inner, session))?;
                // Served or engine-side error: the client relinquished the
                // id either way — the route is gone.
                inner.sessions.lock().unwrap().remove(&session);
                match reply {
                    Ok(SessionReply::Closed { released, .. }) => {
                        Ok(SessionReply::Closed { session, released })
                    }
                    other => other,
                }
            }
        }
    }

    /// Resolve a global session id to its live replica, or answer
    /// `SessionLost` (incarnation bumped / replica dead) or a structured
    /// "unknown session" failure (never routed).
    fn route(&self, session: u64) -> ServeResult<(Arc<Engine>, usize, u64, u64)> {
        let inner = &*self.inner;
        let (slot_idx, incarnation, local) = {
            let sessions = inner.sessions.lock().unwrap();
            match sessions.get(&session) {
                Some(r) => (r.slot, r.incarnation, r.inner),
                None => {
                    return Err(ServeError::Failed(err!("unknown session {session}")));
                }
            }
        };
        let stale = {
            let slots = inner.slots.lock().unwrap();
            match slots.get(slot_idx) {
                Some(s) if s.incarnation == incarnation && s.engine.alive() => {
                    return Ok((s.engine.clone(), slot_idx, incarnation, local));
                }
                _ => true,
            }
        };
        debug_assert!(stale);
        Err(lost(inner, session))
    }

    /// Stop admitting new work across the set (and on every replica).
    pub fn stop_admissions(&self) {
        self.inner.accepting.store(false, Ordering::SeqCst);
        for s in self.inner.slots.lock().unwrap().iter() {
            s.engine.stop_admissions();
        }
    }

    /// Whether the set still admits new work.
    pub fn accepting(&self) -> bool {
        self.inner.accepting.load(Ordering::SeqCst)
    }

    /// Chaos/test hook: crash replica `idx` (worker exits without
    /// draining). The supervisor detects and respawns it.
    pub fn inject_crash(&self, idx: usize) {
        let slots = self.inner.slots.lock().unwrap();
        if !slots.is_empty() {
            slots[idx % slots.len()].engine.inject_crash();
        }
    }

    /// Chaos/test hook: wedge replica `idx` (heartbeat freezes until the
    /// watchdog tears it down).
    pub fn inject_wedge(&self, idx: usize) {
        let slots = self.inner.slots.lock().unwrap();
        if !slots.is_empty() {
            slots[idx % slots.len()].engine.inject_wedge();
        }
    }

    /// Set-level metrics snapshot with per-replica `shards` attached.
    pub fn metrics_to_json(&self) -> Json {
        let mut doc = self.inner.metrics.to_json();
        let shards: Vec<Json> = self
            .inner
            .slots
            .lock()
            .unwrap()
            .iter()
            .map(|s| s.engine.metrics.to_json())
            .collect();
        if let Json::Obj(map) = &mut doc {
            map.insert("shards".into(), Json::Arr(shards));
        }
        doc
    }

    /// Human-readable report: the set-level counters, then each shard.
    pub fn report(&self) -> String {
        let mut s = self.inner.metrics.report();
        let shards: Vec<(usize, String)> = self
            .inner
            .slots
            .lock()
            .unwrap()
            .iter()
            .enumerate()
            .map(|(i, slot)| (i, slot.engine.metrics.report()))
            .collect();
        for (i, shard) in shards {
            s.push_str(&format!("replica {i}:\n{shard}"));
        }
        s
    }

    /// Drain-then-shutdown: stop admissions, stop the supervisor (so it
    /// never respawns a draining replica), then drain every replica —
    /// each answers its queued work before exiting. Idempotent.
    pub fn shutdown(&self) {
        self.inner.accepting.store(false, Ordering::SeqCst);
        self.inner.running.store(false, Ordering::SeqCst);
        if let Some(h) = self.supervisor.lock().unwrap().take() {
            let _ = h.join();
        }
        let engines: Vec<Arc<Engine>> = self
            .inner
            .slots
            .lock()
            .unwrap()
            .iter()
            .map(|s| s.engine.clone())
            .collect();
        for e in &engines {
            e.stop_admissions();
        }
        for e in &engines {
            e.shutdown();
        }
        self.inner
            .metrics
            .set_replica_gauges(0, self.inner.cfg.replicas);
    }
}

/// Forward one (already id-translated) session op to a replica and wait.
/// `None` means the replica died before answering (channel dropped or
/// refused while the set still accepts) — the caller converts that to
/// `SessionLost` / a structured open failure.
#[allow(clippy::type_complexity)]
fn forward(
    inner: &Inner,
    engine: &Engine,
    slot: usize,
    incarnation: u64,
    op: SessionOp,
    deadline: Option<Duration>,
) -> Option<ServeResult<SessionReply>> {
    let rx = match engine.submit_session(op, deadline) {
        Ok(rx) => rx,
        Err(ServeError::ShuttingDown) if inner.accepting.load(Ordering::SeqCst) => {
            note(inner, slot, incarnation, false);
            return None;
        }
        Err(e) => return Some(Err(e)),
    };
    match rx.recv() {
        Ok(Ok(reply)) => {
            note(inner, slot, incarnation, true);
            Some(Ok(reply))
        }
        Ok(Err(e)) => {
            if matches!(e, ServeError::Failed(_)) {
                note(inner, slot, incarnation, false);
            }
            Some(Err(e))
        }
        Err(_) => {
            note(inner, slot, incarnation, false);
            None
        }
    }
}

impl Drop for ReplicaSet {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl Serving for ReplicaSet {
    fn seq_len(&self) -> usize {
        ReplicaSet::seq_len(self)
    }

    fn classes(&self) -> usize {
        ReplicaSet::classes(self)
    }

    fn infer_with(
        &self,
        tokens: Vec<i32>,
        variant: Option<Variant>,
        deadline: Option<Duration>,
    ) -> ServeResult<InferResponse> {
        self.submit(tokens, variant, deadline)?.wait()
    }

    fn session(&self, op: SessionOp, deadline: Option<Duration>) -> ServeResult<SessionReply> {
        self.session_impl(op, deadline)
    }

    fn metrics_json(&self) -> Json {
        self.metrics_to_json()
    }

    fn metrics_report(&self) -> String {
        self.report()
    }

    fn note_quota_rejected(&self) {
        self.inner.metrics.record_quota_rejected();
    }

    fn stop_admissions(&self) {
        ReplicaSet::stop_admissions(self);
    }

    fn drain(&self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The breaker's full state machine: Closed survives sub-threshold
    /// failures, opens at the threshold, blocks while cooling, admits one
    /// half-open probe after the cooldown, and the probe's outcome closes
    /// or re-opens it.
    #[test]
    fn breaker_state_machine() {
        let cooldown = Duration::from_millis(20);
        let mut b = Breaker::new();
        assert!(b.admit(cooldown));
        b.failure(3);
        b.failure(3);
        assert!(b.admit(cooldown), "below threshold stays closed");
        b.failure(3);
        assert!(!b.admit(cooldown), "third consecutive failure opens");
        std::thread::sleep(cooldown + Duration::from_millis(5));
        assert!(b.admit(cooldown), "cooldown admits the half-open probe");
        assert!(!b.admit(cooldown), "only one probe at a time");
        b.failure(3);
        assert!(!b.admit(cooldown), "failed probe re-opens immediately");
        std::thread::sleep(cooldown + Duration::from_millis(5));
        assert!(b.admit(cooldown));
        b.success();
        assert!(b.admit(cooldown), "successful probe closes");
        assert!(b.admit(cooldown), "closed admits freely");
    }

    #[test]
    fn breaker_success_resets_consecutive_count() {
        let cooldown = Duration::from_millis(10);
        let mut b = Breaker::new();
        for _ in 0..10 {
            b.failure(3);
            b.success();
        }
        assert!(b.admit(cooldown), "interleaved successes never open");
    }
}
