//! Dynamic batcher: groups queued requests into batches under a
//! max-batch-size / max-wait policy (vLLM-router-style continuous batching,
//! simplified to the encoder-classifier setting where every request is one
//! fixed-length forward pass).
//!
//! Decode traffic is scheduled separately from one-shot inference: session
//! jobs land in two FIFO lanes — **decode/close** (one cached token each,
//! latency-sensitive: they set the stream's inter-token latency) and
//! **open** (a full prompt prefill, throughput work like a one-shot
//! batch). The engine drains the decode lane first, then opens, then cuts
//! inference batches, so a long prefill backlog never stalls live streams.
//!
//! Pure data structure — no threads — so the policy is unit-testable; the
//! engine drives it from its worker loop.

use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::time::{Duration, Instant};

use super::error::ServeResult;
use super::request::{InferRequest, SessionOp, SessionReply};
use crate::util::error::Result;

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Hard cap on requests per batch (usually the largest compiled bucket).
    pub max_batch: usize,
    /// Oldest request may wait at most this long before the batch is cut.
    pub max_wait: Duration,
    /// Queue capacity; submissions beyond this are rejected (backpressure).
    pub queue_cap: usize,
    /// Deadline budget stamped onto requests that did not bring their own
    /// (`None` = admitted work waits indefinitely). The engine applies it
    /// at admission; the batcher sheds whoever missed theirs at cut time.
    pub default_deadline: Option<Duration>,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            queue_cap: 1024,
            default_deadline: None,
        }
    }
}

/// One queued session operation: the typed op, its enqueue time (for
/// TTFT / inter-token latency accounting), its deadline (checked when
/// the engine dequeues it; `Close` ops are exempt so a drain never leaks
/// a session) and the reply channel the engine answers on (errors travel
/// as the typed [`ServeResult`], so the protocol boundary renders codes
/// without any in-band sentinel).
#[derive(Debug)]
pub struct SessionJob {
    pub op: SessionOp,
    pub enqueued: Instant,
    pub deadline: Option<Instant>,
    pub reply: Sender<ServeResult<SessionReply>>,
}

/// FIFO queue with deadline-or-full batch cutting, grouped by variant,
/// plus the two session lanes (see the module docs for the priority
/// order).
#[derive(Debug)]
pub struct Batcher {
    pub policy: BatchPolicy,
    queue: VecDeque<InferRequest>,
    /// Decode / close jobs: one cached token each, drained first.
    decode_q: VecDeque<SessionJob>,
    /// Open jobs: full prompt prefills, drained after decodes.
    open_q: VecDeque<SessionJob>,
    rejected: u64,
    expired: u64,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            queue: VecDeque::new(),
            decode_q: VecDeque::new(),
            open_q: VecDeque::new(),
            rejected: 0,
            expired: 0,
        }
    }

    /// Enqueue; Err(req) when the queue is full (backpressure signal).
    pub fn push(&mut self, req: InferRequest) -> Result<(), InferRequest> {
        if self.queue.len() >= self.policy.queue_cap {
            self.rejected += 1;
            return Err(req);
        }
        self.queue.push_back(req);
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    /// Requests shed by [`Batcher::shed_expired`] so far.
    pub fn expired(&self) -> u64 {
        self.expired
    }

    /// Remove every queued request whose deadline is at or before `now`
    /// and return them (the engine answers each with a structured
    /// `expired` reply). Relative order of survivors is preserved.
    pub fn shed_expired(&mut self, now: Instant) -> Vec<InferRequest> {
        if self.queue.iter().all(|r| r.deadline.is_none_or(|d| d > now)) {
            return Vec::new(); // common case: nothing expired, no churn
        }
        let mut dead = Vec::new();
        let mut live = VecDeque::with_capacity(self.queue.len());
        for r in self.queue.drain(..) {
            if r.deadline.is_some_and(|d| d <= now) {
                dead.push(r);
            } else {
                live.push_back(r);
            }
        }
        self.queue = live;
        self.expired += dead.len() as u64;
        dead
    }

    /// Backlog-proportional retry hint for `overloaded` replies: how long
    /// until a full queue has plausibly drained, assuming one max_batch
    /// cut per max_wait window. Capped at 10s so the hint stays sane when
    /// max_wait is configured large.
    pub fn retry_after(&self) -> Duration {
        let batches = (self.queue.len() / self.policy.max_batch.max(1)) as u32 + 1;
        (self.policy.max_wait * batches).min(Duration::from_secs(10))
    }

    /// Enqueue a session job into its lane; Err(job) when the combined
    /// session backlog is at `queue_cap` (same backpressure contract as
    /// [`Batcher::push`]).
    pub fn push_session(&mut self, job: SessionJob) -> Result<(), SessionJob> {
        if self.session_len() >= self.policy.queue_cap {
            self.rejected += 1;
            return Err(job);
        }
        match job.op {
            SessionOp::Open { .. } | SessionOp::Reopen { .. } => self.open_q.push_back(job),
            SessionOp::Decode { .. } | SessionOp::Close { .. } => self.decode_q.push_back(job),
        }
        Ok(())
    }

    /// Queued session jobs across both lanes.
    pub fn session_len(&self) -> usize {
        self.decode_q.len() + self.open_q.len()
    }

    /// Queued decode / close jobs (the router's decode load signal).
    pub fn decode_len(&self) -> usize {
        self.decode_q.len()
    }

    /// Queued open (prefill) jobs.
    pub fn open_len(&self) -> usize {
        self.open_q.len()
    }

    /// Next decode / close job, FIFO (drain these before anything else).
    pub fn next_decode(&mut self) -> Option<SessionJob> {
        self.decode_q.pop_front()
    }

    /// Next open job, FIFO (drain after the decode lane).
    pub fn next_open(&mut self) -> Option<SessionJob> {
        self.open_q.pop_front()
    }

    /// Next instant the engine must wake the batcher: the cut deadline of
    /// the oldest request (enqueue + max_wait), or sooner if any queued
    /// request expires before that.
    pub fn next_deadline(&self) -> Option<Instant> {
        let cut = self.queue.front().map(|r| r.enqueued + self.policy.max_wait)?;
        let expiry = self.queue.iter().filter_map(|r| r.deadline).min();
        Some(expiry.map_or(cut, |e| e.min(cut)))
    }

    /// Should a batch be cut now? True when the head-of-line request has
    /// waited out max_wait, or a full max_batch of *same-variant* requests
    /// is ready at the head.
    pub fn ready(&self, now: Instant) -> bool {
        match self.queue.front() {
            None => false,
            Some(head) => {
                if now >= head.enqueued + self.policy.max_wait {
                    return true;
                }
                // Count all queued same-variant requests (cut() collects
                // them regardless of position, preserving FIFO order).
                let head_variant = &head.variant;
                self.queue
                    .iter()
                    .filter(|r| &r.variant == head_variant)
                    .count()
                    >= self.policy.max_batch
            }
        }
    }

    /// Cut the next batch: the head request plus up to max_batch-1 more
    /// *with the same variant*, preserving FIFO order for that variant.
    /// Requests of other variants keep their queue positions.
    pub fn cut(&mut self) -> Vec<InferRequest> {
        let Some(head) = self.queue.pop_front() else {
            return Vec::new();
        };
        let variant = head.variant;
        let mut batch = vec![head];
        let mut i = 0;
        while i < self.queue.len() && batch.len() < self.policy.max_batch {
            if self.queue[i].variant == variant {
                // lint: allow(panic, the while guard bounds i inside the queue)
                batch.push(self.queue.remove(i).unwrap());
            } else {
                i += 1;
            }
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn req(id: u64, variant: Option<&str>) -> InferRequest {
        let mut r = InferRequest::new(id, vec![0; 4]);
        if let Some(v) = variant {
            r = r.with_variant(v.parse::<crate::kernels::Variant>().unwrap());
        }
        r
    }

    fn policy(max_batch: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
            queue_cap: 16,
            default_deadline: None,
        }
    }

    #[test]
    fn cuts_on_full_batch() {
        let mut b = Batcher::new(policy(2, 1000));
        b.push(req(1, None)).unwrap();
        assert!(!b.ready(Instant::now()));
        b.push(req(2, None)).unwrap();
        assert!(b.ready(Instant::now()));
        let batch = b.cut();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert!(b.is_empty());
    }

    #[test]
    fn cuts_on_deadline() {
        let mut b = Batcher::new(policy(8, 0));
        b.push(req(1, None)).unwrap();
        // max_wait = 0 → immediately ready even though batch not full
        assert!(b.ready(Instant::now()));
        assert_eq!(b.cut().len(), 1);
    }

    #[test]
    fn groups_by_variant() {
        let mut b = Batcher::new(policy(4, 1000));
        b.push(req(1, Some("dense"))).unwrap();
        b.push(req(2, Some("dsa90"))).unwrap();
        b.push(req(3, Some("dense"))).unwrap();
        let batch = b.cut();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        // dsa90 request still queued, in order
        assert_eq!(b.len(), 1);
        let rest = b.cut();
        assert_eq!(rest[0].id, 2);
    }

    #[test]
    fn full_batch_of_same_variant_triggers_ready() {
        let mut b = Batcher::new(policy(2, 1000));
        b.push(req(1, Some("dense"))).unwrap();
        b.push(req(2, Some("dsa90"))).unwrap();
        assert!(!b.ready(Instant::now())); // head variant has only 1 queued
        b.push(req(3, Some("dense"))).unwrap();
        assert!(b.ready(Instant::now()));
    }

    #[test]
    fn backpressure_rejects() {
        let mut b = Batcher::new(BatchPolicy {
            queue_cap: 2,
            ..policy(8, 1000)
        });
        b.push(req(1, None)).unwrap();
        b.push(req(2, None)).unwrap();
        assert!(b.push(req(3, None)).is_err());
        assert_eq!(b.rejected(), 1);
    }

    fn job(op: SessionOp) -> (SessionJob, std::sync::mpsc::Receiver<ServeResult<SessionReply>>) {
        let (tx, rx) = std::sync::mpsc::channel();
        (
            SessionJob {
                op,
                enqueued: Instant::now(),
                deadline: None,
                reply: tx,
            },
            rx,
        )
    }

    /// Session jobs land in the right lane and drain decode-first, FIFO
    /// within each lane.
    #[test]
    fn session_lanes_drain_decode_first() {
        let mut b = Batcher::new(policy(8, 1000));
        let (open1, _r1) = job(SessionOp::Open {
            prompt: vec![1, 2],
            variant: None,
        });
        let (dec1, _r2) = job(SessionOp::Decode { session: 1, token: 3 });
        let (close1, _r3) = job(SessionOp::Close { session: 2 });
        b.push_session(open1).unwrap();
        b.push_session(dec1).unwrap();
        b.push_session(close1).unwrap();
        assert_eq!((b.session_len(), b.decode_len(), b.open_len()), (3, 2, 1));
        assert!(matches!(
            b.next_decode().unwrap().op,
            SessionOp::Decode { session: 1, token: 3 }
        ));
        assert!(matches!(b.next_decode().unwrap().op, SessionOp::Close { session: 2 }));
        assert!(b.next_decode().is_none());
        assert!(matches!(b.next_open().unwrap().op, SessionOp::Open { .. }));
        assert_eq!(b.session_len(), 0);
    }

    /// The session lanes share the queue-cap backpressure bound (and the
    /// rejection counter) with the inference queue's policy.
    #[test]
    fn session_backpressure_rejects() {
        let mut b = Batcher::new(BatchPolicy {
            queue_cap: 2,
            ..policy(8, 1000)
        });
        let mut rxs = Vec::new();
        for s in 0..2u64 {
            let (j, rx) = job(SessionOp::Decode { session: s, token: 0 });
            b.push_session(j).unwrap();
            rxs.push(rx);
        }
        let (j, _rx) = job(SessionOp::Open {
            prompt: vec![1],
            variant: None,
        });
        assert!(b.push_session(j).is_err());
        assert_eq!(b.rejected(), 1);
    }

    /// Expired requests are shed exactly once, survivors keep their order,
    /// and no-deadline requests never expire.
    #[test]
    fn sheds_expired_preserving_order() {
        let mut b = Batcher::new(policy(8, 1000));
        b.push(req(1, None).with_deadline(Duration::from_secs(0))).unwrap();
        b.push(req(2, None)).unwrap();
        b.push(req(3, None).with_deadline(Duration::from_secs(0))).unwrap();
        b.push(req(4, None).with_deadline(Duration::from_secs(3600))).unwrap();
        let dead = b.shed_expired(Instant::now());
        assert_eq!(dead.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(b.expired(), 2);
        let rest = b.cut();
        assert_eq!(rest.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 4]);
        assert!(b.shed_expired(Instant::now()).is_empty());
        assert_eq!(b.expired(), 2);
    }

    /// The wake-up deadline accounts for request expiry, not just the cut
    /// window, so a short-deadline request is shed promptly.
    #[test]
    fn next_deadline_covers_expiry() {
        let mut b = Batcher::new(policy(8, 60_000));
        b.push(req(1, None).with_deadline(Duration::from_millis(1))).unwrap();
        let wake = b.next_deadline().unwrap();
        assert!(wake <= Instant::now() + Duration::from_secs(1));
    }

    /// retry_after grows with backlog and is capped.
    #[test]
    fn retry_after_scales_with_backlog() {
        let mut b = Batcher::new(policy(2, 10));
        let empty = b.retry_after();
        for i in 0..8 {
            b.push(req(i, None)).unwrap();
        }
        let full = b.retry_after();
        assert!(full > empty, "{full:?} vs {empty:?}");
        assert!(full <= Duration::from_secs(10));
    }

    #[test]
    fn respects_max_batch() {
        let mut b = Batcher::new(policy(3, 0));
        for i in 0..5 {
            b.push(req(i, None)).unwrap();
        }
        assert_eq!(b.cut().len(), 3);
        assert_eq!(b.cut().len(), 2);
    }
}
