//! Engine execution backends.
//!
//! The engine worker drives an [`InferBackend`], decoupling the serving
//! loop (batching, metrics, fan-out) from what executes a batch:
//!
//! * [`NativeBackend`] — always available: the hand-constructed classifier
//!   over the native DSA kernels (`kernels::model`), so a fresh checkout
//!   serves real traffic with no artifacts and no PJRT.
//! * `ArtifactBackend` (`xla` feature) — AOT-compiled HLO modules executed
//!   through the PJRT registry, as produced by `make artifacts`.
//!
//! Backends are constructed **inside** the worker thread via a factory
//! closure (`Engine::start_with`): the PJRT handles are thread-local, so a
//! backend is never required to be `Send`.

use std::collections::HashMap;

use crate::kernels::dispatch::{for_variant, KernelDispatch};
use crate::kernels::model::NativeClassifier;
use crate::util::error::{bail, Context, Result};

/// What the engine worker needs from an execution backend.
pub trait InferBackend {
    /// Expected token-sequence length per request.
    fn seq_len(&self) -> usize;

    /// Logit count per request.
    fn classes(&self) -> usize;

    /// Execution bucket that fits `n` requests (artifact backends round up
    /// to a compiled batch size; native kernels run any size exactly).
    fn bucket_for(&self, n: usize) -> usize;

    /// Warm up `variant` (compile executables / instantiate kernels).
    /// Errors abort engine startup.
    fn preload(&mut self, variant: &str) -> Result<()>;

    /// Execute `bucket * seq_len()` tokens, returning `bucket * classes()`
    /// logits.
    fn run(&mut self, variant: &str, tokens: &[i32], bucket: usize) -> Result<Vec<f32>>;
}

/// Configuration of the hermetic native backend.
#[derive(Debug, Clone)]
pub struct NativeModelConfig {
    pub seq_len: usize,
    /// Seed of the classifier's embedding table.
    pub seed: u64,
    /// Worker threads per attention call (0 = one per core).
    pub threads: usize,
}

impl Default for NativeModelConfig {
    fn default() -> Self {
        NativeModelConfig {
            seq_len: 256,
            seed: 0xD5A,
            threads: 0,
        }
    }
}

/// Native-kernel backend: no artifacts, no PJRT, no external crates.
pub struct NativeBackend {
    model: NativeClassifier,
    threads: usize,
    kernels: HashMap<String, Box<dyn KernelDispatch>>,
}

impl NativeBackend {
    pub fn new(cfg: NativeModelConfig) -> NativeBackend {
        NativeBackend {
            model: NativeClassifier::new(cfg.seq_len, cfg.seed),
            threads: cfg.threads,
            kernels: HashMap::new(),
        }
    }

    /// Factory form for `Engine::start_with`. Validates the config so a
    /// bad `--seq-len` surfaces as a startup error, not a worker panic.
    pub fn boxed(cfg: NativeModelConfig) -> Result<Box<dyn InferBackend>> {
        if cfg.seq_len < 16 {
            bail!("native backend seq_len {} too short (need >= 16)", cfg.seq_len);
        }
        Ok(Box::new(NativeBackend::new(cfg)))
    }

    fn ensure_kernel(&mut self, variant: &str) -> Result<()> {
        if !self.kernels.contains_key(variant) {
            let k = for_variant(variant, self.threads)
                .with_context(|| format!("unknown serving variant {variant:?}"))?;
            self.kernels.insert(variant.to_string(), k);
        }
        Ok(())
    }
}

impl InferBackend for NativeBackend {
    fn seq_len(&self) -> usize {
        self.model.seq_len()
    }

    fn classes(&self) -> usize {
        self.model.classes()
    }

    fn bucket_for(&self, n: usize) -> usize {
        n.max(1)
    }

    fn preload(&mut self, variant: &str) -> Result<()> {
        self.ensure_kernel(variant)?;
        // Warm every worker of the process-wide pool for this model's
        // problem size: the first real request then dispatches with zero
        // thread spawns and zero scratch allocations. `(l, l)` covers the
        // fused tiled kernels too — their key-tile score buffer is the
        // `[..tile]` prefix of the same scratch row, and the per-chunk
        // DSA buffers are bounded by `keep <= l`.
        let l = self.model.seq_len();
        crate::kernels::pool::WorkerPool::global().warm(l, l);
        Ok(())
    }

    fn run(&mut self, variant: &str, tokens: &[i32], bucket: usize) -> Result<Vec<f32>> {
        self.ensure_kernel(variant)?;
        let kernel = self.kernels.get(variant).expect("just inserted").as_ref();
        let sl = self.model.seq_len();
        if tokens.len() != bucket * sl {
            bail!(
                "token buffer {} != bucket {bucket} x seq_len {sl}",
                tokens.len()
            );
        }
        // One batched dispatch for the whole bucket: the kernels
        // parallelize over (sequence, row-range) work items and pay the
        // thread spawn/join cost once per batch instead of once per
        // sequence. Bit-identical to the per-sequence loop it replaced.
        Ok(self.model.logits_batch(tokens, bucket, kernel))
    }
}

/// PJRT artifact backend over the registry (`make artifacts` output).
#[cfg(feature = "xla")]
pub struct ArtifactBackend {
    registry: crate::runtime::Registry,
}

#[cfg(feature = "xla")]
impl ArtifactBackend {
    /// Factory form for `Engine::start_with`; creates the PJRT client on
    /// the calling (worker) thread.
    pub fn boxed(manifest: crate::runtime::Manifest) -> Result<Box<dyn InferBackend>> {
        Ok(Box::new(ArtifactBackend {
            registry: crate::runtime::Registry::from_manifest(manifest)?,
        }))
    }
}

#[cfg(feature = "xla")]
impl InferBackend for ArtifactBackend {
    fn seq_len(&self) -> usize {
        self.registry.manifest.task_seq_len
    }

    fn classes(&self) -> usize {
        self.registry.manifest.task_classes
    }

    fn bucket_for(&self, n: usize) -> usize {
        self.registry.manifest.bucket_for(n)
    }

    fn preload(&mut self, variant: &str) -> Result<()> {
        match self.registry.preload_classifiers(variant)? {
            0 => bail!("no classifier modules for variant {variant}"),
            _ => Ok(()),
        }
    }

    fn run(&mut self, variant: &str, tokens: &[i32], bucket: usize) -> Result<Vec<f32>> {
        let info = self
            .registry
            .manifest
            .classifier(variant, bucket)
            .with_context(|| format!("no classifier for variant={variant} bucket={bucket}"))?;
        let name = info.name.clone();
        let exe = self.registry.load(&name)?;
        let out = exe.run_f32(&[crate::runtime::Arg::i32(
            tokens.to_vec(),
            &[bucket, self.seq_len()],
        )])?;
        out.into_iter().next().context("empty execution result")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_runs_batches() {
        let mut b = NativeBackend::new(NativeModelConfig {
            seq_len: 256,
            ..Default::default()
        });
        assert_eq!(b.seq_len(), 256);
        assert_eq!(b.classes(), 2);
        assert_eq!(b.bucket_for(0), 1);
        assert_eq!(b.bucket_for(5), 5);
        b.preload("dense").unwrap();
        assert!(b.preload("bogus").is_err());
        let tokens = vec![7i32; 2 * 256];
        let logits = b.run("dsa90", &tokens, 2).unwrap();
        assert_eq!(logits.len(), 4);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert!(b.run("dsa90", &tokens, 3).is_err()); // wrong bucket
    }

    #[test]
    fn batched_run_matches_per_sequence_runs() {
        use crate::workload::{Workload, WorkloadConfig};
        let mut b = NativeBackend::new(NativeModelConfig::default());
        let mut wl = Workload::new(WorkloadConfig {
            seq_len: 256,
            seed: 31337,
            ..Default::default()
        });
        let mut tokens = Vec::new();
        for _ in 0..3 {
            tokens.extend(wl.next_request().tokens);
        }
        let batched = b.run("dense", &tokens, 3).unwrap();
        let mut looped = Vec::new();
        for seq in tokens.chunks_exact(256) {
            looped.extend(b.run("dense", seq, 1).unwrap());
        }
        assert_eq!(batched, looped);
    }
}
