//! Engine execution backends.
//!
//! The engine worker drives an [`InferBackend`], decoupling the serving
//! loop (batching, metrics, fan-out) from what executes a batch:
//!
//! * [`NativeBackend`] — always available: the hand-constructed classifier
//!   over the native DSA kernels (`kernels::model`), so a fresh checkout
//!   serves real traffic with no artifacts and no PJRT. Kernels are built
//!   from the typed [`Variant`] through the configured
//!   [`KernelRegistry`](crate::kernels::KernelRegistry)
//!   (`NativeModelConfig::registry`; default = the process-wide global
//!   one) at the backend's [`KernelSpec`] (threads + exec policy +
//!   per-shape tile plan), and
//!   batches execute through the allocation-free
//!   `logits_batch_into` path over warm per-bucket buffers
//!   ([`ModelScratch`]) — the steady-state serving loop performs **zero
//!   per-batch output allocations** (asserted by the warm-dispatch test).
//! * `ArtifactBackend` (`xla` feature) — AOT-compiled HLO modules executed
//!   through the PJRT registry, as produced by `make artifacts`.
//!
//! Backends are constructed **inside** the worker thread via a factory
//! closure (`Engine::start_with`): the PJRT handles are thread-local, so a
//! backend is never required to be `Send`.

use std::collections::HashMap;
use std::sync::Arc;

use crate::kernels::dispatch::{KernelDispatch, KernelRegistry, KernelSpec, Variant};
use crate::kernels::model::{ModelScratch, NativeClassifier};
use crate::util::error::{bail, Context, Result};

/// What the engine worker needs from an execution backend.
pub trait InferBackend {
    /// Expected token-sequence length per request.
    fn seq_len(&self) -> usize;

    /// Logit count per request.
    fn classes(&self) -> usize;

    /// Execution bucket that fits `n` requests (artifact backends round up
    /// to a compiled batch size; native kernels run any size exactly).
    fn bucket_for(&self, n: usize) -> usize;

    /// Warm up `variant` (compile executables / instantiate kernels).
    /// Errors abort engine startup.
    fn preload(&mut self, variant: Variant) -> Result<()>;

    /// Execute `bucket * seq_len()` tokens, writing `bucket * classes()`
    /// logits into `logits` (cleared first). The engine worker owns one
    /// warm `logits` buffer across batches, so a steady-state backend
    /// performs no per-batch output allocation.
    fn run_into(
        &mut self,
        variant: Variant,
        tokens: &[i32],
        bucket: usize,
        logits: &mut Vec<f32>,
    ) -> Result<()>;

    /// Allocating convenience over [`InferBackend::run_into`].
    fn run(&mut self, variant: Variant, tokens: &[i32], bucket: usize) -> Result<Vec<f32>> {
        let mut logits = Vec::new();
        self.run_into(variant, tokens, bucket, &mut logits)?;
        Ok(logits)
    }
}

/// Configuration of the hermetic native backend.
#[derive(Debug, Clone)]
pub struct NativeModelConfig {
    pub seq_len: usize,
    /// Seed of the classifier's embedding table.
    pub seed: u64,
    /// How attention dispatches execute: worker threads (0 = one per
    /// core), pool-vs-spawn policy, and the per-shape tile plan —
    /// replacing the bare `threads: usize` this config used to carry.
    pub spec: KernelSpec,
    /// Kernel registry the backend builds variants from; `None` = the
    /// process-wide [`KernelRegistry::global`]. This is the embedder's
    /// plug-in point: register a custom variant family here and the
    /// serving stack picks it up without any in-crate edits.
    pub registry: Option<Arc<KernelRegistry>>,
}

impl Default for NativeModelConfig {
    /// The serving defaults: `seq_len = 256`, fixed seed, default
    /// [`KernelSpec`] (all cores, pool execution, committed tile table),
    /// global registry.
    fn default() -> NativeModelConfig {
        NativeModelConfig {
            seq_len: 256,
            seed: 0xD5A,
            spec: KernelSpec::default(),
            registry: None,
        }
    }
}

/// Native-kernel backend: no artifacts, no PJRT, no external crates.
pub struct NativeBackend {
    model: NativeClassifier,
    spec: KernelSpec,
    registry: Option<Arc<KernelRegistry>>,
    kernels: HashMap<Variant, Box<dyn KernelDispatch>>,
    /// Warm per-bucket batch buffers (Q/K/V + context output), reused
    /// across every batch this backend executes.
    scratch: ModelScratch,
}

impl NativeBackend {
    pub fn new(cfg: NativeModelConfig) -> NativeBackend {
        NativeBackend {
            model: NativeClassifier::new(cfg.seq_len, cfg.seed),
            spec: cfg.spec,
            registry: cfg.registry,
            kernels: HashMap::new(),
            scratch: ModelScratch::new(),
        }
    }

    /// Factory form for `Engine::start_with`. Validates the config so a
    /// bad `--seq-len` surfaces as a startup error, not a worker panic.
    pub fn boxed(cfg: NativeModelConfig) -> Result<Box<dyn InferBackend>> {
        if cfg.seq_len < 16 {
            bail!("native backend seq_len {} too short (need >= 16)", cfg.seq_len);
        }
        Ok(Box::new(NativeBackend::new(cfg)))
    }

    fn ensure_kernel(&mut self, variant: Variant) -> Result<()> {
        if !self.kernels.contains_key(&variant) {
            // The registry decides which family builds the kernel — new
            // families plug in there (via `NativeModelConfig::registry`
            // or the global default), not here.
            let registry = self.registry.as_deref().unwrap_or_else(KernelRegistry::global);
            let k = registry
                .build(&variant, &self.spec)
                .with_context(|| format!("no registered kernel family for variant {variant}"))?;
            self.kernels.insert(variant, k);
        }
        Ok(())
    }

    /// Batch-buffer grow events so far (warm steady state records none;
    /// see the warm-dispatch test).
    pub fn scratch_grows(&self) -> u64 {
        self.scratch.grow_events()
    }
}

impl InferBackend for NativeBackend {
    fn seq_len(&self) -> usize {
        self.model.seq_len()
    }

    fn classes(&self) -> usize {
        self.model.classes()
    }

    fn bucket_for(&self, n: usize) -> usize {
        n.max(1)
    }

    fn preload(&mut self, variant: Variant) -> Result<()> {
        self.ensure_kernel(variant)?;
        // Warm every worker of the process-wide pool for this model's
        // problem size: the first real request then dispatches with zero
        // thread spawns and zero scratch allocations. `(l, l)` covers the
        // fused tiled kernels too — their key-tile score buffer is the
        // `[..tile]` prefix of the same scratch row, and the per-chunk
        // DSA buffers are bounded by `keep <= l`.
        let l = self.model.seq_len();
        crate::kernels::pool::WorkerPool::global().warm(l, l);
        Ok(())
    }

    fn run_into(
        &mut self,
        variant: Variant,
        tokens: &[i32],
        bucket: usize,
        logits: &mut Vec<f32>,
    ) -> Result<()> {
        self.ensure_kernel(variant)?;
        let kernel = self.kernels.get(&variant).expect("just inserted").as_ref();
        let sl = self.model.seq_len();
        if tokens.len() != bucket * sl {
            bail!(
                "token buffer {} != bucket {bucket} x seq_len {sl}",
                tokens.len()
            );
        }
        // One batched dispatch for the whole bucket, written into the
        // backend's warm buffers: the kernels parallelize over (sequence,
        // row-range) work items, pay the dispatch cost once per batch,
        // and — once the buffers have seen the bucket size — allocate
        // nothing. Bit-identical to the per-sequence loop it replaced.
        self.model
            .logits_batch_into(tokens, bucket, kernel, &mut self.scratch, logits);
        Ok(())
    }
}

/// PJRT artifact backend over the registry (`make artifacts` output).
#[cfg(feature = "xla")]
pub struct ArtifactBackend {
    registry: crate::runtime::Registry,
}

#[cfg(feature = "xla")]
impl ArtifactBackend {
    /// Factory form for `Engine::start_with`; creates the PJRT client on
    /// the calling (worker) thread.
    pub fn boxed(manifest: crate::runtime::Manifest) -> Result<Box<dyn InferBackend>> {
        Ok(Box::new(ArtifactBackend {
            registry: crate::runtime::Registry::from_manifest(manifest)?,
        }))
    }
}

#[cfg(feature = "xla")]
impl InferBackend for ArtifactBackend {
    fn seq_len(&self) -> usize {
        self.registry.manifest.task_seq_len
    }

    fn classes(&self) -> usize {
        self.registry.manifest.task_classes
    }

    fn bucket_for(&self, n: usize) -> usize {
        self.registry.manifest.bucket_for(n)
    }

    fn preload(&mut self, variant: Variant) -> Result<()> {
        // Artifact manifests key modules by the rendered variant name —
        // Display, not a string parse.
        match self.registry.preload_classifiers(&variant.to_string())? {
            0 => bail!("no classifier modules for variant {variant}"),
            _ => Ok(()),
        }
    }

    fn run_into(
        &mut self,
        variant: Variant,
        tokens: &[i32],
        bucket: usize,
        logits: &mut Vec<f32>,
    ) -> Result<()> {
        let vname = variant.to_string();
        let info = self
            .registry
            .manifest
            .classifier(&vname, bucket)
            .with_context(|| format!("no classifier for variant={vname} bucket={bucket}"))?;
        let name = info.name.clone();
        let exe = self.registry.load(&name)?;
        let out = exe.run_f32(&[crate::runtime::Arg::i32(
            tokens.to_vec(),
            &[bucket, self.seq_len()],
        )])?;
        let out = out.into_iter().next().context("empty execution result")?;
        logits.clear();
        logits.extend_from_slice(&out);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DSA90: Variant = Variant::Dsa { pct: 90 };

    #[test]
    fn native_backend_runs_batches() {
        let mut b = NativeBackend::new(NativeModelConfig {
            seq_len: 256,
            ..Default::default()
        });
        assert_eq!(b.seq_len(), 256);
        assert_eq!(b.classes(), 2);
        assert_eq!(b.bucket_for(0), 1);
        assert_eq!(b.bucket_for(5), 5);
        b.preload(Variant::Dense).unwrap();
        let tokens = vec![7i32; 2 * 256];
        let logits = b.run(DSA90, &tokens, 2).unwrap();
        assert_eq!(logits.len(), 4);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert!(b.run(DSA90, &tokens, 3).is_err()); // wrong bucket
    }

    /// The registry plug-in point actually reaches serving: a backend
    /// configured with a custom registry builds kernels from it (here, a
    /// registry that only knows the dense family — DSA variants fail
    /// preload with "no registered kernel family" instead of silently
    /// falling back to the global registry).
    #[test]
    fn custom_registry_drives_kernel_construction() {
        use crate::kernels::dispatch::DenseKernel;
        let mut registry = KernelRegistry::empty();
        registry.register("dense-only", |variant, spec| match variant {
            Variant::Dense => Some(Box::new(DenseKernel::new(spec.clone()))),
            _ => None,
        });
        let mut b = NativeBackend::new(NativeModelConfig {
            registry: Some(Arc::new(registry)),
            ..Default::default()
        });
        b.preload(Variant::Dense).unwrap();
        let err = b.preload(DSA90).expect_err("family not registered");
        assert!(
            format!("{err:#}").contains("no registered kernel family"),
            "custom registry must be consulted, not the global one"
        );
        let tokens = vec![7i32; 256];
        assert_eq!(b.run(Variant::Dense, &tokens, 1).unwrap().len(), 2);
        assert!(b.run(DSA90, &tokens, 1).is_err());
    }

    #[test]
    fn batched_run_matches_per_sequence_runs() {
        use crate::workload::{Workload, WorkloadConfig};
        let mut b = NativeBackend::new(NativeModelConfig::default());
        let mut wl = Workload::new(WorkloadConfig {
            seq_len: 256,
            seed: 31337,
            ..Default::default()
        });
        let mut tokens = Vec::new();
        for _ in 0..3 {
            tokens.extend(wl.next_request().tokens);
        }
        let batched = b.run(Variant::Dense, &tokens, 3).unwrap();
        let mut looped = Vec::new();
        for seq in tokens.chunks_exact(256) {
            looped.extend(b.run(Variant::Dense, seq, 1).unwrap());
        }
        assert_eq!(batched, looped);
    }

    /// The engine-facing acceptance test for the allocation-free serving
    /// path (warm-scratch style): once the backend has executed a bucket
    /// size, further batches at that size — same or different variants —
    /// record **zero** batch-buffer grows and reuse the worker-owned
    /// logits buffer without regrowing it.
    #[test]
    fn warm_backend_dispatch_is_allocation_free() {
        use crate::workload::{Workload, WorkloadConfig};
        let mut b = NativeBackend::new(NativeModelConfig::default());
        b.preload(Variant::Dense).unwrap();
        b.preload(DSA90).unwrap();
        let mut wl = Workload::new(WorkloadConfig {
            seq_len: 256,
            seed: 2024,
            ..Default::default()
        });
        let bucket = 4;
        let mut tokens = Vec::with_capacity(bucket * 256);
        for _ in 0..bucket {
            tokens.extend(wl.next_request().tokens);
        }
        // Cold pass grows the buffers (and lazily, nothing else after).
        let mut logits = Vec::new();
        b.run_into(Variant::Dense, &tokens, bucket, &mut logits).unwrap();
        let first = logits.clone();
        let warm = b.scratch_grows();
        let warm_cap = logits.capacity();
        assert!(warm >= 1, "cold dispatch must have grown the batch buffers");
        // Steady state: same bucket, both variants, smaller buckets.
        for _ in 0..3 {
            b.run_into(Variant::Dense, &tokens, bucket, &mut logits).unwrap();
            assert_eq!(logits, first, "warm dispatch changed logits");
            b.run_into(DSA90, &tokens, bucket, &mut logits).unwrap();
            b.run_into(Variant::Dense, &tokens[..256], 1, &mut logits).unwrap();
            assert_eq!(&logits[..], &first[..2]);
        }
        assert_eq!(b.scratch_grows(), warm, "warm dispatch allocated batch buffers");
        assert_eq!(logits.capacity(), warm_cap, "worker logits buffer regrew");
    }
}
