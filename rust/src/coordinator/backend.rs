//! Engine execution backends.
//!
//! The engine worker drives an [`InferBackend`], decoupling the serving
//! loop (batching, metrics, fan-out) from what executes a batch:
//!
//! * [`NativeBackend`] — always available: the hand-constructed classifier
//!   over the native DSA kernels (`kernels::model`), so a fresh checkout
//!   serves real traffic with no artifacts and no PJRT. Kernels are built
//!   from the typed [`Variant`] through the configured
//!   [`KernelRegistry`](crate::kernels::KernelRegistry)
//!   (`NativeModelConfig::registry`; default = the process-wide global
//!   one) at the backend's [`KernelSpec`] (threads + exec policy +
//!   per-shape tile plan), and
//!   batches execute through the allocation-free
//!   `logits_batch_into` path over warm per-bucket buffers
//!   ([`ModelScratch`]) — the steady-state serving loop performs **zero
//!   per-batch output allocations** (asserted by the warm-dispatch test).
//! * `ArtifactBackend` (`xla` feature) — AOT-compiled HLO modules executed
//!   through the PJRT registry, as produced by `make artifacts`.
//!
//! Backends are constructed **inside** the worker thread via a factory
//! closure (`Engine::start_with`): the PJRT handles are thread-local, so a
//! backend is never required to be `Send`.

use std::collections::HashMap;
use std::sync::Arc;

use crate::kernels::dispatch::{KernelDispatch, KernelRegistry, KernelSpec, Variant};
use crate::kernels::kvcache::{KvCachePool, KvPoolStats};
use crate::kernels::model::{DecodeSession, ModelScratch, NativeClassifier};
use crate::kernels::scratch::Scratch;
use crate::util::error::{bail, Context, Result};
use crate::util::faults::FaultInjector;

/// What the engine worker needs from an execution backend.
pub trait InferBackend {
    /// Expected token-sequence length per request.
    fn seq_len(&self) -> usize;

    /// Logit count per request.
    fn classes(&self) -> usize;

    /// Execution bucket that fits `n` requests (artifact backends round up
    /// to a compiled batch size; native kernels run any size exactly).
    fn bucket_for(&self, n: usize) -> usize;

    /// Warm up `variant` (compile executables / instantiate kernels).
    /// Errors abort engine startup.
    fn preload(&mut self, variant: Variant) -> Result<()>;

    /// Execute `bucket * seq_len()` tokens, writing `bucket * classes()`
    /// logits into `logits` (cleared first). The engine worker owns one
    /// warm `logits` buffer across batches, so a steady-state backend
    /// performs no per-batch output allocation.
    fn run_into(
        &mut self,
        variant: Variant,
        tokens: &[i32],
        bucket: usize,
        logits: &mut Vec<f32>,
    ) -> Result<()>;

    /// Allocating convenience over [`InferBackend::run_into`].
    fn run(&mut self, variant: Variant, tokens: &[i32], bucket: usize) -> Result<Vec<f32>> {
        let mut logits = Vec::new();
        self.run_into(variant, tokens, bucket, &mut logits)?;
        Ok(logits)
    }

    // --- autoregressive decode sessions -------------------------------
    //
    // Default implementations return a structured "unsupported" error, so
    // backends without a decode path (the AOT artifact backend compiles
    // fixed-shape one-shot modules) reject session traffic cleanly
    // instead of panicking or needing their own stubs.

    /// Open decode session `id` on `variant`, prefilling the cache with
    /// `prompt`. Returns the resident token count.
    fn open_session(&mut self, id: u64, variant: Variant, prompt: &[i32]) -> Result<usize> {
        let _ = (id, variant, prompt);
        bail!("backend does not support decode sessions")
    }

    /// Rebuild session `id` from a journal: prefill `prompt`, then
    /// append `decoded` without running the decode kernel. Because the
    /// kernel never mutates the cache, the resulting state is bitwise-
    /// identical to having decoded the same tokens step by step — this
    /// is the migration path for sessions replayed off a dead replica.
    /// Returns the resident token count (`prompt.len() + decoded.len()`).
    fn reopen_session(
        &mut self,
        id: u64,
        variant: Variant,
        prompt: &[i32],
        decoded: &[i32],
    ) -> Result<usize> {
        let _ = (id, variant, prompt, decoded);
        bail!("backend does not support decode sessions")
    }

    /// Append `token` to session `id` and run one decode step, writing
    /// `classes()` logits into `logits` (cleared first; the engine worker
    /// owns one warm buffer, so steady-state decode performs no per-step
    /// output allocation). Returns the resident token count.
    fn decode_into(&mut self, id: u64, token: i32, logits: &mut Vec<f32>) -> Result<usize> {
        let _ = (id, token, logits);
        bail!("backend does not support decode sessions")
    }

    /// Close session `id`, releasing its cache for reuse. Returns the
    /// token count that was resident.
    fn close_session(&mut self, id: u64) -> Result<usize> {
        let _ = id;
        bail!("backend does not support decode sessions")
    }

    /// Live decode sessions (metrics gauge).
    fn session_count(&self) -> usize {
        0
    }

    /// Tokens resident across all live session caches (metrics gauge).
    fn resident_tokens(&self) -> usize {
        0
    }

    /// Cache bucket-grow events across live sessions **and** the pooled
    /// free list — flat once steady-state traffic runs entirely on
    /// recycled capacity (metrics gauge; the e2e warm-cache test pins it).
    fn cache_grows(&self) -> u64 {
        0
    }
}

/// Configuration of the hermetic native backend.
#[derive(Debug, Clone)]
pub struct NativeModelConfig {
    pub seq_len: usize,
    /// Seed of the classifier's embedding table.
    pub seed: u64,
    /// How attention dispatches execute: worker threads (0 = one per
    /// core), pool-vs-spawn policy, and the per-shape tile plan —
    /// replacing the bare `threads: usize` this config used to carry.
    pub spec: KernelSpec,
    /// Kernel registry the backend builds variants from; `None` = the
    /// process-wide [`KernelRegistry::global`]. This is the embedder's
    /// plug-in point: register a custom variant family here and the
    /// serving stack picks it up without any in-crate edits.
    pub registry: Option<Arc<KernelRegistry>>,
    /// Seeded fault injector polled before every batch / prefill / decode
    /// (`backend.run` / `backend.open` / `backend.decode` sites); `None`
    /// (the default) compiles the hooks down to a branch on a missing
    /// option. Chaos tests arm this to prove the engine survives backend
    /// panics, errors and stalls.
    pub faults: Option<Arc<FaultInjector>>,
}

impl Default for NativeModelConfig {
    /// The serving defaults: `seq_len = 256`, fixed seed, default
    /// [`KernelSpec`] (all cores, pool execution, committed tile table),
    /// global registry.
    fn default() -> NativeModelConfig {
        NativeModelConfig {
            seq_len: 256,
            seed: 0xD5A,
            spec: KernelSpec::default(),
            registry: None,
            faults: None,
        }
    }
}

/// One live decode session as the native backend tracks it: the model
/// session plus the variant it was opened on (decode steps always run the
/// session's own kernel — the adaptive router steers *new* sessions, not
/// live caches whose mask history would otherwise shift mid-stream).
struct NativeSession {
    sess: DecodeSession,
    variant: Variant,
}

/// Native-kernel backend: no artifacts, no PJRT, no external crates.
pub struct NativeBackend {
    model: NativeClassifier,
    spec: KernelSpec,
    registry: Option<Arc<KernelRegistry>>,
    /// Chaos hook, polled first in `run_into`/`open_session`/`decode_into`.
    faults: Option<Arc<FaultInjector>>,
    kernels: HashMap<Variant, Box<dyn KernelDispatch>>,
    /// Warm per-bucket batch buffers (Q/K/V + context output), reused
    /// across every batch this backend executes.
    scratch: ModelScratch,
    /// Live decode sessions by engine-assigned id.
    sessions: HashMap<u64, NativeSession>,
    /// Recycler for closed sessions' caches — steady-state session churn
    /// reuses grown buckets instead of allocating.
    cache_pool: KvCachePool,
    /// Warm kernel scratch for the single-query decode path (the batch
    /// path has its own per-worker scratch inside the pool).
    decode_scratch: Scratch,
    /// Warm one-hot value row and context row for decode steps.
    onehot: Vec<f32>,
    ctx_row: Vec<f32>,
}

impl NativeBackend {
    pub fn new(cfg: NativeModelConfig) -> NativeBackend {
        let model = NativeClassifier::new(cfg.seq_len, cfg.seed);
        let (dk, dv) = model.cache_dims();
        NativeBackend {
            model,
            spec: cfg.spec,
            registry: cfg.registry,
            faults: cfg.faults,
            kernels: HashMap::new(),
            scratch: ModelScratch::new(),
            sessions: HashMap::new(),
            cache_pool: KvCachePool::new(dk, dv),
            decode_scratch: Scratch::new(),
            onehot: Vec::new(),
            ctx_row: Vec::new(),
        }
    }

    /// Factory form for `Engine::start_with`. Validates the config so a
    /// bad `--seq-len` surfaces as a startup error, not a worker panic.
    pub fn boxed(cfg: NativeModelConfig) -> Result<Box<dyn InferBackend>> {
        if cfg.seq_len < 16 {
            bail!("native backend seq_len {} too short (need >= 16)", cfg.seq_len);
        }
        Ok(Box::new(NativeBackend::new(cfg)))
    }

    fn ensure_kernel(&mut self, variant: Variant) -> Result<()> {
        if !self.kernels.contains_key(&variant) {
            // The registry decides which family builds the kernel — new
            // families plug in there (via `NativeModelConfig::registry`
            // or the global default), not here.
            let registry = self.registry.as_deref().unwrap_or_else(KernelRegistry::global);
            let k = registry
                .build(&variant, &self.spec)
                .with_context(|| format!("no registered kernel family for variant {variant}"))?;
            self.kernels.insert(variant, k);
        }
        Ok(())
    }

    /// Batch-buffer grow events so far (warm steady state records none;
    /// see the warm-dispatch test).
    pub fn scratch_grows(&self) -> u64 {
        self.scratch.grow_events()
    }

    /// Session-cache recycler counters (created / recycled / parked).
    pub fn cache_pool_stats(&self) -> KvPoolStats {
        self.cache_pool.stats()
    }

    /// Poll the chaos hook at `site` (no-op without an injector).
    fn fire(&self, site: &'static str) -> Result<()> {
        match &self.faults {
            Some(f) => f.fire(site),
            None => Ok(()),
        }
    }
}

impl InferBackend for NativeBackend {
    fn seq_len(&self) -> usize {
        self.model.seq_len()
    }

    fn classes(&self) -> usize {
        self.model.classes()
    }

    fn bucket_for(&self, n: usize) -> usize {
        n.max(1)
    }

    fn preload(&mut self, variant: Variant) -> Result<()> {
        self.ensure_kernel(variant)?;
        // Warm every worker of the process-wide pool for this model's
        // problem size: the first real request then dispatches with zero
        // thread spawns and zero scratch allocations. `(l, l)` covers the
        // fused tiled kernels too — their key-tile score buffer is the
        // `[..tile]` prefix of the same scratch row, and the per-chunk
        // DSA buffers are bounded by `keep <= l`.
        let l = self.model.seq_len();
        crate::kernels::pool::WorkerPool::global().warm(l, l);
        Ok(())
    }

    fn run_into(
        &mut self,
        variant: Variant,
        tokens: &[i32],
        bucket: usize,
        logits: &mut Vec<f32>,
    ) -> Result<()> {
        self.fire("backend.run")?;
        self.ensure_kernel(variant)?;
        // lint: allow(panic, ensure_kernel on the line above inserted this entry)
        let kernel = self.kernels.get(&variant).expect("just inserted").as_ref();
        let sl = self.model.seq_len();
        if tokens.len() != bucket * sl {
            bail!(
                "token buffer {} != bucket {bucket} x seq_len {sl}",
                tokens.len()
            );
        }
        // One batched dispatch for the whole bucket, written into the
        // backend's warm buffers: the kernels parallelize over (sequence,
        // row-range) work items, pay the dispatch cost once per batch,
        // and — once the buffers have seen the bucket size — allocate
        // nothing. Bit-identical to the per-sequence loop it replaced.
        self.model
            .logits_batch_into(tokens, bucket, kernel, &mut self.scratch, logits);
        Ok(())
    }

    fn open_session(&mut self, id: u64, variant: Variant, prompt: &[i32]) -> Result<usize> {
        self.fire("backend.open")?;
        self.ensure_kernel(variant)?;
        if self.sessions.contains_key(&id) {
            bail!("session {id} already open");
        }
        let sl = self.model.seq_len();
        if prompt.is_empty() || prompt.len() > sl {
            bail!(
                "prompt length {} out of range 1..={sl} for session {id}",
                prompt.len()
            );
        }
        let cache = self.cache_pool.take();
        let sess = self.model.open_session(prompt, cache, &mut self.onehot);
        let resident = sess.len();
        self.sessions.insert(id, NativeSession { sess, variant });
        Ok(resident)
    }

    fn reopen_session(
        &mut self,
        id: u64,
        variant: Variant,
        prompt: &[i32],
        decoded: &[i32],
    ) -> Result<usize> {
        // Same chaos site as open: a reopen is an open from the backend's
        // point of view, so fault matrices cover both with one knob.
        self.fire("backend.open")?;
        self.ensure_kernel(variant)?;
        if self.sessions.contains_key(&id) {
            bail!("session {id} already open");
        }
        let sl = self.model.seq_len();
        let total = prompt.len() + decoded.len();
        if prompt.is_empty() || total > sl {
            bail!(
                "replay length {total} (prompt {} + decoded {}) out of range 1..={sl} \
                 for session {id}",
                prompt.len(),
                decoded.len()
            );
        }
        let cache = self.cache_pool.take();
        let sess = self.model.reopen_session(prompt, decoded, cache, &mut self.onehot);
        let resident = sess.len();
        self.sessions.insert(id, NativeSession { sess, variant });
        Ok(resident)
    }

    fn decode_into(&mut self, id: u64, token: i32, logits: &mut Vec<f32>) -> Result<usize> {
        self.fire("backend.decode")?;
        let ns = match self.sessions.get_mut(&id) {
            Some(ns) => ns,
            None => bail!("unknown session {id} (closed or evicted)"),
        };
        let sl = self.model.seq_len();
        if ns.sess.len() >= sl {
            bail!("session {id} at the model's sequence capacity ({sl} tokens)");
        }
        // lint: allow(panic, open_session preloads the kernel for every live session)
        let kernel = self.kernels.get(&ns.variant).expect("ensured at open").as_ref();
        let out = self.model.decode_step(
            &mut ns.sess,
            token,
            kernel,
            &mut self.decode_scratch,
            &mut self.onehot,
            &mut self.ctx_row,
        );
        logits.clear();
        logits.extend_from_slice(&out);
        Ok(ns.sess.len())
    }

    fn close_session(&mut self, id: u64) -> Result<usize> {
        match self.sessions.remove(&id) {
            Some(ns) => {
                let resident = ns.sess.len();
                self.cache_pool.put(ns.sess.into_cache());
                Ok(resident)
            }
            None => bail!("unknown session {id} (closed or evicted)"),
        }
    }

    fn session_count(&self) -> usize {
        self.sessions.len()
    }

    fn resident_tokens(&self) -> usize {
        self.sessions.values().map(|ns| ns.sess.len()).sum()
    }

    fn cache_grows(&self) -> u64 {
        let live: u64 = self.sessions.values().map(|ns| ns.sess.cache_grow_events()).sum();
        live + self.cache_pool.grow_events()
    }
}

/// PJRT artifact backend over the registry (`make artifacts` output).
#[cfg(feature = "xla")]
pub struct ArtifactBackend {
    registry: crate::runtime::Registry,
}

#[cfg(feature = "xla")]
impl ArtifactBackend {
    /// Factory form for `Engine::start_with`; creates the PJRT client on
    /// the calling (worker) thread.
    pub fn boxed(manifest: crate::runtime::Manifest) -> Result<Box<dyn InferBackend>> {
        Ok(Box::new(ArtifactBackend {
            registry: crate::runtime::Registry::from_manifest(manifest)?,
        }))
    }
}

#[cfg(feature = "xla")]
impl InferBackend for ArtifactBackend {
    fn seq_len(&self) -> usize {
        self.registry.manifest.task_seq_len
    }

    fn classes(&self) -> usize {
        self.registry.manifest.task_classes
    }

    fn bucket_for(&self, n: usize) -> usize {
        self.registry.manifest.bucket_for(n)
    }

    fn preload(&mut self, variant: Variant) -> Result<()> {
        // Artifact manifests key modules by the rendered variant name —
        // Display, not a string parse.
        match self.registry.preload_classifiers(&variant.to_string())? {
            0 => bail!("no classifier modules for variant {variant}"),
            _ => Ok(()),
        }
    }

    fn run_into(
        &mut self,
        variant: Variant,
        tokens: &[i32],
        bucket: usize,
        logits: &mut Vec<f32>,
    ) -> Result<()> {
        let vname = variant.to_string();
        let info = self
            .registry
            .manifest
            .classifier(&vname, bucket)
            .with_context(|| format!("no classifier for variant={vname} bucket={bucket}"))?;
        let name = info.name.clone();
        let exe = self.registry.load(&name)?;
        let out = exe.run_f32(&[crate::runtime::Arg::i32(
            tokens.to_vec(),
            &[bucket, self.seq_len()],
        )])?;
        let out = out.into_iter().next().context("empty execution result")?;
        logits.clear();
        logits.extend_from_slice(&out);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DSA90: Variant = Variant::Dsa { pct: 90 };

    #[test]
    fn native_backend_runs_batches() {
        let mut b = NativeBackend::new(NativeModelConfig {
            seq_len: 256,
            ..Default::default()
        });
        assert_eq!(b.seq_len(), 256);
        assert_eq!(b.classes(), 2);
        assert_eq!(b.bucket_for(0), 1);
        assert_eq!(b.bucket_for(5), 5);
        b.preload(Variant::Dense).unwrap();
        let tokens = vec![7i32; 2 * 256];
        let logits = b.run(DSA90, &tokens, 2).unwrap();
        assert_eq!(logits.len(), 4);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert!(b.run(DSA90, &tokens, 3).is_err()); // wrong bucket
    }

    /// The registry plug-in point actually reaches serving: a backend
    /// configured with a custom registry builds kernels from it (here, a
    /// registry that only knows the dense family — DSA variants fail
    /// preload with "no registered kernel family" instead of silently
    /// falling back to the global registry).
    #[test]
    fn custom_registry_drives_kernel_construction() {
        use crate::kernels::dispatch::DenseKernel;
        let mut registry = KernelRegistry::empty();
        registry.register("dense-only", |variant, spec| match variant {
            Variant::Dense => Some(Box::new(DenseKernel::new(spec.clone()))),
            _ => None,
        });
        let mut b = NativeBackend::new(NativeModelConfig {
            registry: Some(Arc::new(registry)),
            ..Default::default()
        });
        b.preload(Variant::Dense).unwrap();
        let err = b.preload(DSA90).expect_err("family not registered");
        assert!(
            format!("{err:#}").contains("no registered kernel family"),
            "custom registry must be consulted, not the global one"
        );
        let tokens = vec![7i32; 256];
        assert_eq!(b.run(Variant::Dense, &tokens, 1).unwrap().len(), 2);
        assert!(b.run(DSA90, &tokens, 1).is_err());
    }

    #[test]
    fn batched_run_matches_per_sequence_runs() {
        use crate::workload::{Workload, WorkloadConfig};
        let mut b = NativeBackend::new(NativeModelConfig::default());
        let mut wl = Workload::new(WorkloadConfig {
            seq_len: 256,
            seed: 31337,
            ..Default::default()
        });
        let mut tokens = Vec::new();
        for _ in 0..3 {
            tokens.extend(wl.next_request().tokens);
        }
        let batched = b.run(Variant::Dense, &tokens, 3).unwrap();
        let mut looped = Vec::new();
        for seq in tokens.chunks_exact(256) {
            looped.extend(b.run(Variant::Dense, seq, 1).unwrap());
        }
        assert_eq!(batched, looped);
    }

    /// The engine-facing acceptance test for the allocation-free serving
    /// path (warm-scratch style): once the backend has executed a bucket
    /// size, further batches at that size — same or different variants —
    /// record **zero** batch-buffer grows and reuse the worker-owned
    /// logits buffer without regrowing it.
    #[test]
    fn warm_backend_dispatch_is_allocation_free() {
        use crate::workload::{Workload, WorkloadConfig};
        let mut b = NativeBackend::new(NativeModelConfig::default());
        b.preload(Variant::Dense).unwrap();
        b.preload(DSA90).unwrap();
        let mut wl = Workload::new(WorkloadConfig {
            seq_len: 256,
            seed: 2024,
            ..Default::default()
        });
        let bucket = 4;
        let mut tokens = Vec::with_capacity(bucket * 256);
        for _ in 0..bucket {
            tokens.extend(wl.next_request().tokens);
        }
        // Cold pass grows the buffers (and lazily, nothing else after).
        let mut logits = Vec::new();
        b.run_into(Variant::Dense, &tokens, bucket, &mut logits).unwrap();
        let first = logits.clone();
        let warm = b.scratch_grows();
        let warm_cap = logits.capacity();
        assert!(warm >= 1, "cold dispatch must have grown the batch buffers");
        // Steady state: same bucket, both variants, smaller buckets.
        for _ in 0..3 {
            b.run_into(Variant::Dense, &tokens, bucket, &mut logits).unwrap();
            assert_eq!(logits, first, "warm dispatch changed logits");
            b.run_into(DSA90, &tokens, bucket, &mut logits).unwrap();
            b.run_into(Variant::Dense, &tokens[..256], 1, &mut logits).unwrap();
            assert_eq!(&logits[..], &first[..2]);
        }
        assert_eq!(b.scratch_grows(), warm, "warm dispatch allocated batch buffers");
        assert_eq!(logits.capacity(), warm_cap, "worker logits buffer regrew");
    }

    /// Session decode through the backend reproduces the one-shot batch
    /// path **bitwise** once the cache reaches `seq_len`, for dense and
    /// DSA variants alike.
    #[test]
    fn session_decode_matches_one_shot_run() {
        use crate::workload::{Workload, WorkloadConfig};
        let mut b = NativeBackend::new(NativeModelConfig::default());
        let mut wl = Workload::new(WorkloadConfig {
            seq_len: 256,
            seed: 606,
            ..Default::default()
        });
        for (id, variant) in [(1u64, Variant::Dense), (2u64, DSA90)] {
            let tokens = wl.next_request().tokens;
            let oneshot = b.run(variant, &tokens, 1).unwrap();
            let split = 200;
            let resident = b.open_session(id, variant, &tokens[..split]).unwrap();
            assert_eq!(resident, split);
            let mut logits = Vec::new();
            for (i, &t) in tokens[split..].iter().enumerate() {
                let resident = b.decode_into(id, t, &mut logits).unwrap();
                assert_eq!(resident, split + i + 1);
                assert_eq!(logits.len(), 2);
            }
            assert_eq!(
                (logits[0].to_bits(), logits[1].to_bits()),
                (oneshot[0].to_bits(), oneshot[1].to_bits()),
                "{variant}: decode diverged from one-shot run"
            );
            assert_eq!(b.session_count(), 1);
            assert_eq!(b.resident_tokens(), 256);
            assert_eq!(b.close_session(id).unwrap(), 256);
            assert_eq!(b.session_count(), 0);
        }
    }

    /// Session misuse surfaces as structured errors, never panics:
    /// unknown ids, duplicate opens, bad prompt lengths and decoding past
    /// the model's sequence capacity.
    #[test]
    fn session_errors_are_structured() {
        let mut b = NativeBackend::new(NativeModelConfig {
            seq_len: 16,
            ..Default::default()
        });
        let mut logits = Vec::new();
        let err = b.decode_into(9, 1, &mut logits).expect_err("unknown id");
        assert!(format!("{err:#}").contains("unknown session"));
        let err = b.close_session(9).expect_err("unknown id");
        assert!(format!("{err:#}").contains("unknown session"));
        assert!(b.open_session(1, Variant::Dense, &[]).is_err(), "empty prompt");
        assert!(
            b.open_session(1, Variant::Dense, &[1i32; 17]).is_err(),
            "prompt longer than seq_len"
        );
        b.open_session(1, Variant::Dense, &[5i32; 15]).unwrap();
        let err = b.open_session(1, Variant::Dense, &[5i32; 2]).expect_err("dup");
        assert!(format!("{err:#}").contains("already open"));
        b.decode_into(1, 7, &mut logits).unwrap(); // 16th token: at capacity
        let err = b.decode_into(1, 7, &mut logits).expect_err("capacity");
        assert!(format!("{err:#}").contains("sequence capacity"));
        assert_eq!(b.close_session(1).unwrap(), 16);
    }

    /// The chaos hooks gate every backend entry point: an error-only
    /// injector turns batch / prefill / decode calls into structured
    /// injected errors, and disarming restores normal service in place.
    #[test]
    fn fault_hooks_gate_every_entry_point() {
        use crate::util::faults::{FaultConfig, FaultInjector};
        let faults = Arc::new(FaultInjector::new(FaultConfig {
            error_rate: 1.0,
            ..FaultConfig::quiet(5)
        }));
        let mut b = NativeBackend::new(NativeModelConfig {
            seq_len: 16,
            faults: Some(Arc::clone(&faults)),
            ..Default::default()
        });
        let tokens = vec![1i32; 16];
        let mut logits = Vec::new();
        let err = b
            .run_into(Variant::Dense, &tokens, 1, &mut logits)
            .expect_err("injected");
        assert!(format!("{err:#}").contains("injected backend error at backend.run"));
        assert!(b.open_session(1, Variant::Dense, &tokens[..4]).is_err());
        assert!(b.decode_into(1, 2, &mut logits).is_err());
        assert_eq!(faults.injected_total(), 3);
        faults.set_armed(false);
        b.run_into(Variant::Dense, &tokens, 1, &mut logits).unwrap();
        assert_eq!(b.open_session(1, Variant::Dense, &tokens[..4]).unwrap(), 4);
    }

    /// Closed sessions return their cache to the recycler: reopening runs
    /// on the grown buckets with zero new cache grow events.
    #[test]
    fn session_churn_recycles_caches() {
        let mut b = NativeBackend::new(NativeModelConfig::default());
        let prompt = vec![3i32; 200];
        b.open_session(1, DSA90, &prompt).unwrap();
        let mut logits = Vec::new();
        for _ in 0..56 {
            b.decode_into(1, 8, &mut logits).unwrap();
        }
        let grown = b.cache_grows();
        assert!(grown >= 1, "cold session must grow cache buckets");
        b.close_session(1).unwrap();
        assert_eq!(b.cache_grows(), grown, "pool must retain grown buckets");
        b.open_session(2, DSA90, &prompt).unwrap();
        for _ in 0..56 {
            b.decode_into(2, 8, &mut logits).unwrap();
        }
        assert_eq!(b.cache_grows(), grown, "recycled session re-grew its cache");
        b.close_session(2).unwrap();
        let s = b.cache_pool_stats();
        assert_eq!((s.created, s.recycled, s.free), (1, 1, 1));
    }
}
