//! Serving metrics: latency / queue-time summaries, batch occupancy,
//! per-variant counters, adaptive-router decisions and worker-pool stats.
//! Shared across engine + server threads; everything here surfaces in the
//! server's `{"op":"metrics"}` response.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::kernels::pool::PoolStats;
use crate::kernels::Variant;
use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::util::sync::lock_recover;

#[derive(Default)]
struct Inner {
    // Keyed by the typed `Variant` (Copy + Ord): the per-batch recording
    // path allocates no key strings — names render via Display only when
    // a report/JSON snapshot is taken.
    latency: BTreeMap<Variant, Summary>,
    queue_time: BTreeMap<Variant, Summary>,
    batch_occupancy: Summary,
    completed: u64,
    rejected: u64,
    batches: u64,
    started: Option<Instant>,
    // --- overload accounting (admission control / shed ladder) ---
    /// Requests shed because their deadline expired in queue, keyed by
    /// the variant they would have run as.
    expired: BTreeMap<Variant, u64>,
    /// Batches the shed ladder forced onto the sparsest rung (a subset of
    /// `routed` — degradation is a routing decision under pressure).
    degraded: BTreeMap<Variant, u64>,
    /// Requests answered with a structured execution error (injected or
    /// real backend failure, including caught panics).
    errored: u64,
    /// Submissions refused by a per-client quota at the server boundary.
    quota_rejected: u64,
    /// Adaptive-router decisions: variant -> batches routed there.
    routed: BTreeMap<Variant, u64>,
    /// Most recent router rung (None until the router decides once).
    router_rung: Option<Variant>,
    /// Latest worker-pool snapshot (None until a batch executed).
    pool: Option<PoolStats>,
    // --- decode sessions (all zero until the first `open`) ---
    sessions_opened: u64,
    sessions_closed: u64,
    /// Sessions force-closed by the engine's LRU capacity bound.
    sessions_evicted: u64,
    /// Live-session gauges, refreshed by the engine after session work.
    active_sessions: u64,
    /// Tokens resident across live session caches.
    resident_tokens: u64,
    /// KV-cache bucket grow events (live sessions + pooled free list) —
    /// flat once steady-state churn runs on recycled capacity.
    cache_grows: u64,
    decode_steps: u64,
    /// Per-variant decode step latency (the serving inter-token latency).
    decode_latency: BTreeMap<Variant, Summary>,
    // --- replica supervision (all zero until a ReplicaSet records) ---
    /// Configured replica count (the gauge's denominator); the section
    /// surfaces once this is nonzero.
    replicas_configured: u64,
    /// Replicas currently healthy (worker alive + heartbeat fresh).
    replicas_alive: u64,
    /// Crashed or wedged replicas the supervisor tore down.
    replica_crashes: u64,
    /// Fresh replicas the supervisor spawned to replace torn-down ones.
    replica_respawns: u64,
    /// One-shot requests transparently re-dispatched onto a sibling after
    /// their replica died mid-flight (each still counts once as served).
    retried: u64,
    /// Session ops answered `session_lost` because their replica died.
    /// With durable sessions this only counts **failed migrations**
    /// (replay budget / siblings / memory exhausted) — a successful
    /// migration is counted under `sessions_migrated` instead.
    session_lost: u64,
    // --- durable sessions (journaled replay / migration) ---
    /// Sessions transparently migrated onto a healthy sibling by
    /// replaying their token journal after their replica died or was
    /// drained.
    sessions_migrated: u64,
    /// Tokens replayed (prompt + decoded history) across all migrations.
    replayed_tokens: u64,
    /// Migration attempts that fell back to `session_lost` because the
    /// replay budget, healthy siblings or the resident-token budget were
    /// exhausted.
    migration_failed: u64,
    /// Session opens refused by the global `--max-resident-tokens`
    /// memory budget.
    resident_budget_rejected: u64,
    /// One-shots that failed over to a sibling *before* acceptance (a
    /// replica crash raced the dispatch) — distinct from `retried`,
    /// which counts post-acceptance failovers.
    failover_races: u64,
}

/// Thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Self {
        let m = Metrics::default();
        lock_recover(&m.inner).started = Some(Instant::now());
        m
    }

    /// Record one executed batch under the typed serving variant —
    /// allocation-free: the `Variant` key is `Copy`, so nothing is
    /// heap-allocated inside the metrics mutex on the per-batch path.
    pub fn record_batch(&self, variant: Variant, occupancy: usize, latencies_s: &[(f64, f64)]) {
        let mut g = lock_recover(&self.inner);
        g.batches += 1;
        g.batch_occupancy.add(occupancy as f64);
        g.completed += latencies_s.len() as u64;
        let lat = g.latency.entry(variant).or_default();
        for (l, _) in latencies_s {
            lat.add(*l);
        }
        let qt = g.queue_time.entry(variant).or_default();
        for (_, q) in latencies_s {
            qt.add(*q);
        }
    }

    pub fn record_rejected(&self, n: u64) {
        lock_recover(&self.inner).rejected += n;
    }

    /// Record `n` requests shed because their deadline expired while
    /// queued, under the variant they would have run as.
    pub fn record_expired(&self, variant: Variant, n: u64) {
        *lock_recover(&self.inner).expired.entry(variant).or_insert(0) += n;
    }

    /// Record one batch degraded to the sparsest rung by the shed ladder
    /// (also counted in `routed` by the caller's `record_routed`).
    pub fn record_degraded(&self, variant: Variant) {
        *lock_recover(&self.inner).degraded.entry(variant).or_insert(0) += 1;
    }

    /// Record `n` requests answered with a structured execution error.
    pub fn record_errored(&self, n: u64) {
        lock_recover(&self.inner).errored += n;
    }

    /// Record one submission refused by a per-client quota.
    pub fn record_quota_rejected(&self) {
        lock_recover(&self.inner).quota_rejected += 1;
    }

    /// Record an adaptive-router decision: one batch routed to `variant`.
    pub fn record_routed(&self, variant: Variant) {
        let mut g = lock_recover(&self.inner);
        *g.routed.entry(variant).or_insert(0) += 1;
        g.router_rung = Some(variant);
    }

    /// Record the latest worker-pool counters (taken after each batch).
    pub fn record_pool(&self, stats: PoolStats) {
        lock_recover(&self.inner).pool = Some(stats);
    }

    pub fn record_session_opened(&self) {
        lock_recover(&self.inner).sessions_opened += 1;
    }

    pub fn record_session_closed(&self) {
        lock_recover(&self.inner).sessions_closed += 1;
    }

    /// Record an LRU eviction (the engine also records the implied close).
    pub fn record_session_evicted(&self) {
        let mut g = lock_recover(&self.inner);
        g.sessions_evicted += 1;
        g.sessions_closed += 1;
    }

    /// Refresh the replica-health gauges (supervisor sweep / startup).
    pub fn set_replica_gauges(&self, alive: usize, configured: usize) {
        let mut g = lock_recover(&self.inner);
        g.replicas_alive = alive as u64;
        g.replicas_configured = configured as u64;
    }

    /// Record one replica torn down as crashed or wedged.
    pub fn record_replica_crash(&self) {
        lock_recover(&self.inner).replica_crashes += 1;
    }

    /// Record one fresh replica spawned to replace a torn-down one.
    pub fn record_replica_respawn(&self) {
        lock_recover(&self.inner).replica_respawns += 1;
    }

    /// Record one one-shot request re-dispatched onto a sibling replica.
    pub fn record_retried(&self) {
        lock_recover(&self.inner).retried += 1;
    }

    /// Record one session op answered `session_lost`.
    pub fn record_session_lost(&self) {
        lock_recover(&self.inner).session_lost += 1;
    }

    /// Record one session migrated onto a sibling replica, with the token
    /// count (prompt + decoded history) its journal replayed.
    pub fn record_session_migrated(&self, replayed_tokens: u64) {
        let mut g = lock_recover(&self.inner);
        g.sessions_migrated += 1;
        g.replayed_tokens += replayed_tokens;
    }

    /// Record one migration attempt that fell back to `session_lost`.
    pub fn record_migration_failed(&self) {
        lock_recover(&self.inner).migration_failed += 1;
    }

    /// Record one session open refused by the global resident-token
    /// memory budget.
    pub fn record_resident_budget_rejected(&self) {
        lock_recover(&self.inner).resident_budget_rejected += 1;
    }

    /// Record one pre-acceptance failover race: a replica crash raced the
    /// dispatch, and the request was re-picked onto a sibling without
    /// ever having been accepted (so it is not a `retried`).
    pub fn record_failover_race(&self) {
        lock_recover(&self.inner).failover_races += 1;
    }

    /// Replicas currently healthy, as last gauged by the supervisor.
    pub fn replicas_alive(&self) -> u64 {
        lock_recover(&self.inner).replicas_alive
    }

    /// Crashed/wedged replicas torn down so far.
    pub fn replica_crashes(&self) -> u64 {
        lock_recover(&self.inner).replica_crashes
    }

    /// Replicas respawned so far.
    pub fn replica_respawns(&self) -> u64 {
        lock_recover(&self.inner).replica_respawns
    }

    /// One-shot requests retried onto a sibling so far.
    pub fn retried(&self) -> u64 {
        lock_recover(&self.inner).retried
    }

    /// Session ops answered `session_lost` so far.
    pub fn session_lost(&self) -> u64 {
        lock_recover(&self.inner).session_lost
    }

    /// Sessions migrated onto a sibling so far.
    pub fn sessions_migrated(&self) -> u64 {
        lock_recover(&self.inner).sessions_migrated
    }

    /// Tokens replayed across all migrations so far.
    pub fn replayed_tokens(&self) -> u64 {
        lock_recover(&self.inner).replayed_tokens
    }

    /// Migration attempts that fell back to `session_lost` so far.
    pub fn migration_failed(&self) -> u64 {
        lock_recover(&self.inner).migration_failed
    }

    /// Session opens refused by the resident-token budget so far.
    pub fn resident_budget_rejected(&self) -> u64 {
        lock_recover(&self.inner).resident_budget_rejected
    }

    /// Pre-acceptance failover races counted so far.
    pub fn failover_races(&self) -> u64 {
        lock_recover(&self.inner).failover_races
    }

    /// Tokens resident across live session caches, as last gauged.
    pub fn resident_tokens(&self) -> u64 {
        lock_recover(&self.inner).resident_tokens
    }

    /// Record one decode step under the session's variant; `latency_s` is
    /// enqueue-to-reply (the serving inter-token latency).
    pub fn record_decode(&self, variant: Variant, latency_s: f64) {
        let mut g = lock_recover(&self.inner);
        g.decode_steps += 1;
        g.decode_latency.entry(variant).or_default().add(latency_s);
    }

    /// Refresh the live-session gauges (engine worker, after session
    /// work): active session count, cache-resident tokens and cumulative
    /// KV-cache grow events.
    pub fn set_session_gauges(&self, active: usize, resident_tokens: usize, cache_grows: u64) {
        let mut g = lock_recover(&self.inner);
        g.active_sessions = active as u64;
        g.resident_tokens = resident_tokens as u64;
        g.cache_grows = cache_grows;
    }

    /// Cumulative KV-cache grow events as last gauged (e2e warm-cache
    /// assertions read this back through the protocol).
    pub fn cache_grows(&self) -> u64 {
        lock_recover(&self.inner).cache_grows
    }

    pub fn completed(&self) -> u64 {
        lock_recover(&self.inner).completed
    }

    pub fn rejected(&self) -> u64 {
        lock_recover(&self.inner).rejected
    }

    pub fn errored(&self) -> u64 {
        lock_recover(&self.inner).errored
    }

    pub fn expired_total(&self) -> u64 {
        lock_recover(&self.inner).expired.values().sum()
    }

    pub fn quota_rejected(&self) -> u64 {
        lock_recover(&self.inner).quota_rejected
    }

    /// Requests/second since start.
    pub fn throughput(&self) -> f64 {
        let g = lock_recover(&self.inner);
        match g.started {
            Some(t0) => g.completed as f64 / t0.elapsed().as_secs_f64().max(1e-9),
            None => 0.0,
        }
    }

    /// Human-readable multi-line report.
    pub fn report(&self) -> String {
        let mut g = lock_recover(&self.inner);
        let mut s = format!(
            "completed={} rejected={} batches={} mean_occupancy={:.2} throughput={:.1} req/s\n",
            g.completed,
            g.rejected,
            g.batches,
            g.batch_occupancy.mean(),
            {
                let t0 = g.started;
                match t0 {
                    Some(t) => g.completed as f64 / t.elapsed().as_secs_f64().max(1e-9),
                    None => 0.0,
                }
            }
        );
        let variants: Vec<Variant> = g.latency.keys().copied().collect();
        for v in variants {
            if let Some(sum) = g.latency.get_mut(&v) {
                let line = sum.report_ms(&format!("  {v} latency"));
                s.push_str(&line);
                s.push('\n');
            }
            if let Some(sum) = g.queue_time.get_mut(&v) {
                let line = sum.report_ms(&format!("  {v} queue  "));
                s.push_str(&line);
                s.push('\n');
            }
        }
        if g.sessions_opened > 0 {
            s.push_str(&format!(
                "  sessions active={} opened={} closed={} evicted={} resident_tokens={} cache_grows={}\n",
                g.active_sessions,
                g.sessions_opened,
                g.sessions_closed,
                g.sessions_evicted,
                g.resident_tokens,
                g.cache_grows
            ));
        }
        if g.sessions_migrated + g.migration_failed + g.resident_budget_rejected > 0 {
            s.push_str(&format!(
                "  sessions migrated={} replayed_tokens={} migration_failed={} resident_budget={}\n",
                g.sessions_migrated, g.replayed_tokens, g.migration_failed,
                g.resident_budget_rejected
            ));
        }
        if g.decode_steps > 0 {
            s.push_str(&format!("  decode steps={}\n", g.decode_steps));
            let variants: Vec<Variant> = g.decode_latency.keys().copied().collect();
            for v in variants {
                if let Some(sum) = g.decode_latency.get_mut(&v) {
                    let line = sum.report_ms(&format!("  {v} decode "));
                    s.push_str(&line);
                    s.push('\n');
                }
            }
        }
        if let Some(rung) = &g.router_rung {
            s.push_str(&format!("  router rung={rung} routed:"));
            for (v, n) in &g.routed {
                s.push_str(&format!(" {v}={n}"));
            }
            s.push('\n');
        }
        {
            let expired: u64 = g.expired.values().sum();
            let degraded: u64 = g.degraded.values().sum();
            s.push_str(&format!(
                "  overload shed={} expired={} degraded_batches={} quota_rejected={} errored={}\n",
                g.rejected, expired, degraded, g.quota_rejected, g.errored
            ));
        }
        if let Some(p) = &g.pool {
            s.push_str(&format!(
                "  pool workers={} dispatches={} tasks={} queue_hw={} scratch_grows={}\n",
                p.workers, p.dispatches, p.tasks_executed, p.queue_highwater, p.scratch_grows
            ));
        }
        if g.replicas_configured > 0 {
            s.push_str(&format!(
                "  replicas alive={}/{} crashes={} respawns={} retried={} failover_races={} session_lost={}\n",
                g.replicas_alive,
                g.replicas_configured,
                g.replica_crashes,
                g.replica_respawns,
                g.retried,
                g.failover_races,
                g.session_lost
            ));
        }
        s
    }

    /// Machine-readable snapshot.
    pub fn to_json(&self) -> Json {
        let mut g = lock_recover(&self.inner);
        let mut obj = vec![
            ("completed", Json::num(g.completed as f64)),
            ("rejected", Json::num(g.rejected as f64)),
            ("batches", Json::num(g.batches as f64)),
            ("mean_occupancy", Json::num(g.batch_occupancy.mean())),
        ];
        if let Some(t0) = g.started {
            obj.push((
                "throughput_rps",
                Json::num(g.completed as f64 / t0.elapsed().as_secs_f64().max(1e-9)),
            ));
        }
        let variants: Vec<Variant> = g.latency.keys().copied().collect();
        let mut per_variant = Vec::new();
        for v in variants {
            let Some(lat) = g.latency.get_mut(&v) else { continue };
            per_variant.push(Json::obj(vec![
                ("variant", Json::str(v.to_string())),
                ("n", Json::num(lat.len() as f64)),
                ("mean_ms", Json::num(lat.mean() * 1e3)),
                ("p50_ms", Json::num(lat.percentile(50.0) * 1e3)),
                ("p95_ms", Json::num(lat.percentile(95.0) * 1e3)),
                ("p99_ms", Json::num(lat.percentile(99.0) * 1e3)),
            ]));
        }
        obj.push(("variants", Json::Arr(per_variant)));
        // The overload section is always present (zeros included): the
        // chaos tests and operators need its absence to never be
        // ambiguous with "no overload happened".
        let per_variant_counts = |m: &BTreeMap<Variant, u64>| {
            Json::Obj(
                m.iter()
                    .map(|(v, &n)| (v.to_string(), Json::num(n as f64)))
                    .collect(),
            )
        };
        obj.push((
            "overload",
            Json::obj(vec![
                ("shed", Json::num(g.rejected as f64)),
                ("expired_total", Json::num(g.expired.values().sum::<u64>() as f64)),
                ("expired", per_variant_counts(&g.expired)),
                ("degraded_batches", per_variant_counts(&g.degraded)),
                ("quota_rejected", Json::num(g.quota_rejected as f64)),
                ("errored", Json::num(g.errored as f64)),
            ]),
        ));
        if g.sessions_opened + g.sessions_migrated + g.migration_failed
            + g.resident_budget_rejected
            > 0
        {
            obj.push((
                "sessions",
                Json::obj(vec![
                    ("active", Json::num(g.active_sessions as f64)),
                    ("opened", Json::num(g.sessions_opened as f64)),
                    ("closed", Json::num(g.sessions_closed as f64)),
                    ("evicted", Json::num(g.sessions_evicted as f64)),
                    ("resident_tokens", Json::num(g.resident_tokens as f64)),
                    ("cache_grows", Json::num(g.cache_grows as f64)),
                    ("migrated", Json::num(g.sessions_migrated as f64)),
                    ("replayed_tokens", Json::num(g.replayed_tokens as f64)),
                    ("migration_failed", Json::num(g.migration_failed as f64)),
                    ("resident_budget", Json::num(g.resident_budget_rejected as f64)),
                ]),
            ));
        }
        if g.decode_steps > 0 {
            let variants: Vec<Variant> = g.decode_latency.keys().copied().collect();
            let mut per_variant = Vec::new();
            for v in variants {
                let Some(lat) = g.decode_latency.get_mut(&v) else { continue };
                per_variant.push(Json::obj(vec![
                    ("variant", Json::str(v.to_string())),
                    ("n", Json::num(lat.len() as f64)),
                    ("mean_ms", Json::num(lat.mean() * 1e3)),
                    ("p50_ms", Json::num(lat.percentile(50.0) * 1e3)),
                    ("p95_ms", Json::num(lat.percentile(95.0) * 1e3)),
                    ("p99_ms", Json::num(lat.percentile(99.0) * 1e3)),
                ]));
            }
            obj.push((
                "decode",
                Json::obj(vec![
                    ("steps", Json::num(g.decode_steps as f64)),
                    ("variants", Json::Arr(per_variant)),
                ]),
            ));
        }
        if let Some(rung) = g.router_rung {
            let routed = Json::Obj(
                g.routed
                    .iter()
                    .map(|(v, &n)| (v.to_string(), Json::num(n as f64)))
                    .collect(),
            );
            obj.push((
                "router",
                Json::obj(vec![
                    ("rung", Json::str(rung.to_string())),
                    ("routed_batches", routed),
                ]),
            ));
        }
        if g.replicas_configured > 0 {
            obj.push((
                "replicas",
                Json::obj(vec![
                    ("alive", Json::num(g.replicas_alive as f64)),
                    ("configured", Json::num(g.replicas_configured as f64)),
                    ("crashes", Json::num(g.replica_crashes as f64)),
                    ("respawns", Json::num(g.replica_respawns as f64)),
                    ("retried", Json::num(g.retried as f64)),
                    ("failover_races", Json::num(g.failover_races as f64)),
                    ("session_lost", Json::num(g.session_lost as f64)),
                ]),
            ));
        }
        if let Some(p) = &g.pool {
            obj.push((
                "pool",
                Json::obj(vec![
                    ("workers", Json::num(p.workers as f64)),
                    ("dispatches", Json::num(p.dispatches as f64)),
                    ("tasks_executed", Json::num(p.tasks_executed as f64)),
                    ("queue_highwater", Json::num(p.queue_highwater as f64)),
                    ("scratch_grows", Json::num(p.scratch_grows as f64)),
                ]),
            ));
        }
        Json::obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = Metrics::new();
        let dense = Variant::Dense;
        m.record_batch(dense, 3, &[(0.010, 0.001), (0.012, 0.002), (0.011, 0.001)]);
        m.record_batch(dense, 1, &[(0.020, 0.005)]);
        m.record_rejected(2);
        assert_eq!(m.completed(), 4);
        let j = m.to_json();
        assert_eq!(j.get("rejected").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("batches").unwrap().as_f64(), Some(2.0));
        let report = m.report();
        assert!(report.contains("dense latency"));
        // router/pool/session sections are absent until recorded
        assert!(j.get("router").is_none());
        assert!(j.get("pool").is_none());
        assert!(j.get("sessions").is_none());
        assert!(j.get("decode").is_none());
    }

    /// Session lifecycle counters, live gauges and per-variant decode
    /// latency surface as their own typed sections once session traffic
    /// exists.
    #[test]
    fn session_and_decode_sections_surface() {
        let m = Metrics::new();
        m.record_session_opened();
        m.record_session_opened();
        m.record_session_closed();
        m.record_session_evicted();
        m.set_session_gauges(1, 200, 4);
        m.record_decode(Variant::Dsa { pct: 90 }, 0.002);
        m.record_decode(Variant::Dsa { pct: 90 }, 0.003);
        assert_eq!(m.cache_grows(), 4);
        let j = m.to_json();
        let s = j.get("sessions").expect("sessions section");
        assert_eq!(s.get("active").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(s.get("opened").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(s.get("closed").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(s.get("evicted").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(s.get("resident_tokens").and_then(|v| v.as_f64()), Some(200.0));
        assert_eq!(s.get("cache_grows").and_then(|v| v.as_f64()), Some(4.0));
        let d = j.get("decode").expect("decode section");
        assert_eq!(d.get("steps").and_then(|v| v.as_f64()), Some(2.0));
        let report = m.report();
        assert!(report.contains("sessions active=1"));
        assert!(report.contains("decode steps=2"));
        assert!(report.contains("dsa90 decode"));
    }

    /// Overload counters surface always (zeros included) and partition by
    /// decision: shed vs expired vs degraded vs quota vs errored.
    #[test]
    fn overload_section_always_present() {
        let m = Metrics::new();
        let j = m.to_json();
        let o = j.get("overload").expect("overload section at zero");
        assert_eq!(o.get("shed").and_then(|v| v.as_f64()), Some(0.0));
        assert_eq!(o.get("expired_total").and_then(|v| v.as_f64()), Some(0.0));

        m.record_rejected(3);
        m.record_expired(Variant::Dense, 2);
        m.record_expired(Variant::Dsa { pct: 95 }, 1);
        m.record_degraded(Variant::Dsa { pct: 95 });
        m.record_quota_rejected();
        m.record_errored(4);
        assert_eq!(m.rejected(), 3);
        assert_eq!(m.expired_total(), 3);
        assert_eq!(m.errored(), 4);
        assert_eq!(m.quota_rejected(), 1);
        let j = m.to_json();
        let o = j.get("overload").expect("overload section");
        assert_eq!(o.get("shed").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(o.get("expired_total").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(o.path(&["expired", "dense"]).and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(o.path(&["expired", "dsa95"]).and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(
            o.path(&["degraded_batches", "dsa95"]).and_then(|v| v.as_f64()),
            Some(1.0)
        );
        assert_eq!(o.get("quota_rejected").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(o.get("errored").and_then(|v| v.as_f64()), Some(4.0));
        let report = m.report();
        assert!(report.contains("overload shed=3 expired=3 degraded_batches=1"));
    }

    /// The replicas section is absent until a ReplicaSet gauges it, then
    /// surfaces the supervisor's health/failover counters.
    #[test]
    fn replicas_section_surfaces_once_gauged() {
        let m = Metrics::new();
        assert!(m.to_json().get("replicas").is_none());
        m.set_replica_gauges(2, 3);
        m.record_replica_crash();
        m.record_replica_respawn();
        m.record_retried();
        m.record_retried();
        m.record_session_lost();
        assert_eq!(m.replicas_alive(), 2);
        assert_eq!(m.replica_crashes(), 1);
        assert_eq!(m.replica_respawns(), 1);
        assert_eq!(m.retried(), 2);
        assert_eq!(m.session_lost(), 1);
        let j = m.to_json();
        let r = j.get("replicas").expect("replicas section");
        assert_eq!(r.get("alive").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(r.get("configured").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(r.get("crashes").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(r.get("respawns").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(r.get("retried").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(r.get("failover_races").and_then(|v| v.as_f64()), Some(0.0));
        assert_eq!(r.get("session_lost").and_then(|v| v.as_f64()), Some(1.0));
        assert!(m.report().contains("replicas alive=2/3 crashes=1 respawns=1"));
    }

    /// The durable-session counters surface the sessions section on their
    /// own (a migration can happen on a set whose shard-level `opened`
    /// counters live elsewhere) and the pre-acceptance `failover_races`
    /// counter rides in the replicas section — so the accounting identity
    /// has no invisible path.
    #[test]
    fn migration_and_failover_race_counters_surface() {
        let m = Metrics::new();
        assert!(m.to_json().get("sessions").is_none());
        m.record_session_migrated(96);
        m.record_session_migrated(32);
        m.record_migration_failed();
        m.record_resident_budget_rejected();
        m.record_failover_race();
        m.set_replica_gauges(2, 2);
        assert_eq!(m.sessions_migrated(), 2);
        assert_eq!(m.replayed_tokens(), 128);
        assert_eq!(m.migration_failed(), 1);
        assert_eq!(m.resident_budget_rejected(), 1);
        assert_eq!(m.failover_races(), 1);
        let j = m.to_json();
        let s = j.get("sessions").expect("sessions section via migration");
        assert_eq!(s.get("migrated").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(s.get("replayed_tokens").and_then(|v| v.as_f64()), Some(128.0));
        assert_eq!(s.get("migration_failed").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(s.get("resident_budget").and_then(|v| v.as_f64()), Some(1.0));
        let r = j.get("replicas").expect("replicas section");
        assert_eq!(r.get("failover_races").and_then(|v| v.as_f64()), Some(1.0));
        let report = m.report();
        assert!(report.contains("sessions migrated=2 replayed_tokens=128"));
        assert!(report.contains("failover_races=1"));
    }

    #[test]
    fn router_and_pool_sections_surface() {
        let m = Metrics::new();
        m.record_routed(Variant::Dense);
        m.record_routed(Variant::Dsa { pct: 90 });
        m.record_routed(Variant::Dsa { pct: 90 });
        m.record_pool(PoolStats {
            workers: 4,
            dispatches: 7,
            tasks_executed: 28,
            queue_highwater: 5,
            scratch_grows: 12,
        });
        let j = m.to_json();
        let router = j.get("router").expect("router section");
        assert_eq!(router.get("rung").and_then(|r| r.as_str()), Some("dsa90"));
        let routed = router.get("routed_batches").expect("routed counts");
        assert_eq!(routed.get("dense").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(routed.get("dsa90").and_then(|v| v.as_f64()), Some(2.0));
        let pool = j.get("pool").expect("pool section");
        assert_eq!(pool.get("workers").and_then(|v| v.as_f64()), Some(4.0));
        assert_eq!(pool.get("tasks_executed").and_then(|v| v.as_f64()), Some(28.0));
        assert_eq!(pool.get("queue_highwater").and_then(|v| v.as_f64()), Some(5.0));
        let report = m.report();
        assert!(report.contains("router rung=dsa90"));
        assert!(report.contains("pool workers=4"));
    }
}
