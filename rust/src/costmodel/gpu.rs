//! Analytical V100 kernel-time model (paper Table 4 and Fig. 10).
//!
//! We do not have a V100 in this sandbox (see DESIGN.md substitutions), so
//! kernel speedups are reproduced with a calibrated analytical model. For
//! the matrix kernels the model is
//!
//! `t_sparse(s) / t_dense = (1 - s) / eff + ovh`
//!
//! where `(1 - s)` is the kept-work fraction, `eff` is the sparse kernel's
//! throughput efficiency *relative to the dense baseline at the same
//! precision* (dense FP16 rides tensor cores, which is why fine-grained
//! FP16 kernels lose — Sec. 5.1), and `ovh` is the sparsity-independent
//! fraction (metadata traffic, gather latency, launch).
//!
//! `eff`/`ovh` are calibrated per (kernel, format, precision) to published
//! anchor points — Gale et al. 2020 fine-grained kernels (SpMM breakeven
//! ~71% sparsity, SDDMM ~88%; 1.85x / 1.09x at 90%) and Chen et al. 2021
//! column-vector kernels (Table 4's 1x4 / 1x8 rows). The *model output* is
//! then the full sparsity sweep, the crossover locations, and the ordering
//! between formats — the falsifiable shape the benches regenerate.
//!
//! The softmax model (Fig. 10) is a bandwidth roofline with a launch floor:
//! softmax is elementwise/memory-bound, so sparse softmax time scales with
//! kept bytes until the kernel-launch floor caps the speedup.

/// Hardware profile (defaults = NVIDIA V100-SXM2).
#[derive(Debug, Clone)]
pub struct GpuProfile {
    /// FP32 CUDA-core peak, FLOP/s.
    pub fp32_peak: f64,
    /// FP16 tensor-core peak, FLOP/s.
    pub fp16_tc_peak: f64,
    /// HBM bandwidth, B/s.
    pub hbm_bw: f64,
    /// Kernel launch + sync floor, seconds.
    pub launch_s: f64,
}

impl Default for GpuProfile {
    fn default() -> Self {
        GpuProfile {
            fp32_peak: 15.7e12,
            fp16_tc_peak: 125e12,
            hbm_bw: 900e9,
            launch_s: 4.5e-6,
        }
    }
}

/// Numeric precision of the kernel's operands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    Fp32,
    Fp16,
}

impl Precision {
    pub fn bytes(self) -> f64 {
        match self {
            Precision::Fp32 => 4.0,
            Precision::Fp16 => 2.0,
        }
    }
}

/// Sparsity format of the attention matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Format {
    Dense,
    /// Unstructured element-level sparsity.
    FineGrained,
    /// Column-vector 1xV encoding (Fig. 9); V = reuse factor.
    ColVec(usize),
}

impl Format {
    pub fn reuse(self) -> f64 {
        match self {
            Format::Dense => 64.0, // tiled GEMM reuse (register/SMEM blocking)
            Format::FineGrained => 1.0,
            Format::ColVec(v) => v as f64,
        }
    }
}

/// Attention kernel shapes: scores are [l, l], features are [l, d].
#[derive(Debug, Clone, Copy)]
pub struct AttnShape {
    pub l: usize,
    pub d: usize,
    /// batch * heads multiplier
    pub bh: usize,
}

impl AttnShape {
    /// Paper Table 4 / Fig. 10 setting (Text task: b=16, h=4, l=2000).
    pub fn table4() -> Self {
        AttnShape { l: 2000, d: 64, bh: 16 * 4 }
    }
}

/// Calibrated (efficiency, overhead) for a sparse kernel configuration.
///
/// Anchors (see module docs): fine-grained FP32 from Gale et al. 2020;
/// 1xV FP16 column-vector kernels from Chen et al. 2021 / paper Table 4.
/// Efficiency grows with the format's reuse factor; overhead shrinks as
/// metadata amortizes over larger vectors.
pub fn sparse_params(kernel: &str, fmt: Format, prec: Precision) -> (f64, f64) {
    let v = fmt.reuse();
    match (kernel, prec) {
        ("spmm", Precision::Fp32) => {
            // fine-grained anchor: eff 0.41, ovh 0.30 (breakeven ~71%).
            let eff = 0.41 * (1.0 + 0.12 * (v - 1.0)).min(2.2);
            let ovh = (0.30 / (1.0 + 0.05 * (v - 1.0))).max(0.10);
            (eff, ovh)
        }
        ("sddmm", Precision::Fp32) => {
            // fine-grained anchor: eff 0.24, ovh 0.50 (breakeven ~88%).
            let eff = 0.24 * (1.0 + 0.12 * (v - 1.0)).min(2.2);
            let ovh = (0.50 / (1.0 + 0.05 * (v - 1.0))).max(0.20);
            (eff, ovh)
        }
        ("spmm", Precision::Fp16) => {
            // Dense baseline is tensor-core: sparse kernels need reuse to
            // compete. Anchors: 1x4 -> 1.57x, 1x8 -> 1.94x at 90%.
            let eff = match fmt {
                Format::FineGrained => 0.12,
                _ => 0.10 + 0.047 * v, // v=4: 0.288, v=8: 0.476
            };
            let ovh = 0.31;
            (eff, ovh)
        }
        ("sddmm", Precision::Fp16) => {
            // Anchors: 1x4 -> 0.94x (slower than dense), 1x8 -> 1.15x.
            let eff = match fmt {
                Format::FineGrained => 0.08,
                _ => 0.10 + 0.022 * v, // v=4: 0.188, v=8: 0.276
            };
            let ovh = 0.50;
            (eff, ovh)
        }
        (k, _) => panic!("unknown kernel {k:?}"),
    }
}

/// Dense GEMM time for the attention-shaped product (roofline).
pub fn dense_gemm_time(shape: AttnShape, prec: Precision, gpu: &GpuProfile) -> f64 {
    let (l, d, bh) = (shape.l as f64, shape.d as f64, shape.bh as f64);
    let flops = bh * 2.0 * l * l * d;
    let (peak, util) = match prec {
        Precision::Fp32 => (gpu.fp32_peak, 0.65),
        Precision::Fp16 => (gpu.fp16_tc_peak, 0.50),
    };
    let bytes = bh * (l * l + 2.0 * l * d) * prec.bytes();
    (flops / (peak * util)).max(bytes / (gpu.hbm_bw * 0.80)) + gpu.launch_s
}

/// Sparse kernel time from the calibrated relative model.
pub fn sparse_kernel_time(
    kernel: &str,
    shape: AttnShape,
    fmt: Format,
    prec: Precision,
    sparsity: f64,
    gpu: &GpuProfile,
) -> f64 {
    assert!((0.0..1.0).contains(&sparsity));
    let t_dense = dense_gemm_time(shape, prec, gpu);
    match fmt {
        Format::Dense => t_dense,
        _ => {
            let (eff, ovh) = sparse_params(kernel, fmt, prec);
            t_dense * ((1.0 - sparsity) / eff + ovh) + gpu.launch_s
        }
    }
}

/// Speedup of a sparse kernel over the dense GEMM at the same precision
/// (Table 4's rows).
pub fn kernel_speedup(
    kernel: &str,
    shape: AttnShape,
    fmt: Format,
    prec: Precision,
    sparsity: f64,
) -> f64 {
    let gpu = GpuProfile::default();
    dense_gemm_time(shape, prec, &gpu)
        / sparse_kernel_time(kernel, shape, fmt, prec, sparsity, &gpu)
}

/// Breakeven sparsity: smallest sparsity where the sparse kernel wins.
pub fn breakeven_sparsity(kernel: &str, fmt: Format, prec: Precision) -> f64 {
    let (eff, ovh) = sparse_params(kernel, fmt, prec);
    // (1-s)/eff + ovh = 1  =>  s = 1 - eff*(1 - ovh)
    (1.0 - eff * (1.0 - ovh)).clamp(0.0, 1.0)
}

/// Softmax latency (Fig. 10): bandwidth-bound elementwise pass over the
/// score matrix; the sparse version touches only kept entries (CSR values)
/// plus index metadata, floored by the kernel launch.
pub fn softmax_time(shape: AttnShape, sparsity: f64, gpu: &GpuProfile) -> f64 {
    let n = shape.bh as f64 * shape.l as f64 * shape.l as f64;
    let keep = 1.0 - sparsity;
    // 3 passes over values (max, exp-sum, normalize write) + indices once.
    let idx = if sparsity > 0.0 { 4.0 } else { 0.0 };
    let bytes = n * keep * (3.0 * 4.0 + idx);
    bytes / (gpu.hbm_bw * 0.80) + gpu.launch_s
}

/// Fig. 10 series: speedup of sparse softmax vs dense at each sparsity.
pub fn softmax_speedup(shape: AttnShape, sparsity: f64) -> f64 {
    let gpu = GpuProfile::default();
    softmax_time(shape, 0.0, &gpu) / softmax_time(shape, sparsity, &gpu)
}

#[cfg(test)]
mod tests {
    use super::*;

    const S: AttnShape = AttnShape { l: 2000, d: 64, bh: 64 };

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() / b < tol
    }

    #[test]
    fn table4_fine_grained_fp32_anchors() {
        // Paper: fine-grained @90%: SpMM 1.85x, SDDMM 1.09x (FP32).
        let spmm = kernel_speedup("spmm", S, Format::FineGrained, Precision::Fp32, 0.90);
        let sddmm = kernel_speedup("sddmm", S, Format::FineGrained, Precision::Fp32, 0.90);
        assert!(close(spmm, 1.85, 0.10), "spmm {spmm}");
        assert!(close(sddmm, 1.09, 0.10), "sddmm {sddmm}");
        assert!(spmm > sddmm, "SpMM must benefit more than SDDMM");
    }

    #[test]
    fn table4_vector_fp16_anchors() {
        // Paper: vec 1x8 @90% FP16: SpMM 1.94x, SDDMM 1.15x;
        //        vec 1x4: SpMM 1.57x, SDDMM 0.94x (below 1 = slower).
        let spmm8 = kernel_speedup("spmm", S, Format::ColVec(8), Precision::Fp16, 0.90);
        let spmm4 = kernel_speedup("spmm", S, Format::ColVec(4), Precision::Fp16, 0.90);
        let sddmm8 = kernel_speedup("sddmm", S, Format::ColVec(8), Precision::Fp16, 0.90);
        let sddmm4 = kernel_speedup("sddmm", S, Format::ColVec(4), Precision::Fp16, 0.90);
        assert!(close(spmm8, 1.94, 0.12), "spmm8 {spmm8}");
        assert!(close(spmm4, 1.57, 0.12), "spmm4 {spmm4}");
        assert!(close(sddmm8, 1.15, 0.12), "sddmm8 {sddmm8}");
        assert!(close(sddmm4, 0.94, 0.12), "sddmm4 {sddmm4}");
    }

    #[test]
    fn fine_grained_fp16_loses_to_tensor_cores() {
        // Sec. 5.1: "when half precision is used ... fine-grained kernels
        // can hardly compete with GEMM" — dense FP16 rides tensor cores.
        let s = kernel_speedup("spmm", S, Format::FineGrained, Precision::Fp16, 0.90);
        assert!(s < 1.0, "fine-grained fp16 spmm speedup {s} should be < 1");
    }

    #[test]
    fn breakeven_near_published_points() {
        let spmm = breakeven_sparsity("spmm", Format::FineGrained, Precision::Fp32);
        assert!((0.65..0.78).contains(&spmm), "spmm breakeven {spmm}");
        let sddmm = breakeven_sparsity("sddmm", Format::FineGrained, Precision::Fp32);
        assert!((0.84..0.92).contains(&sddmm), "sddmm breakeven {sddmm}");
    }

    #[test]
    fn speedup_monotone_in_sparsity() {
        let mut prev = 0.0;
        for s in [0.5, 0.7, 0.9, 0.95, 0.99] {
            let v = kernel_speedup("spmm", S, Format::FineGrained, Precision::Fp32, s);
            assert!(v > prev);
            prev = v;
        }
    }

    #[test]
    fn softmax_speedup_range_matches_fig10() {
        // Paper Fig. 10 (b=16, h=4, l=2000): 3.0x – 709.9x.
        let shape = AttnShape::table4();
        let s50 = softmax_speedup(shape, 0.50);
        let s999 = softmax_speedup(shape, 0.999);
        assert!(s50 > 1.3 && s50 < 5.0, "s50 {s50}");
        assert!(s999 > 100.0, "s999 {s999}");
        // monotone in sparsity
        let mut prev = 0.0;
        for s in [0.5, 0.75, 0.9, 0.95, 0.99, 0.999] {
            let v = softmax_speedup(shape, s);
            assert!(v > prev);
            prev = v;
        }
    }

    #[test]
    fn launch_floor_caps_speedup() {
        let shape = AttnShape::table4();
        let s1 = softmax_speedup(shape, 0.99995);
        let s2 = softmax_speedup(shape, 0.99999);
        // near-identical: the launch floor dominates
        assert!((s1 - s2).abs() / s1 < 0.05, "{s1} vs {s2}");
    }
}
