//! MAC-count model of a Transformer encoder layer (paper Sec. 4.4, Fig. 7
//! and the Sec. 3.3 computation-saving analysis).
//!
//! Breakdown follows the paper:
//! * **Linear** — Q/K/V/output projections: `4 l d^2`
//! * **Attention** — `QK^T` and `AV`: `2 l^2 d` (summed over heads)
//! * **Other** — position-wise FFN: `2 l d d_ff`
//!
//! DSA scales the Attention part by the keep ratio `(1 - sparsity)` and adds
//! the prediction path: `XP` (`l d k`), the two `k x k` transforms
//! (`2 l k^2` per head) and `S~ = Q~K~^T` (`l^2 k` per head), counted in
//! *reduced-precision* MACs (Sec. 3.3's beta factor).

/// LRA-style model/workload configuration for cost accounting.
#[derive(Debug, Clone)]
pub struct LayerShape {
    pub seq_len: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_layers: usize,
}

impl LayerShape {
    /// Paper benchmark configs (Appendix A).
    pub fn lra_text() -> Self {
        LayerShape { seq_len: 2000, d_model: 256, n_heads: 4, d_ff: 1024, n_layers: 4 }
    }
    pub fn lra_text_4k() -> Self {
        LayerShape { seq_len: 4000, d_model: 256, n_heads: 4, d_ff: 1024, n_layers: 4 }
    }
    pub fn lra_retrieval() -> Self {
        LayerShape { seq_len: 4000, d_model: 128, n_heads: 4, d_ff: 512, n_layers: 4 }
    }
    pub fn lra_image() -> Self {
        // Appendix A.3: one layer, 8 heads, 64 q/k/v hidden dims, 128 FFN.
        LayerShape { seq_len: 1024, d_model: 64, n_heads: 8, d_ff: 128, n_layers: 1 }
    }
    /// This repo's serving testbed config.
    pub fn testbed() -> Self {
        LayerShape { seq_len: 256, d_model: 128, n_heads: 4, d_ff: 256, n_layers: 2 }
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }
}

/// Full-precision MAC breakdown for one forward pass of the whole encoder.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MacBreakdown {
    pub linear: f64,
    pub attention: f64,
    pub other: f64,
    /// Reduced-precision prediction-path MACs (0 for dense).
    pub prediction: f64,
}

impl MacBreakdown {
    pub fn total_fp(&self) -> f64 {
        self.linear + self.attention + self.other
    }

    /// Prediction overhead relative to the *dense* model's FP MACs — the
    /// paper reports 1.17%–1.33% (Sec. 1 / Sec. 3.3).
    pub fn prediction_overhead(&self, dense: &MacBreakdown) -> f64 {
        self.prediction / dense.total_fp()
    }
}

/// Dense vanilla-transformer MACs.
pub fn dense_macs(s: &LayerShape) -> MacBreakdown {
    let (l, d, ff) = (s.seq_len as f64, s.d_model as f64, s.d_ff as f64);
    let per_layer_linear = 4.0 * l * d * d;
    let per_layer_attn = 2.0 * l * l * d;
    let per_layer_other = 2.0 * l * d * ff;
    let n = s.n_layers as f64;
    MacBreakdown {
        linear: n * per_layer_linear,
        attention: n * per_layer_attn,
        other: n * per_layer_other,
        prediction: 0.0,
    }
}

/// DSA MACs at `sparsity` with projection scale `sigma` (k = sigma * d_head).
pub fn dsa_macs(s: &LayerShape, sparsity: f64, sigma: f64) -> MacBreakdown {
    assert!((0.0..1.0).contains(&sparsity));
    let dense = dense_macs(s);
    let keep = 1.0 - sparsity;
    let (l, d) = (s.seq_len as f64, s.d_model as f64);
    let h = s.n_heads as f64;
    let k = (sigma * s.d_head() as f64).max(1.0);
    // Per layer: shared XP + per-head (Q~, K~ transforms + S~ scores).
    let per_layer_pred = l * d * k + h * (2.0 * l * k * k + l * l * k);
    MacBreakdown {
        linear: dense.linear,
        attention: dense.attention * keep,
        other: dense.other,
        prediction: s.n_layers as f64 * per_layer_pred,
    }
}

/// Overall computation reduction of DSA vs dense (the paper's headline
/// "2.79x – 4.35x", Sec. 4.4) counting FP MACs only, as Fig. 7 does.
pub fn reduction_factor(s: &LayerShape, sparsity: f64, sigma: f64) -> f64 {
    dense_macs(s).total_fp() / dsa_macs(s, sparsity, sigma).total_fp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_breakdown_matches_formula() {
        let s = LayerShape { seq_len: 100, d_model: 64, n_heads: 4, d_ff: 128, n_layers: 2 };
        let m = dense_macs(&s);
        assert_eq!(m.linear, 2.0 * 4.0 * 100.0 * 64.0 * 64.0);
        assert_eq!(m.attention, 2.0 * 2.0 * 100.0 * 100.0 * 64.0);
        assert_eq!(m.other, 2.0 * 2.0 * 100.0 * 64.0 * 128.0);
    }

    #[test]
    fn attention_dominates_long_sequences() {
        let m = dense_macs(&LayerShape::lra_text_4k());
        assert!(m.attention > m.linear + m.other);
        // and not at short sequences
        let m2 = dense_macs(&LayerShape {
            seq_len: 64,
            ..LayerShape::lra_text()
        });
        assert!(m2.attention < m2.linear + m2.other);
    }

    #[test]
    fn dsa_scales_attention_only() {
        let s = LayerShape::lra_text();
        let d = dense_macs(&s);
        let m = dsa_macs(&s, 0.9, 0.25);
        assert_eq!(m.linear, d.linear);
        assert_eq!(m.other, d.other);
        assert!((m.attention - 0.1 * d.attention).abs() < 1e-3 * d.attention);
        assert!(m.prediction > 0.0);
    }

    #[test]
    fn paper_headline_reduction_range() {
        // Paper Sec. 4.4: "DSA achieves 2.79–4.35x computation reduction".
        // The 4K tasks sit at the top of the range; the 2K text config at
        // the bottom (its Linear+FFN share is larger).
        let r_text4k = reduction_factor(&LayerShape::lra_text_4k(), 0.95, 0.25);
        assert!(r_text4k > 2.79, "text-4k reduction {r_text4k}");
        let r_text2k = reduction_factor(&LayerShape::lra_text(), 0.95, 0.25);
        assert!(r_text2k < r_text4k, "longer sequences must save more");
        assert!(r_text2k > 1.5);
        let r_img = reduction_factor(&LayerShape::lra_image(), 0.95, 0.25);
        assert!(r_img > 1.0);
    }

    #[test]
    fn prediction_overhead_around_paper_range() {
        // INT4 prediction at sigma=0.25: paper reports ~1.17%-1.33% of the
        // dense FP32 computation when weighted by precision (beta = 4/32).
        let s = LayerShape::lra_text();
        let dense = dense_macs(&s);
        let m = dsa_macs(&s, 0.95, 0.25);
        let beta = 4.0 / 32.0;
        let ovh = m.prediction_overhead(&dense) * beta;
        assert!(ovh > 0.002 && ovh < 0.05, "overhead {ovh}");
    }
}
