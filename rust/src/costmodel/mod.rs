//! Analytical cost models reproducing the paper's efficiency results:
//!
//! * [`macs`] — MAC-count breakdown (Fig. 7, Sec. 3.3 / 4.4 headline).
//! * [`energy`] — relative-energy projection (Fig. 8).
//! * [`gpu`] — V100 roofline kernel model (Table 4, Fig. 10); see
//!   DESIGN.md substitutions for why this replaces real-GPU timing.

pub mod energy;
pub mod gpu;
pub mod macs;
pub mod tpu;
