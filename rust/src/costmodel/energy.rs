//! Relative-energy model (paper Fig. 8).
//!
//! The paper projects each reduced-precision MAC's energy to a fraction of
//! an FP32 MAC using 45nm factors from an industry-grade simulator
//! (Neurometer, Tang et al. 2021). We use the same style of table
//! (Horowitz-lineage 45nm numbers); keep in sync with
//! python/compile/quant.py `quant_mac_energy_factor`.

use super::macs::{dense_macs, dsa_macs, LayerShape, MacBreakdown};

/// Energy of one MAC at a given precision, relative to FP32 = 1.0.
pub fn mac_energy_factor(precision: &str) -> f64 {
    match precision {
        "fp32" => 1.0,
        "int16" => 0.35,
        "int8" => 0.12,
        "int4" => 0.045,
        "int2" => 0.02,
        p => panic!("unknown precision {p:?}"),
    }
}

/// Relative energy of a model configuration vs the dense FP32 baseline.
#[derive(Debug, Clone, Copy)]
pub struct EnergyReport {
    /// FP32-equivalent energy units of the main path.
    pub main_path: f64,
    /// FP32-equivalent energy units of the prediction path.
    pub prediction: f64,
    /// Dense baseline energy units.
    pub baseline: f64,
}

impl EnergyReport {
    /// Total relative energy (Fig. 8's bar height; baseline = 1.0).
    pub fn relative(&self) -> f64 {
        (self.main_path + self.prediction) / self.baseline
    }
}

/// Fig. 8: DSA at `sparsity`, prediction at `precision`, sigma = k/d_head.
pub fn dsa_energy(
    shape: &LayerShape,
    sparsity: f64,
    sigma: f64,
    precision: &str,
) -> EnergyReport {
    let dense: MacBreakdown = dense_macs(shape);
    let dsa: MacBreakdown = dsa_macs(shape, sparsity, sigma);
    EnergyReport {
        main_path: dsa.total_fp(), // runs at full precision
        prediction: dsa.prediction * mac_energy_factor(precision),
        baseline: dense.total_fp(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factors_monotone_in_bits() {
        assert!(mac_energy_factor("int2") < mac_energy_factor("int4"));
        assert!(mac_energy_factor("int4") < mac_energy_factor("int8"));
        assert!(mac_energy_factor("int8") < mac_energy_factor("int16"));
        assert!(mac_energy_factor("int16") < mac_energy_factor("fp32"));
    }

    #[test]
    fn fig8_dsa95_is_compelling() {
        // Paper: "even with the predictor overhead considered, the overall
        // benefit is still compelling" for DSA-95, sigma=0.25, INT4.
        for shape in [
            LayerShape::lra_text(),
            LayerShape::lra_retrieval(),
            LayerShape::lra_image(),
        ] {
            let e = dsa_energy(&shape, 0.95, 0.25, "int4");
            let rel = e.relative();
            assert!(rel < 0.75, "relative energy {rel} for {shape:?}");
            assert!(rel > 0.0);
        }
    }

    #[test]
    fn prediction_energy_small_at_int4() {
        let e = dsa_energy(&LayerShape::lra_text(), 0.95, 0.25, "int4");
        assert!(e.prediction < 0.05 * e.baseline);
        // ... but significant if run at FP32 (motivates quantization).
        let e32 = dsa_energy(&LayerShape::lra_text(), 0.95, 0.25, "fp32");
        assert!(e32.prediction > 5.0 * e.prediction);
    }
}
