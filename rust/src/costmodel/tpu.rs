//! TPU kernel estimator for the L1 Pallas masked-attention kernel.
//!
//! Pallas runs under `interpret=True` on this CPU testbed, so real-TPU
//! performance is *estimated*, not measured (DESIGN.md
//! §Hardware-Adaptation). This module makes the estimate explicit and
//! testable: given the kernel's BlockSpec tiling it computes the VMEM
//! residency, the MXU pass count, the block-level skip rate achievable at
//! a given dynamic sparsity, and a roofline latency estimate.

/// TPU core profile (defaults ≈ one TPUv4 core).
#[derive(Debug, Clone)]
pub struct TpuProfile {
    /// bf16 MXU peak, FLOP/s.
    pub mxu_peak: f64,
    /// VMEM capacity, bytes.
    pub vmem_bytes: f64,
    /// HBM bandwidth, B/s.
    pub hbm_bw: f64,
    /// Systolic tile edge (128 for the 128x128 MXU).
    pub mxu_tile: usize,
}

impl Default for TpuProfile {
    fn default() -> Self {
        TpuProfile {
            mxu_peak: 137.5e12,
            vmem_bytes: 16.0 * 1024.0 * 1024.0,
            hbm_bw: 1.2e12,
            mxu_tile: 128,
        }
    }
}

/// The masked-attention kernel's tiling (mirrors
/// python/compile/kernels/dsa_attention.py BlockSpecs).
#[derive(Debug, Clone, Copy)]
pub struct KernelTiling {
    pub l: usize,
    pub d: usize,
    pub block_q: usize,
    /// Element size (4 = f32, 2 = bf16).
    pub elem_bytes: usize,
}

impl KernelTiling {
    pub fn paper_text() -> Self {
        KernelTiling { l: 2048, d: 64, block_q: 128, elem_bytes: 4 }
    }
}

/// Static estimate of one kernel invocation.
#[derive(Debug, Clone, Copy)]
pub struct KernelEstimate {
    /// Peak VMEM residency (single-buffered), bytes.
    pub vmem_resident: f64,
    /// With double buffering of the streamed panels.
    pub vmem_double_buffered: f64,
    /// MXU passes per row panel (score + output stages), dense.
    pub mxu_passes_dense: u64,
    /// Estimated dense kernel latency, seconds (roofline).
    pub dense_latency_s: f64,
}

/// VMEM + MXU static analysis of the row-tiled masked-attention kernel.
pub fn estimate(t: KernelTiling, p: &TpuProfile) -> KernelEstimate {
    let (l, d, bq, b) = (t.l as f64, t.d as f64, t.block_q as f64, t.elem_bytes as f64);
    // Resident per grid step: Q panel + full K + full V + mask panel +
    // score scratch panel + output panel.
    let q_panel = bq * d * b;
    let kv = 2.0 * l * d * b;
    let mask_panel = bq * l * b;
    let score_panel = bq * l * 4.0; // f32 accumulation
    let out_panel = bq * d * b;
    let resident = q_panel + kv + mask_panel + score_panel + out_panel;

    // MXU passes per row panel: S = Q K^T needs (bq/T)*(l/T)*(d/T) passes;
    // Z = P V needs (bq/T)*(d/T)*(l/T).
    let tile = p.mxu_tile as f64;
    let per_panel = 2.0 * (bq / tile).ceil() * (l / tile).ceil() * (d / tile).max(1.0).ceil();
    let panels = (l / bq).ceil();

    // Roofline: FLOPs = 2 * 2*l*l*d (two matmuls); bytes = Q,K,V,mask,out.
    let flops = 4.0 * l * l * d;
    let bytes = (3.0 * l * d + l * l + l * d) * b;
    let dense_latency = (flops / (p.mxu_peak * 0.6)).max(bytes / (p.hbm_bw * 0.8));

    KernelEstimate {
        vmem_resident: resident,
        vmem_double_buffered: resident + q_panel + mask_panel + out_panel,
        mxu_passes_dense: (per_panel * panels) as u64,
        dense_latency_s: dense_latency,
    }
}

/// Fraction of MXU passes skippable at `sparsity` when the dynamic mask is
/// aligned to `block` (see sparse::BlockSparse::mxu_skip_rate): with
/// block == MXU tile, skip = block sparsity; finer-than-tile masks skip a
/// pass only when all covered blocks are empty, modeled by the probability
/// that a tile contains no kept block under a uniform block distribution.
pub fn mxu_skip_fraction(sparsity: f64, block: usize, mxu_tile: usize) -> f64 {
    assert!((0.0..1.0).contains(&sparsity));
    if block >= mxu_tile {
        return sparsity;
    }
    let per = (mxu_tile / block) as f64;
    // tile empty ⇔ all per^2 covered blocks empty (independent approx).
    sparsity.powf(per * per)
}

/// Estimated attention-stage speedup at a sparsity/alignment on TPU.
pub fn attention_speedup(t: KernelTiling, sparsity: f64, block: usize) -> f64 {
    let p = TpuProfile::default();
    let est = estimate(t, &p);
    let skip = mxu_skip_fraction(sparsity, block, p.mxu_tile);
    // Compute shrinks by the skip rate; HBM traffic shrinks only for the
    // mask/score panels (K/V still stream). Take the roofline max.
    let (l, d, b) = (t.l as f64, t.d as f64, t.elem_bytes as f64);
    let flops = 4.0 * l * l * d * (1.0 - skip);
    let bytes = (3.0 * l * d + (1.0 - sparsity) * l * l + l * d) * b;
    let sparse_latency = (flops / (p.mxu_peak * 0.6)).max(bytes / (p.hbm_bw * 0.8));
    est.dense_latency_s / sparse_latency
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_text_fits_vmem() {
        // DESIGN.md §Hardware-Adaptation: ~2.1 MB resident, <4.2 MB double
        // buffered at l=2048, block_q=128 — comfortably inside 16 MB VMEM.
        let est = estimate(KernelTiling::paper_text(), &TpuProfile::default());
        let mb = est.vmem_resident / (1024.0 * 1024.0);
        assert!(mb > 1.0 && mb < 4.0, "resident {mb} MB");
        assert!(est.vmem_double_buffered < 8.0 * 1024.0 * 1024.0);
    }

    #[test]
    fn block_q_sweep_tradeoff() {
        // Larger panels amortize K/V residency but grow the score panel.
        let base = KernelTiling::paper_text();
        let small = estimate(KernelTiling { block_q: 64, ..base }, &TpuProfile::default());
        let large = estimate(KernelTiling { block_q: 512, ..base }, &TpuProfile::default());
        assert!(small.vmem_resident < large.vmem_resident);
    }

    #[test]
    fn tile_aligned_masks_skip_at_sparsity() {
        assert!((mxu_skip_fraction(0.9, 128, 128) - 0.9).abs() < 1e-12);
        // fine-grained masks barely skip whole tiles
        assert!(mxu_skip_fraction(0.9, 1, 128) < 1e-6);
        // 64-blocks on a 128 tile: skip = 0.9^4
        assert!((mxu_skip_fraction(0.9, 64, 128) - 0.9f64.powi(4)).abs() < 1e-12);
    }

    #[test]
    fn estimated_speedup_range_matches_design_doc() {
        // DESIGN/EXPERIMENTS quote ~6-8x attention-stage speedup at DSA-90
        // with tile-aligned blocks.
        let s = attention_speedup(KernelTiling::paper_text(), 0.90, 128);
        assert!(s > 4.0 && s < 11.0, "speedup {s}");
        // Fine-grained masks give little TPU speedup — only the
        // bandwidth-side saving on score/mask traffic survives (the kernel
        // at these shapes is memory-bound); MXU passes are not skipped.
        // This is the quantitative version of "structural sparsity is
        // required on dense-matrix hardware" (Sec. 5.1).
        let f = attention_speedup(KernelTiling::paper_text(), 0.90, 1);
        assert!(f < 2.0, "fine-grained {f}");
        assert!(s > 2.0 * f, "block alignment must dominate fine-grained");
    }

    #[test]
    fn speedup_monotone_in_sparsity() {
        let mut prev = 0.0;
        for sp in [0.5, 0.8, 0.9, 0.95, 0.99] {
            let s = attention_speedup(KernelTiling::paper_text(), sp, 128);
            assert!(s >= prev);
            prev = s;
        }
    }
}
