//! Synthetic serving workloads: request generators with Poisson or bursty
//! arrivals, mirroring the text task's token distribution so predictions
//! run against in-distribution inputs. Long-lived session traffic
//! ([`Workload::next_session`]) splits the same sequences into a prompt
//! prefix (prefill at `open`) and a streamed decode tail, so session
//! benches exercise exactly the distribution the one-shot path serves.

use std::time::Duration;

use crate::util::rng::Rng;

/// Arrival process shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arrival {
    /// Poisson with the given mean rate.
    Poisson,
    /// Alternating hot/cold phases (rate x4 / rate x0.25, 1 s phases).
    Bursty,
    /// Back-to-back (closed loop, zero think time).
    Closed,
}

/// Workload generator configuration.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub seq_len: usize,
    pub rate_rps: f64,
    pub arrival: Arrival,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            seq_len: 256,
            rate_rps: 50.0,
            arrival: Arrival::Poisson,
            seed: 0,
        }
    }
}

/// One generated request: token ids + the delay to wait *before* issuing it.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub tokens: Vec<i32>,
    pub delay: Duration,
    /// Ground-truth label of the synthetic example (for accuracy checks).
    pub label: i32,
}

/// Streaming generator.
pub struct Workload {
    cfg: WorkloadConfig,
    rng: Rng,
    issued: usize,
}

impl Workload {
    pub fn new(cfg: WorkloadConfig) -> Self {
        let rng = Rng::new(cfg.seed ^ 0xD5A);
        Workload {
            cfg,
            rng,
            issued: 0,
        }
    }

    /// Generate a text-task example (needle counting — mirrors
    /// python/compile/data.py gen_text so the model is in-distribution).
    fn gen_tokens(&mut self) -> (Vec<i32>, i32) {
        let l = self.cfg.seq_len;
        let hi = (l / 16).max(8);
        let lo = (hi / 4).max(2);
        let needle = 1 + self.rng.below(254) as i32;
        let label = self.rng.below(2) as i32;
        let mut toks: Vec<i32> = (0..l)
            .map(|_| {
                let mut t = 1 + self.rng.below(254) as i32;
                if t == needle {
                    t = (t % 254) + 1;
                    if t == needle {
                        t = if needle == 1 { 2 } else { 1 };
                    }
                }
                t
            })
            .collect();
        toks[0] = needle;
        let count = if label == 1 {
            hi + self.rng.below(hi as u64) as usize
        } else {
            self.rng.below(lo as u64) as usize
        };
        let pos = self.rng.sample_indices(l - 1, count.min(l - 1));
        for p in pos {
            toks[1 + p] = needle;
        }
        (toks, label)
    }

    fn next_delay(&mut self) -> Duration {
        match self.cfg.arrival {
            Arrival::Closed => Duration::ZERO,
            Arrival::Poisson => {
                Duration::from_secs_f64(self.rng.exponential(self.cfg.rate_rps))
            }
            Arrival::Bursty => {
                // 1-second phases: hot = 4x rate, cold = 0.25x rate.
                let phase_hot = (self.issued / 16) % 2 == 0;
                let rate = if phase_hot {
                    self.cfg.rate_rps * 4.0
                } else {
                    self.cfg.rate_rps * 0.25
                };
                Duration::from_secs_f64(self.rng.exponential(rate))
            }
        }
    }

    pub fn next_request(&mut self) -> GenRequest {
        let delay = self.next_delay();
        let (tokens, label) = self.gen_tokens();
        self.issued += 1;
        GenRequest {
            tokens,
            delay,
            label,
        }
    }

    /// Generate a fixed-size trace up front (deterministic given the seed).
    pub fn trace(&mut self, n: usize) -> Vec<GenRequest> {
        (0..n).map(|_| self.next_request()).collect()
    }

    /// Generate one decode session: the same `seq_len`-token sequence a
    /// [`Workload::next_request`] at this point in the stream would
    /// produce, split at `prefill` into the open-time prompt and the
    /// streamed decode tail (so a session decoded to completion sees
    /// exactly the one-shot request's tokens — the decode-equals-infer
    /// property tests rely on it). `prefill` is clamped to
    /// `1..=seq_len`.
    pub fn next_session(&mut self, prefill: usize) -> GenSession {
        let delay = self.next_delay();
        let (mut tokens, label) = self.gen_tokens();
        self.issued += 1;
        let prefill = prefill.clamp(1, tokens.len());
        let steps = tokens.split_off(prefill);
        GenSession {
            prompt: tokens,
            steps,
            delay,
            label,
        }
    }

    /// Generate a fixed-size session trace (deterministic given the seed).
    pub fn session_trace(&mut self, n: usize, prefill: usize) -> Vec<GenSession> {
        (0..n).map(|_| self.next_session(prefill)).collect()
    }
}

/// One generated decode session: the prompt to `open` with, the tokens to
/// stream through `decode`, and the arrival delay *before* opening.
/// `prompt ∥ steps` is exactly one [`GenRequest::tokens`] sequence.
#[derive(Debug, Clone)]
pub struct GenSession {
    pub prompt: Vec<i32>,
    pub steps: Vec<i32>,
    pub delay: Duration,
    /// Ground-truth label of the full sequence (the final decode step's
    /// prediction is checked against this).
    pub label: i32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_trace() {
        let cfg = WorkloadConfig {
            seq_len: 64,
            seed: 7,
            ..Default::default()
        };
        let a = Workload::new(cfg.clone()).trace(5);
        let b = Workload::new(cfg).trace(5);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.delay, y.delay);
        }
    }

    #[test]
    fn tokens_valid_and_needle_planted() {
        let mut w = Workload::new(WorkloadConfig {
            seq_len: 128,
            ..Default::default()
        });
        for _ in 0..50 {
            let r = w.next_request();
            assert_eq!(r.tokens.len(), 128);
            assert!(r.tokens.iter().all(|&t| (1..=255).contains(&t)));
            let needle = r.tokens[0];
            let count = r.tokens[1..].iter().filter(|&&t| t == needle).count();
            let hi = 128usize / 16;
            if r.label == 1 {
                assert!(count >= hi, "label 1 but count {count}");
            } else {
                assert!(count < hi / 2, "label 0 but count {count}");
            }
        }
    }

    #[test]
    fn poisson_rate_roughly_matches() {
        let mut w = Workload::new(WorkloadConfig {
            seq_len: 16,
            rate_rps: 200.0,
            ..Default::default()
        });
        let trace = w.trace(2000);
        let total: f64 = trace.iter().map(|r| r.delay.as_secs_f64()).sum();
        let rate = 2000.0 / total;
        assert!((rate - 200.0).abs() < 20.0, "rate {rate}");
    }

    /// A session is a one-shot request split in two: same seed, same
    /// position in the stream → `prompt ∥ steps == next_request().tokens`
    /// with the same label, and the split lands at `prefill`.
    #[test]
    fn session_is_a_split_request() {
        let cfg = WorkloadConfig {
            seq_len: 64,
            seed: 99,
            ..Default::default()
        };
        let reqs = Workload::new(cfg.clone()).trace(4);
        let sessions = Workload::new(cfg).session_trace(4, 48);
        for (r, s) in reqs.iter().zip(sessions.iter()) {
            assert_eq!(s.prompt.len(), 48);
            assert_eq!(s.steps.len(), 64 - 48);
            let mut joined = s.prompt.clone();
            joined.extend_from_slice(&s.steps);
            assert_eq!(joined, r.tokens);
            assert_eq!(s.label, r.label);
            assert_eq!(s.delay, r.delay);
        }
    }

    /// The prefill split is clamped into `1..=seq_len` so every session
    /// has a non-empty prompt and the tail never underflows.
    #[test]
    fn session_prefill_is_clamped() {
        let mut w = Workload::new(WorkloadConfig {
            seq_len: 32,
            seed: 5,
            ..Default::default()
        });
        let s = w.next_session(0);
        assert_eq!((s.prompt.len(), s.steps.len()), (1, 31));
        let s = w.next_session(1000);
        assert_eq!((s.prompt.len(), s.steps.len()), (32, 0));
    }

    #[test]
    fn closed_loop_has_zero_delay() {
        let mut w = Workload::new(WorkloadConfig {
            arrival: Arrival::Closed,
            ..Default::default()
        });
        assert_eq!(w.next_request().delay, Duration::ZERO);
    }
}
