//! PE-array dataflow simulator for sparse attention (paper Sec. 5.2,
//! Fig. 11, Table 5).
//!
//! Models the two-step SDDMM→SpMM chain on a spatial array: `P` PEs work
//! row-parallel on a panel of `P` consecutive attention rows; each kept
//! entry (r, c) needs the second operand's vector `c` (a column of `K^T`
//! for SDDMM, a row of `V` for SpMM — same index pattern for both). The
//! simulator counts *operand vector loads* under three dataflows:
//!
//! * **RowByRow** — one row at a time, no cross-row sharing: every kept
//!   entry loads its operand vector (the paper's 1x baseline).
//! * **RowParallel** — P rows in lockstep by entry position; vectors
//!   requested by several PEs in the *same step* are loaded once
//!   (broadcast), so reuse only happens on coincidental alignment.
//! * **RowParallelReordered** — computations inside each row are reordered
//!   so the panel walks the *union* of its columns; each vector is loaded
//!   once per panel (Fig. 11 right). Out-of-order execution is free here
//!   because the reordered A is consumed entirely by the next GEMM — no
//!   reshuffle needed (Sec. 5.2).

use crate::sparse::Csr;

/// Dataflow policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataflow {
    RowByRow,
    RowParallel,
    RowParallelReordered,
}

/// Result of simulating one attention matrix.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub dataflow: Dataflow,
    pub pes: usize,
    /// Operand vector loads (each = one `d`-element memory access).
    pub vector_loads: u64,
    /// Total MAC-vector operations (= nnz).
    pub work: u64,
    /// Execution steps taken (panel-sequential).
    pub steps: u64,
    /// PE utilization: work / (P * steps).
    pub utilization: f64,
}

impl SimResult {
    /// Memory-access reduction vs the row-by-row baseline (Table 5 rows).
    pub fn reduction_vs(&self, baseline: &SimResult) -> f64 {
        baseline.vector_loads as f64 / self.vector_loads.max(1) as f64
    }
}

/// Simulate `csr` under `dataflow` with `pes` row-parallel PEs.
pub fn simulate(csr: &Csr, dataflow: Dataflow, pes: usize) -> SimResult {
    assert!(pes > 0);
    let nnz = csr.nnz() as u64;
    match dataflow {
        Dataflow::RowByRow => {
            // Sequential rows; every entry loads its vector.
            SimResult {
                dataflow,
                pes: 1,
                vector_loads: nnz,
                work: nnz,
                steps: nnz,
                utilization: 1.0,
            }
        }
        Dataflow::RowParallel => {
            let mut loads = 0u64;
            let mut steps = 0u64;
            let mut seen = vec![u64::MAX; csr.cols]; // step tag per column
            let mut step_tag = 0u64;
            for panel in (0..csr.rows).step_by(pes) {
                let rows: Vec<&[u32]> =
                    (panel..(panel + pes).min(csr.rows)).map(|r| csr.row(r)).collect();
                let depth = rows.iter().map(|r| r.len()).max().unwrap_or(0);
                for t in 0..depth {
                    step_tag += 1;
                    let mut any = false;
                    for row in &rows {
                        if let Some(&c) = row.get(t) {
                            any = true;
                            if seen[c as usize] != step_tag {
                                seen[c as usize] = step_tag;
                                loads += 1;
                            }
                        }
                    }
                    if any {
                        steps += 1;
                    }
                }
            }
            SimResult {
                dataflow,
                pes,
                vector_loads: loads,
                work: nnz,
                steps,
                utilization: nnz as f64 / (pes as f64 * steps.max(1) as f64),
            }
        }
        Dataflow::RowParallelReordered => {
            // Column-major walk of each panel's column union: one load per
            // distinct column per panel; a step serves every PE holding it.
            let mut loads = 0u64;
            let mut steps = 0u64;
            let mut stamp = vec![u64::MAX; csr.cols];
            let mut tag = 0u64;
            for panel in (0..csr.rows).step_by(pes) {
                tag += 1;
                let mut union = 0u64;
                for r in panel..(panel + pes).min(csr.rows) {
                    for &c in csr.row(r) {
                        if stamp[c as usize] != tag {
                            stamp[c as usize] = tag;
                            union += 1;
                        }
                    }
                }
                loads += union;
                steps += union; // one column broadcast per step
            }
            SimResult {
                dataflow,
                pes,
                vector_loads: loads,
                work: nnz,
                steps,
                utilization: nnz as f64 / (pes as f64 * steps.max(1) as f64),
            }
        }
    }
}

/// Convenience: run all three dataflows and report Table-5-style rows.
pub fn table5_rows(csr: &Csr, pes: usize) -> Vec<(String, f64)> {
    let base = simulate(csr, Dataflow::RowByRow, pes);
    [Dataflow::RowByRow, Dataflow::RowParallel, Dataflow::RowParallelReordered]
        .into_iter()
        .map(|df| {
            let r = simulate(csr, df, pes);
            (format!("{df:?}"), r.reduction_vs(&base))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{topk, DenseMask};
    use crate::util::rng::Rng;

    fn csr_from(entries: &[(usize, usize)], rows: usize, cols: usize) -> Csr {
        let mut m = DenseMask::zeros(rows, cols);
        for &(r, c) in entries {
            m.set(r, c, true);
        }
        Csr::from_mask(&m)
    }

    #[test]
    fn rowbyrow_counts_every_entry() {
        let csr = csr_from(&[(0, 1), (0, 3), (1, 1), (2, 5)], 4, 8);
        let r = simulate(&csr, Dataflow::RowByRow, 4);
        assert_eq!(r.vector_loads, 4);
        assert_eq!(r.work, 4);
    }

    #[test]
    fn reorder_loads_union_once() {
        // Panel of 4 rows sharing column 1: reordered loads {1,3,5} = 3.
        let csr = csr_from(&[(0, 1), (0, 3), (1, 1), (2, 5), (3, 1)], 4, 8);
        let r = simulate(&csr, Dataflow::RowParallelReordered, 4);
        assert_eq!(r.vector_loads, 3);
        assert_eq!(r.work, 5);
    }

    #[test]
    fn lockstep_coalesces_only_aligned() {
        // Rows [3,4] and [1,3]: step 0 = {3,1} (2 loads), step 1 = {4,3}
        // (2 loads) — the shared column 3 is NOT coalesced because it is
        // misaligned across the two rows; reordering captures it.
        let csr = csr_from(&[(0, 3), (0, 4), (1, 1), (1, 3)], 2, 8);
        let np = simulate(&csr, Dataflow::RowParallel, 2);
        assert_eq!(np.vector_loads, 4);
        let re = simulate(&csr, Dataflow::RowParallelReordered, 2);
        assert_eq!(re.vector_loads, 3); // union {1,3,4}
    }

    #[test]
    fn lockstep_coalesces_aligned_columns() {
        // Both rows start with column 7: coalesced in step 0.
        let csr = csr_from(&[(0, 7), (1, 7)], 2, 8);
        let np = simulate(&csr, Dataflow::RowParallel, 2);
        assert_eq!(np.vector_loads, 1);
    }

    #[test]
    fn ordering_invariant_reorder_leq_lockstep_leq_base() {
        let mut rng = Rng::new(42);
        for _ in 0..20 {
            let rows = 32;
            let cols = 64;
            let k = 1 + rng.below(12) as usize;
            let scores: Vec<f32> = (0..rows * cols).map(|_| rng.f32()).collect();
            let m = topk::topk_mask_exact(&scores, rows, cols, k);
            let csr = Csr::from_mask(&m);
            let base = simulate(&csr, Dataflow::RowByRow, 8);
            let np = simulate(&csr, Dataflow::RowParallel, 8);
            let re = simulate(&csr, Dataflow::RowParallelReordered, 8);
            assert!(re.vector_loads <= np.vector_loads);
            assert!(np.vector_loads <= base.vector_loads);
            assert_eq!(base.work, re.work);
        }
    }

    #[test]
    fn row_uniform_masks_keep_pes_busy() {
        let mut rng = Rng::new(3);
        let (rows, cols, k) = (64, 128, 13);
        let scores: Vec<f32> = (0..rows * cols).map(|_| rng.f32()).collect();
        let m = topk::topk_mask_exact(&scores, rows, cols, k);
        let csr = Csr::from_mask(&m);
        let r = simulate(&csr, Dataflow::RowParallel, 8);
        // Row-uniform k ⇒ every lockstep step is fully occupied.
        assert!((r.utilization - 1.0).abs() < 1e-9, "util {}", r.utilization);
    }

    #[test]
    fn skewed_masks_underutilize() {
        // One long row + empty rows in the same panel.
        let mut entries = Vec::new();
        for c in 0..16 {
            entries.push((0usize, c));
        }
        entries.push((1, 0));
        let csr = csr_from(&entries, 4, 32);
        let r = simulate(&csr, Dataflow::RowParallel, 4);
        assert!(r.utilization < 0.5, "util {}", r.utilization);
    }
}
