//! Hardware-specialization simulators (paper Sec. 5.2):
//!
//! * [`dataflow`] — PE-array operand-load simulator: row-by-row vs
//!   row-parallel vs reordered (Fig. 11 / Table 5).
//! * [`multiprecision`] — decoupled vs coupled multi-precision PE arrays.

pub mod dataflow;
pub mod multiprecision;

pub use dataflow::{simulate, Dataflow, SimResult};
