//! Multi-precision PE-array organization study (paper Sec. 5.2).
//!
//! DSA needs both low-precision prediction (INT4-ish) and full-precision
//! execution. The paper contrasts two organizations:
//!
//! * **Decoupled** — two fixed arrays (small low-precision + large
//!   full-precision) working as a pipeline; throughput ratio is fixed, so
//!   one side idles whenever the workload ratio differs (Liu et al. 2020).
//! * **Coupled** — one array of precision-configurable PEs (BitFusion
//!   style); sections are re-partitioned per layer, trading idle time for
//!   runtime configuration complexity.
//!
//! The model assigns each PE a throughput of 1 FP32 MAC/cycle or
//! `int_speedup` INT4 MACs/cycle and reports makespan + utilization for a
//! (prediction, execution) workload pair.

/// One array organization.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrayOrg {
    /// `frac_lp` of the PEs are permanently low-precision.
    Decoupled { frac_lp: f64 },
    /// PEs reconfigure between phases; `reconfig_overhead` is the fraction
    /// of a phase lost to reconfiguration.
    Coupled { reconfig_overhead: f64 },
}

/// Workload of one attention layer, in MAC counts.
#[derive(Debug, Clone, Copy)]
pub struct PhaseWork {
    /// Low-precision prediction MACs.
    pub predict_macs: f64,
    /// Full-precision execution MACs (sparse attention + projections).
    pub exec_macs: f64,
}

/// Simulation output.
#[derive(Debug, Clone, Copy)]
pub struct OrgResult {
    /// Cycles to finish the layer (normalized PE-cycles).
    pub cycles: f64,
    /// Fraction of PE-cycles doing useful work.
    pub utilization: f64,
}

/// Evaluate an organization on a workload.
///
/// `n_pes` full-precision-equivalent PEs; a low-precision PE does
/// `int_speedup` prediction MACs per cycle (e.g. 8 for INT4 vs FP32
/// bit-parallel area parity).
pub fn evaluate(org: ArrayOrg, w: PhaseWork, n_pes: f64, int_speedup: f64) -> OrgResult {
    assert!(n_pes > 0.0 && int_speedup > 0.0);
    match org {
        ArrayOrg::Decoupled { frac_lp } => {
            assert!((0.0..1.0).contains(&frac_lp) && frac_lp > 0.0);
            let lp = frac_lp * n_pes;
            let fp = (1.0 - frac_lp) * n_pes;
            // Pipelined: steady-state rate limited by the slower stage.
            let t_lp = w.predict_macs / (lp * int_speedup);
            let t_fp = w.exec_macs / fp;
            let cycles = t_lp.max(t_fp);
            let useful = w.predict_macs / int_speedup + w.exec_macs;
            OrgResult {
                cycles,
                utilization: useful / (cycles * n_pes),
            }
        }
        ArrayOrg::Coupled { reconfig_overhead } => {
            // Whole array per phase, plus reconfiguration loss.
            let t = w.predict_macs / (n_pes * int_speedup) + w.exec_macs / n_pes;
            let cycles = t * (1.0 + reconfig_overhead);
            let useful = w.predict_macs / int_speedup + w.exec_macs;
            OrgResult {
                cycles,
                utilization: useful / (cycles * n_pes),
            }
        }
    }
}

/// Best fixed split for a decoupled array on a *single* workload — used to
/// show the fragility: the optimum moves with the task's sparsity ratio.
pub fn best_decoupled_split(w: PhaseWork, _n_pes: f64, int_speedup: f64) -> f64 {
    // Balance: predict/(f*s) = exec/(1-f)  =>  f = p / (p + s*e) with p,e.
    let p = w.predict_macs;
    let e = w.exec_macs;
    (p / (p + int_speedup * e)).clamp(0.01, 0.99)
}

#[cfg(test)]
mod tests {
    use super::*;

    const W: PhaseWork = PhaseWork {
        predict_macs: 1.0e9,
        exec_macs: 4.0e9,
    };

    #[test]
    fn coupled_beats_mismatched_decoupled() {
        // A decoupled array sized for a different workload mix idles.
        let bad = evaluate(ArrayOrg::Decoupled { frac_lp: 0.5 }, W, 256.0, 8.0);
        let coupled = evaluate(ArrayOrg::Coupled { reconfig_overhead: 0.05 }, W, 256.0, 8.0);
        assert!(coupled.cycles < bad.cycles);
        assert!(coupled.utilization > bad.utilization);
    }

    #[test]
    fn well_sized_decoupled_matches_coupled() {
        let f = best_decoupled_split(W, 256.0, 8.0);
        let tuned = evaluate(ArrayOrg::Decoupled { frac_lp: f }, W, 256.0, 8.0);
        let coupled = evaluate(ArrayOrg::Coupled { reconfig_overhead: 0.05 }, W, 256.0, 8.0);
        // Pipelined + perfectly balanced beats sequential-with-overhead.
        assert!(tuned.cycles <= coupled.cycles * 1.05);
        assert!(tuned.utilization > 0.9);
    }

    #[test]
    fn optimum_split_moves_with_workload() {
        let f1 = best_decoupled_split(W, 256.0, 8.0);
        let w2 = PhaseWork {
            predict_macs: 1.0e9,
            exec_macs: 0.5e9, // much sparser execution
        };
        let f2 = best_decoupled_split(w2, 256.0, 8.0);
        assert!(f2 > f1 * 2.0, "split should shift: {f1} -> {f2}");
    }

    #[test]
    fn utilization_bounded() {
        for frac in [0.1, 0.3, 0.7] {
            let r = evaluate(ArrayOrg::Decoupled { frac_lp: frac }, W, 128.0, 8.0);
            assert!(r.utilization > 0.0 && r.utilization <= 1.0 + 1e-9);
        }
    }
}
